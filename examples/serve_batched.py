"""Batched serving example: continuous greedy decoding with a sharded KV
cache across three architecture families (dense GQA, SSM, hybrid) — the
serving-side counterpart of the dry-run's decode shapes.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.launch.serve import BatchedServer
from repro.models import build_model


def main() -> None:
    for arch in ("qwen2-0.5b", "mamba2-130m", "zamba2-1.2b"):
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        srv = BatchedServer(model, params, batch=4, max_seq=64)
        prompts = jax.random.randint(jax.random.key(1), (4, 6), 0,
                                     cfg.vocab_size)
        t0 = time.perf_counter()
        out = srv.generate(prompts, steps=16)
        dt = time.perf_counter() - t0
        toks = out.size
        print(f"{arch:14s} [{cfg.arch_type:6s}] generated {toks} tokens in "
              f"{dt:.2f}s ({toks/dt:.0f} tok/s on CPU) "
              f"sample={out[0, :8].tolist()}")


if __name__ == "__main__":
    main()
