"""Quickstart: train a reduced-config model with the production train step
(KVStore-MPI semantics: mpi-SGD, one client) on the synthetic bigram
language, checkpoint it, and serve a few tokens.

  PYTHONPATH=src python examples/quickstart.py [--arch qwen2-0.5b] [--steps 60]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import get_config, reduced
from repro.core.hierarchy import SyncConfig
from repro.data import DataConfig, TokenPipeline
from repro.launch.serve import BatchedServer
from repro.launch.train import make_train_state, make_train_step
from repro.models import build_model
from repro.optim import sgd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=0.1)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    print(f"arch={cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model}) "
          f"params={sum(l.size for l in jax.tree_util.tree_leaves(jax.eval_shape(model.init, jax.random.key(0)))):,}")

    # vocab 256 keeps the bigram automaton learnable in ~60 steps on CPU
    pipe = TokenPipeline(DataConfig(seed=0, vocab_size=256, seq_len=64,
                                    batch_size=8,
                                    steps_per_epoch=args.steps))
    print(f"loss floor (automaton entropy): {pipe.optimal_xent():.3f}")

    optimizer = sgd(args.lr, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    state = make_train_state(model, optimizer, sync, jax.random.key(0))
    step = jax.jit(make_train_step(model, optimizer, sync, None))

    for i, batch in enumerate(pipe.epoch(0)):
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}")

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, state["params"], step=args.steps)
        params, meta = restore_checkpoint(
            path, jax.tree.map(jnp.zeros_like, state["params"]))
        print(f"checkpoint round-trip ok (step {meta['step']})")

    srv = BatchedServer(model, params, batch=2, max_seq=96)
    prompts = pipe.batch_at(1, 0)["tokens"][:2, :8]
    out = srv.generate(prompts, steps=12)
    print("prompt :", prompts.tolist())
    print("greedy :", out.tolist())


if __name__ == "__main__":
    main()
