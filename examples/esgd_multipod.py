"""mpi-ESGD with the production train step: two clients (the multi-pod
layout) doing local sync-SGD with lazy elastic exchange — the paper's
path to cluster-wide scaling — vs fully-synchronous mpi-SGD at the same
token budget.

The C>1 production path is the 2-axis pod×data shard driver (the
default): each client is one pod of ``--data-per-pod`` devices, the
gradient leg reduce-scatters over the ``data`` communicator INSIDE the
pod, and the elastic exchange is the only traffic crossing the ``pod``
communicator (``core.comm.Communicator`` groups — the paper's
MPI-groups-in-KVStore model). ``--driver vmap`` keeps the single-process
stacked-client step as the readable reference; both run the same
flat-substrate math and their losses match to float tolerance.

  PYTHONPATH=src python examples/esgd_multipod.py [--steps 80]
  PYTHONPATH=src python examples/esgd_multipod.py --driver vmap
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core.hierarchy import SyncConfig, declientize
from repro.data import DataConfig, TokenPipeline
from repro.launch import shard_driver
from repro.launch.train import make_train_state, make_train_step
from repro.models import build_model
from repro.optim import sgd


def run_mode(model, sync, pipes, steps, lr, driver="shard",
             data_per_pod=2):
    optimizer = sgd(lr, momentum=0.9)
    C = sync.num_clients
    sharded = driver == "shard" and C > 1
    if sharded:
        # one pod per client, data_per_pod devices inside each: the
        # 2-axis pod×data hierarchy in one mapped program
        geom = (C, data_per_pod)
        state = shard_driver.make_driver_state(model, optimizer, sync,
                                               geom, jax.random.key(0))
        step = jax.jit(shard_driver.make_emulated_step(
            model, optimizer, sync, geom))
    else:
        state = make_train_state(model, optimizer, sync, jax.random.key(0))
        step = jax.jit(make_train_step(model, optimizer, sync, None))
    losses = []
    for i in range(steps):
        batches = [p.batch_at(0, i) for p in pipes]
        if sharded:
            batch = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *batches)
            batch = shard_driver.shard_batch(batch, geom)
        elif C > 1:
            batch = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        else:
            batch = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *batches)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    replicas = C * data_per_pod if sharded else C
    params = declientize(state["params"], replicas)
    return losses, params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--interval", type=int, default=8)
    ap.add_argument("--driver", choices=("vmap", "shard"), default="shard",
                    help="'shard' (default): the 2-axis pod×data "
                         "production driver (launch/shard_driver.py, "
                         "emulated axes); 'vmap': the single-process "
                         "stacked-client reference step")
    ap.add_argument("--data-per-pod", type=int, default=2,
                    help="devices per pod-client on the shard driver's "
                         "'data' axis (the intra-client communicator)")
    args = ap.parse_args()

    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    pipes = [
        TokenPipeline(DataConfig(seed=0, vocab_size=256,
                                 seq_len=48, batch_size=4,
                                 steps_per_epoch=args.steps, shard=c))
        for c in range(2)
    ]

    print("== mpi-SGD (1 client, every-step global sync) ==")
    sgd_losses, _ = run_mode(
        model, SyncConfig(mode="mpi_sgd", num_clients=1), pipes,
        args.steps, lr=0.1)
    print("== mpi-ESGD (2 clients, elastic exchange every "
          f"{args.interval} steps, driver={args.driver}) ==")
    esgd_losses, _ = run_mode(
        model,
        SyncConfig(mode="mpi_esgd", num_clients=2, esgd_alpha=0.5,
                   esgd_interval=args.interval),
        pipes, args.steps, lr=0.1, driver=args.driver,
        data_per_pod=args.data_per_pod)

    print(f"\n{'step':>5s} {'mpi_sgd':>8s} {'mpi_esgd':>9s}")
    for i in range(0, args.steps, 10):
        print(f"{i:5d} {sgd_losses[i]:8.4f} {esgd_losses[i]:9.4f}")
    print(f"final {sgd_losses[-1]:8.4f} {esgd_losses[-1]:9.4f}")
    syncs_sgd = args.steps
    syncs_esgd = args.steps // args.interval
    print(f"\ncross-client syncs: mpi_sgd={syncs_sgd} "
          f"mpi_esgd={syncs_esgd} ({syncs_sgd//syncs_esgd}x fewer)")


if __name__ == "__main__":
    main()
