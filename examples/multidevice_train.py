"""Execute (not just lower) the production sharded train step on a real
multi-device mesh: 8 host CPU devices as (pod=2, data=2, model=2) — a
miniature of the two-pod production layout. Runs mpi-ESGD: two clients
with their own replicas, elastic exchange across 'pod' every 4 steps.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/multidevice_train.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, reduced
from repro.core.compat import make_mesh, set_mesh
from repro.core.hierarchy import SyncConfig, declientize
from repro.data import DataConfig, TokenPipeline
from repro.launch.train import (
    clientize_batch_specs,
    make_train_state,
    make_train_step,
    state_specs,
)
from repro.models import build_model
from repro.optim import sgd
from repro.sharding.rules import param_specs


def main() -> None:
    assert len(jax.devices()) >= 8, "needs 8 host devices (set XLA_FLAGS)"
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    optimizer = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_esgd", num_clients=2, esgd_alpha=0.5,
                      esgd_interval=4)
    sync.validate(mesh)

    # same mesh for both factories: the GSPMD path keeps per-leaf layouts
    state = make_train_state(model, optimizer, sync, jax.random.key(0),
                             mesh=mesh)
    sspecs = state_specs(state, mesh, sync)
    sh = lambda specs: jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                    is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh(sspecs))
    step = jax.jit(make_train_step(model, optimizer, sync, mesh),
                   out_shardings=(sh(sspecs), None))

    pipes = [TokenPipeline(DataConfig(seed=0, vocab_size=256, seq_len=64,
                                      batch_size=4, shard=c))
             for c in range(2)]
    bspec = NamedSharding(mesh, P(("pod",), ("data",), None))
    with set_mesh(mesh):
        for i in range(12):
            batches = [p.batch_at(0, i) for p in pipes]
            batch = jax.tree.map(
                lambda *xs: jax.device_put(jnp.stack(xs), bspec), *batches)
            state, metrics = step(state, batch)
            spread = max(jax.tree_util.tree_leaves(jax.tree.map(
                lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))),
                state["params"])))
            sync_mark = " <- elastic exchange" if i % 4 == 0 else ""
            print(f"step {i:2d} loss {float(metrics['loss']):.4f} "
                  f"replica spread {spread:.4f}{sync_mark}")

    final = declientize(state["params"], 2)
    n = sum(l.size for l in jax.tree_util.tree_leaves(final))
    print(f"consensus model: {n:,} params, all shards on "
          f"{len(jax.devices())} devices executed SPMD")


if __name__ == "__main__":
    main()
