"""The paper end-to-end: all six parallel-SGD modes (dist/mpi x
SGD/ASGD/ESGD) training the paper's model family (a compact ResNet) on
synthetic ImageNet-like data, through the real KVStore-MPI API, with
simulated cluster timing — reproducing the shape of Figs. 11/13.

  PYTHONPATH=src python examples/hybrid_ps_mpi.py [--epochs 3]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.resnet50_cifar import ResNetConfig
from repro.core.algorithms import MODES, AlgoConfig, run
from repro.data import DataConfig, ImagePipeline
from repro.models.resnet import init_resnet, resnet_apply, resnet_loss


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--clients", type=int, default=2)
    args = ap.parse_args()

    rcfg = ResNetConfig(stage_sizes=(1, 1), width=8, image_size=8)
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: resnet_loss(p, b, rcfg)[0]))

    test_pipe = ImagePipeline(
        DataConfig(seed=0, batch_size=256, steps_per_epoch=1, shard=999),
        image_size=8)
    test_batch = test_pipe.batch_at(99, 0)

    def eval_fn(params):
        logits = resnet_apply(params, test_batch["images"], rcfg)
        return float(jnp.mean(
            (jnp.argmax(logits, -1) == test_batch["labels"]).astype(jnp.float32)))

    def make_pipe(w):
        return ImagePipeline(
            DataConfig(seed=0, batch_size=8, steps_per_epoch=10, shard=w),
            image_size=8)

    print(f"{'mode':10s} {'final_acc':>9s} {'epoch_time':>10s} {'staleness':>9s}")
    for mode in MODES:
        cfg = AlgoConfig(
            mode=mode, num_workers=args.workers, num_clients=args.clients,
            num_servers=1, lr=0.1, momentum=0.9, epochs=args.epochs,
            steps_per_epoch=10, esgd_interval=4, compute_time=0.45,
            jitter=0.2, model_bytes=1e8)
        h = run(cfg, lambda k: init_resnet(k, rcfg), grad_fn, eval_fn,
                make_pipe)
        print(f"{mode:10s} {h.metrics[-1]:9.3f} {h.epoch_time:9.1f}s "
              f"{h.mean_staleness:9.2f}")


if __name__ == "__main__":
    main()
