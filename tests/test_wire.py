"""Low-precision wire protocol: the WIRE_BLOCK codec (kernel vs jnp vs
oracle, pad/all-zero edge cases), quantized ring collectives vs an exact
hop-by-hop dequant-oracle, wire policy plumbing (Communicator / SyncConfig
/ KVStore / AlgoConfig guards and deprecations), low-precision optimizer
state streams, and the train-step equivalence + convergence windows."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives as C
from repro.core import flatbuf as F
from repro.core.comm import Communicator
from repro.core.hierarchy import SyncConfig
from repro.kernels.quant_bucket.quant_bucket import (
    WIRE_BLOCK,
    dequantize_wire,
    quantize_wire,
    wire_decode,
    wire_encode,
    wire_nbytes,
)
from repro.kernels.quant_bucket.ref import wire_decode_ref, wire_encode_ref

AXIS = "ring"


def _roundtrip(x, wire):
    """The hop codec applied to one chunk (what the receiver sees)."""
    if wire == "bf16":
        return np.asarray(x, np.float32).astype(jnp.bfloat16).astype(
            np.float32)
    codes, scales = wire_encode(jnp.asarray(x))
    return np.asarray(wire_decode(codes, scales, x.shape[0]))


# --------------------------------------------------------------------------
# the WIRE_BLOCK codec
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, WIRE_BLOCK, WIRE_BLOCK + 17,
                               5 * WIRE_BLOCK, 64 * WIRE_BLOCK + 3])
def test_wire_codec_kernel_matches_jnp_and_ref(n):
    x = jax.random.normal(jax.random.key(0), (n,)) * 2.5
    cj, sj = wire_encode(x)
    cr, sr = wire_encode_ref(x)
    np.testing.assert_array_equal(np.asarray(cj), np.asarray(cr))
    np.testing.assert_allclose(sj, sr, rtol=1e-7)
    ck, sk = quantize_wire(x)
    # kernel pads to whole tiles; the shared buckets must match exactly
    np.testing.assert_array_equal(np.asarray(ck)[:cj.shape[0]],
                                  np.asarray(cj))
    np.testing.assert_allclose(sk[:sj.shape[0]], sj, rtol=1e-6)
    back_j = wire_decode(cj, sj, n)
    back_r = wire_decode_ref(cr, sr, n)
    back_k = dequantize_wire(ck, sk, n)
    np.testing.assert_allclose(back_j, back_r, rtol=1e-7)
    # the Pallas pair may differ by one ulp of the scale (interpret-mode
    # reduction ordering), never more
    np.testing.assert_allclose(back_k, back_j, rtol=1e-6, atol=1e-7)
    # error bound: one quantization step of the bucket absmax
    pad = (-n) % WIRE_BLOCK
    xp = np.pad(np.asarray(x), (0, pad)).reshape(-1, WIRE_BLOCK)
    bound = np.abs(xp).max(axis=1) / 127.0
    err = np.pad(np.abs(np.asarray(back_j) - np.asarray(x)),
                 (0, pad)).reshape(-1, WIRE_BLOCK)
    assert (err <= bound[:, None] * 0.51 + 1e-9).all()


def test_wire_codec_pad_does_not_poison_scales():
    """Bucket padding is zeros: a partial final bucket's scale must come
    from the real values only (zeros never raise an absmax)."""
    n = WIRE_BLOCK + 7  # final bucket: 7 real values + 121 pad zeros
    x = jnp.concatenate([jnp.ones((WIRE_BLOCK,)) * 3.0,
                         jnp.ones((7,)) * 0.5])
    _, scales = wire_encode(x)
    np.testing.assert_allclose(scales, [3.0 / 127.0, 0.5 / 127.0],
                               rtol=1e-6)
    back = wire_decode(*wire_encode(x), n)
    np.testing.assert_allclose(back, x, rtol=1e-6)


def test_wire_codec_all_zero_bucket_decodes_to_zero():
    """The max(absmax, 1e-12) guard: an all-zero bucket must not divide
    by zero and must decode to exactly 0.0."""
    x = jnp.concatenate([jnp.zeros((WIRE_BLOCK,)),
                         jnp.ones((WIRE_BLOCK,))])
    codes, scales = wire_encode(x)
    assert np.isfinite(np.asarray(scales)).all()
    back = wire_decode(codes, scales, x.shape[0])
    np.testing.assert_array_equal(np.asarray(back[:WIRE_BLOCK]),
                                  np.zeros(WIRE_BLOCK))
    # the Pallas kernel hits the same guard
    back_k = dequantize_wire(*quantize_wire(x), x.shape[0])
    np.testing.assert_array_equal(np.asarray(back_k[:WIRE_BLOCK]),
                                  np.zeros(WIRE_BLOCK))


def test_wire_codec_bf16_input():
    x = (jax.random.normal(jax.random.key(3), (300,)) * 4).astype(
        jnp.bfloat16)
    back = wire_decode(*wire_encode(x), 300)
    assert back.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back),
                               np.asarray(x, np.float32),
                               atol=4 * 4 / 127.0)


def test_wire_nbytes():
    assert wire_nbytes(WIRE_BLOCK) == WIRE_BLOCK + 4
    assert wire_nbytes(WIRE_BLOCK + 1) == WIRE_BLOCK + 1 + 8
    # the geometry-exact ratio the benches gate: (1 + 4/128)/4
    assert wire_nbytes(1 << 20) / (4 << 20) == pytest.approx(0.2578125)


# --------------------------------------------------------------------------
# quantized ring reduce-scatter == the hop-by-hop dequant-oracle, exactly
# --------------------------------------------------------------------------

def _oracle_reduce_scatter(x, nr, wire):
    """Sequential simulation of ``ring_reduce_scatter``'s exact schedule
    with the hop codec applied where the wire is: the reference the
    quantized collective must match BIT-FOR-BIT (same jnp ops in the
    same order)."""
    p, n = x.shape
    chunk = -(-n // (p * nr))
    flat = np.pad(np.asarray(x, np.float32), ((0, 0), (0, chunk * p * nr - n)))
    bufs = flat.reshape(p, nr, p, chunk)
    acc = [[None] * nr for _ in range(p)]
    for s in range(p - 1):
        for r in range(nr):
            sends = []
            for d in range(p):
                send = bufs[d][r][(d - s - 1) % p] if s == 0 else acc[d][r]
                sends.append(_roundtrip(send, wire) if wire else send)
            new = []
            for d in range(p):
                recv = sends[(d - 1) % p]
                local = bufs[d][r][(d - s - 2) % p]
                new.append(np.asarray(jnp.asarray(local) + jnp.asarray(recv)))
            for d in range(p):
                acc[d][r] = new[d]
    if nr == 1:
        return np.stack([acc[d][0] for d in range(p)])
    return np.stack([np.stack(acc[d]).reshape(-1) for d in range(p)])


@pytest.mark.parametrize("wire", ["int8", "bf16"])
@pytest.mark.parametrize("p,nr", [(2, 1), (8, 1), (8, 3), (2, 2)])
def test_quantized_reduce_scatter_matches_dequant_oracle(p, nr, wire):
    n = 999  # odd on purpose: pad must ride the rings without poisoning
    x = jax.random.normal(jax.random.key(4), (p, n)) * 3
    got = C.emulate(C.ring_reduce_scatter, x, num_rings=nr, wire_dtype=wire)
    want = _oracle_reduce_scatter(x, nr, wire)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("wire", ["int8", "bf16"])
@pytest.mark.parametrize("p,nr", [(2, 1), (8, 1), (8, 3)])
def test_quantized_allgather_is_allgather_of_roundtrip(p, nr, wire):
    """Gathering moves values without reducing them, so the quantized
    allgather must equal the f32 allgather of codec-roundtripped shards
    EXACTLY — including each device's own shard (the replica-identity
    property)."""
    chunk = 128
    shards = jax.random.normal(jax.random.key(5), (p, nr * chunk)) * 2
    got = C.emulate(C.ring_allgather, shards, num_rings=nr, wire_dtype=wire)
    rt = jnp.stack([
        jnp.asarray(np.concatenate([
            _roundtrip(np.asarray(shards[d]).reshape(nr, chunk)[r], wire)
            for r in range(nr)]))
        for d in range(p)])
    want = C.emulate(C.ring_allgather, rt, num_rings=nr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every device reconstructs the identical buffer
    assert (np.asarray(got) == np.asarray(got)[0][None]).all()


@pytest.mark.parametrize("wire", ["int8", "bf16"])
def test_quantized_rs_ag_roundtrip_accuracy(wire):
    """End-to-end RS + AG: sum within the codec's error envelope (p hops
    of one-quant-step error each), replicas identical."""
    p, n = 8, 1000
    x = jax.random.normal(jax.random.key(6), (p, n))
    rs = C.emulate(C.ring_reduce_scatter, x, wire_dtype=wire)
    ag = C.emulate(C.ring_allgather, rs, wire_dtype=wire)
    want = np.asarray(jnp.sum(x, 0))
    tol = 0.2 if wire == "int8" else 0.1
    np.testing.assert_allclose(np.asarray(ag)[0][:n], want, atol=tol)
    assert (np.asarray(ag) == np.asarray(ag)[0][None]).all()


def test_hierarchical_two_axis_quantized_allreduce():
    """Multi-axis groups quantize per level; the result stays within the
    compounded codec error of the true sum and is replica-identical."""
    P, D, n = 2, 4, 600
    x = jax.random.normal(jax.random.key(7), (P, D, n))
    comm = Communicator.world(("pod", "data"), (P, D), method="ring",
                              wire_dtype="int8")
    fn = jax.vmap(jax.vmap(comm.allreduce, axis_name="data"),
                  axis_name="pod")
    out = np.asarray(fn(x))
    want = np.asarray(jnp.sum(x, (0, 1)))
    np.testing.assert_allclose(out.reshape(P * D, n)[0], want, atol=0.2)
    assert (out.reshape(P * D, n) == out.reshape(P * D, n)[0][None]).all()


def test_unknown_wire_dtype_raises():
    x = jnp.zeros((4, 64))
    with pytest.raises(ValueError, match="wire_dtype"):
        C.emulate(C.ring_reduce_scatter, x, wire_dtype="fp8")
    with pytest.raises(ValueError, match="wire_dtype"):
        SyncConfig(wire_dtype="fp4", allreduce_method="ring").validate()


# --------------------------------------------------------------------------
# policy plumbing: guards, validate, deprecations
# --------------------------------------------------------------------------

def test_explicit_wire_knob_alongside_comm_raises():
    from repro.core.elastic import elastic_exchange_sharded
    from repro.optim.sgd import scatter_update_gather, sgd

    tree = {"w": jnp.ones((40,))}
    spec = F.spec_for(tree)
    comm = Communicator.world((AXIS,), (2,), wire_dtype="int8")
    with pytest.raises(ValueError, match="wire"):
        scatter_update_gather(spec, tree, tree, jnp.zeros((spec.size,)),
                              0.1, 0.9, comm=comm, wire_dtype="int8")
    with pytest.raises(ValueError, match="wire"):
        elastic_exchange_sharded(spec, tree, tree, 0.5, comm=comm,
                                 wire_dtype="bf16")
    with pytest.raises(ValueError, match="policy"):
        C.tensor_allreduce(tree, comm, wire_dtype="int8")
    with pytest.raises(ValueError, match="policy"):
        C.tensor_pushpull(tree, comm, wire_dtype="int8")


def test_wire_requires_ring_family_method():
    with pytest.raises(ValueError, match="ring"):
        SyncConfig(wire_dtype="int8").validate()  # default psum
    SyncConfig(wire_dtype="int8", allreduce_method="ring").validate()
    SyncConfig(wire_dtype="bf16",
               allreduce_method="multi_ring").validate()
    # a psum/tree group refuses to silently drop the codec
    comm = Communicator.world((AXIS,), (4,), method="psum",
                              wire_dtype="int8")
    with pytest.raises(ValueError, match="wire_dtype"):
        C.emulate(lambda v, a: comm.allreduce(v),
                  jnp.ones((4, 8)))
    tree_comm = Communicator.world((AXIS,), (4,), method="tree",
                                   wire_dtype="bf16")
    with pytest.raises(ValueError, match="wire_dtype"):
        C.emulate(lambda v, a: tree_comm.allreduce(v), jnp.ones((4, 8)))


def test_wire_policy_inherited_through_split():
    w = Communicator.world(("pod", "data"), (2, 4), method="ring",
                           wire_dtype="int8")
    assert w.split("data").wire_dtype == "int8"
    assert w.complement("pod").wire_dtype == "int8"
    assert w.local().wire_dtype == "int8"
    assert w.with_policy(wire_dtype=None).wire_dtype is None


def test_kvstore_compress_push_removed():
    from repro.core.kvstore import KVStore

    n = 4 * WIRE_BLOCK
    with pytest.raises(ValueError, match="compress_push.*int8"):
        KVStore.create("dist_async", num_workers=1, compress_push=True)
    kv = KVStore.create("dist_async", num_workers=1, wire_dtype="int8")
    assert kv.wire_dtype == "int8"
    assert not hasattr(kv, "compress_push")  # the alias property is gone
    kv.init("w", jnp.zeros((n,), jnp.float32))
    kv.set_elastic(0.5)
    kv.push("w", jnp.full((n,), 2.0, jnp.float32))
    assert kv.pushed_bytes == wire_nbytes(n)


def test_kvstore_bf16_wire():
    from repro.core.kvstore import KVStore

    kv = KVStore.create("dist_async", num_workers=1, wire_dtype="bf16")
    kv.init("w", jnp.zeros((256,), jnp.float32))
    kv.set_elastic(1.0)  # center <- pushed (roundtripped) value
    x = jax.random.normal(jax.random.key(8), (256,))
    kv.push("w", x)
    assert kv.pushed_bytes == 256 * 2
    np.testing.assert_array_equal(
        np.asarray(kv.value("w")),
        np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32)))


def test_algo_config_compress_push_removed():
    from repro.core.algorithms import AlgoConfig, _worker_group

    with pytest.raises(ValueError, match="compress_push.*int8"):
        AlgoConfig(mode="mpi_esgd", compress_push=True)
    assert AlgoConfig(mode="mpi_sgd").effective_wire_dtype is None
    with pytest.warns(DeprecationWarning, match="policy"):
        full = AlgoConfig(mode="mpi_sgd", wire_dtype="bf16")
    # ONE knob: the PS leg and the collective hops share the wire dtype
    assert full.effective_wire_dtype == "bf16"
    assert full.collective_wire_dtype == "bf16"
    assert full.policy.wire_dtype == "bf16"
    assert _worker_group(full).wire_dtype == "bf16"
    x = jnp.ones((2, 8))
    np.testing.assert_allclose(
        np.asarray(_worker_group(full).emulate_reduce(x)),
        np.full((2, 8), 2.0), rtol=1e-2)


def test_train_settings_and_jobspec_thread_wire_dtype():
    from repro.configs.base import TrainSettings
    from repro.launch.launcher import JobSpec, build_job

    s = TrainSettings(wire_dtype="int8", allreduce_method="ring",
                      state_dtype="bf16", optimizer_name="adamw")
    sync = s.sync_config()
    assert sync.wire_dtype == "int8"
    sync.validate()
    opt = s.optimizer()
    assert opt.hyper["state_dtype"] == jnp.bfloat16
    # "f32" normalizes to None (one spelling below the config layer)
    assert TrainSettings().sync_config().wire_dtype is None

    spec = JobSpec(num_workers=4, num_servers=1, num_clients=2,
                   arch="qwen2-0.5b", shape="train_4k", wire_dtype="int8",
                   state_dtype="bf16")
    job = build_job(spec)
    assert job["sync"]["wire_dtype"] == "int8"
    assert job["sync"]["state_dtype"] == "bf16"
    assert "--wire-dtype int8" in job["clients"][0]["launch_cmd"]
    assert "--state-dtype bf16" in job["clients"][0]["launch_cmd"]
    # f32 stays off the command line (the default needs no flag)
    job_f32 = build_job(JobSpec(num_workers=4, num_servers=1,
                                num_clients=2, arch="qwen2-0.5b",
                                shape="train_4k"))
    assert "--wire-dtype" not in job_f32["clients"][0]["launch_cmd"]
    assert "--state-dtype" not in job_f32["clients"][0]["launch_cmd"]
    with pytest.raises(ValueError, match="wire_dtype"):
        JobSpec(num_workers=4, num_servers=1, num_clients=2,
                arch="qwen2-0.5b", shape="train_4k",
                wire_dtype="fp8").validate()
    with pytest.raises(ValueError, match="state_dtype"):
        JobSpec(num_workers=4, num_servers=1, num_clients=2,
                arch="qwen2-0.5b", shape="train_4k",
                state_dtype="fp8").validate()


# --------------------------------------------------------------------------
# low-precision optimizer state streams
# --------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.split(jax.random.key(seed), 3)
    return {"w": jax.random.normal(k[0], (13, 7)),
            "b": jax.random.normal(k[1], (5,)),
            "deep": {"u": jax.random.normal(k[2], (3, 11, 2))}}


def test_optstate_shard_init_declares_stream_dtypes():
    from repro.optim.sgd import adagrad, adamw, optstate_shard_init

    spec = F.spec_for(_tree())
    st = optstate_shard_init(adamw(0.01, state_dtype=jnp.bfloat16).hyper,
                             spec, 2)
    assert st["mv"].dtype == jnp.bfloat16 and st["t"].dtype == jnp.int32
    st32 = optstate_shard_init(adamw(0.01).hyper, spec, 2)
    assert st32["mv"].dtype == jnp.float32
    assert st["mv"].nbytes * 2 == st32["mv"].nbytes
    acc = optstate_shard_init(adagrad(0.01, state_dtype=jnp.bfloat16).hyper,
                              spec, 2)
    assert acc.dtype == jnp.bfloat16
    # explicit override beats the hyper's declaration
    o = optstate_shard_init(adamw(0.01).hyper, spec, 2,
                            state_dtypes=jnp.bfloat16)
    assert o["mv"].dtype == jnp.bfloat16


@pytest.mark.parametrize("p", [1, 2, 8])
@pytest.mark.parametrize("family", ["adamw", "adagrad"])
def test_fused_bf16_state_streams_match_f32_within_eps(p, family):
    """The acceptance bound: bf16 m/v (or accumulator) streams track the
    f32-state run within test eps — the streams only round at the store,
    compute stays f32 inside the kernel."""
    from repro.optim.sgd import (
        adagrad,
        adamw,
        optstate_shard_init,
        scatter_update_gather,
    )

    params = _tree(1)
    spec = F.spec_for(params)
    make = adamw if family == "adamw" else adagrad
    h32 = make(0.01).hyper
    h16 = make(0.01, state_dtype=jnp.bfloat16).hyper
    comm = Communicator.world((AXIS,), (p,), method="ring")
    steps = 4
    k = jax.random.key(42)
    grads = jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.fold_in(k, x.size), (steps, p) + x.shape),
        params)

    def run(hyper):
        st = optstate_shard_init(hyper, spec, p)
        stacked_p = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), params)
        stacked_s = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), st)

        def dev(g, pp, s):
            return scatter_update_gather(spec, g, pp, s, hyper=hyper,
                                         comm=comm)

        step = jax.vmap(dev, axis_name=AXIS)
        for s in range(steps):
            g = jax.tree.map(lambda x: x[s], grads)
            stacked_p, stacked_s = step(g, stacked_p, stacked_s)
        return stacked_p

    p32, p16 = run(h32), run(h16)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-3),
        p32, p16)


def test_sgd_bf16_momentum_stays_bf16():
    """Per-leaf sgd must hold the declared state dtype across updates
    (f32 arithmetic, rounded store — not a silent f32 promotion that
    voids the bytes saving and retraces jitted steps)."""
    from repro.optim.sgd import sgd

    opt = sgd(0.1, momentum=0.9, state_dtype=jnp.bfloat16)
    params = _tree(7)
    st = opt.init(params)
    for s in range(2):
        g = jax.tree.map(lambda x: jnp.ones_like(x) * (s + 1), params)
        params, st = opt.update(g, st, params)
        assert all(l.dtype == jnp.bfloat16
                   for l in jax.tree_util.tree_leaves(st))


def test_elastic_exchange_packed_compress_removed():
    from repro.core.elastic import elastic_exchange_packed

    w, c = _tree(5), _tree(6)
    with pytest.raises(ValueError, match="compress=True.*int8"):
        elastic_exchange_packed(w, c, 0.4, compress=True)
    # the one spelling that remains
    new_w, new_c = elastic_exchange_packed(w, c, 0.4, wire_dtype="int8")
    assert jax.tree_util.tree_structure(new_w) == \
        jax.tree_util.tree_structure(w)


def test_per_leaf_bf16_state_matches_flat_bf16_state():
    """Per-leaf adamw with bf16 state mirrors the kernel's f32-compute /
    bf16-store contract, so the two substrates agree leaf-for-leaf."""
    from repro.optim.sgd import adamw, flat_adamw

    params = _tree(2)
    spec = F.spec_for(params)
    leaf_opt = adamw(0.02, state_dtype=jnp.bfloat16)
    flat_opt = flat_adamw(0.02, spec, state_dtype=jnp.bfloat16)
    sl, sf = leaf_opt.init(params), flat_opt.init(params)
    assert sf["mv"].dtype == jnp.bfloat16
    pl_, pf = params, params
    k = jax.random.key(9)
    for s in range(3):
        g = jax.tree.map(
            lambda x: jax.random.normal(
                jax.random.fold_in(jax.random.fold_in(k, s), x.size),
                x.shape), params)
        pl_, sl = leaf_opt.update(g, sl, pl_)
        pf, sf = flat_opt.update(g, sf, pf)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6),
        pl_, pf)


# --------------------------------------------------------------------------
# full-path equivalence + structural checks
# --------------------------------------------------------------------------

def test_quantized_step_adds_zero_pallas_launches():
    """Structural acceptance: quantize/dequant live inside the jitted
    step as fused jnp — the per-device program has exactly the ONE fused
    update launch regardless of wire dtype; the hop-free packed wire
    (KVStore push) is exactly one quant/dequant Pallas pair."""
    from benchmarks.common import jaxpr_primitives
    from repro.core.elastic import wire_packed
    from repro.optim.sgd import optstate_shard_init, scatter_update_gather

    params = _tree(3)
    spec = F.spec_for(params)
    grads = jax.tree.map(jnp.ones_like, params)
    counts = {}
    for wire in (None, "bf16", "int8"):
        comm = Communicator.world((AXIS,), (8,), method="ring",
                                  wire_dtype=wire)
        st = optstate_shard_init({"name": "sgd", "lr": 0.1,
                                  "momentum": 0.9}, spec, 8)

        def dev(g, pp, s, _c=comm):
            return scatter_update_gather(spec, g, pp, s, 0.1, 0.9, comm=_c)

        prims = [n for n, _ in jaxpr_primitives(dev, grads, params, st,
                                                axis=AXIS, p=8)]
        counts[wire] = prims.count("pallas_call")
    assert counts == {None: 1, "bf16": 1, "int8": 1}

    prims = [n for n, _ in jaxpr_primitives(
        lambda t: wire_packed(t, "int8"), params)]
    assert prims.count("pallas_call") == 2  # one quantize + one dequantize


@pytest.mark.parametrize("p", [2, 8])
def test_sharded_exchange_with_quantized_wire(p):
    """The elastic leg under the wire protocol: centers stay identical
    across devices and land within the codec envelope of the exact
    exchange."""
    from repro.core.elastic import elastic_exchange_sharded

    tree = _tree(4)
    spec = F.spec_for(tree)
    center = jax.tree.map(lambda l: l * 0.5, tree)
    stacked_w = jax.tree.map(
        lambda l: jnp.stack([l * (1 + 0.1 * i) for i in range(p)]), tree)
    stacked_c = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (p,) + l.shape), center)
    alpha = 0.5 / p
    out = {}
    for wire in (None, "int8"):
        comm = Communicator.world(("pod",), (p,), method="ring",
                                  wire_dtype=wire)

        def dev(w, c, _c=comm):
            return elastic_exchange_sharded(spec, w, c, alpha, comm=_c)

        out[wire] = jax.vmap(dev, axis_name="pod")(stacked_w, stacked_c)
    for leaf_q, leaf_f in zip(jax.tree_util.tree_leaves(out["int8"][1]),
                              jax.tree_util.tree_leaves(out[None][1])):
        # replicated center identical on every device
        assert (np.asarray(leaf_q) == np.asarray(leaf_q)[0][None]).all()
        np.testing.assert_allclose(np.asarray(leaf_q),
                                   np.asarray(leaf_f), atol=0.1)


def _driver_losses(sync, p, steps, model, batch):
    from repro.launch.shard_driver import (
        make_driver_state,
        make_emulated_step,
        shard_batch,
    )
    from repro.optim.sgd import sgd

    opt = sgd(0.1, momentum=0.9)
    st = make_driver_state(model, opt, sync, p, jax.random.key(1))
    step = jax.jit(make_emulated_step(model, opt, sync, p))
    losses = []
    for _ in range(steps):
        st, m = step(st, shard_batch(batch, p))
        losses.append(float(m["loss"]))
    return losses


@pytest.mark.slow
def test_bf16_wire_train_step_matches_f32_within_bf16_tol():
    from repro.configs.base import get_config, reduced
    from repro.models.model import build_model

    model = build_model(reduced(get_config("qwen2-0.5b")))
    k = jax.random.key(0)
    toks = jax.random.randint(k, (8, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    p, steps = 4, 3
    base = SyncConfig(mode="mpi_sgd", allreduce_method="ring")
    bf16 = SyncConfig(mode="mpi_sgd", allreduce_method="ring",
                      wire_dtype="bf16")
    lf = _driver_losses(base, p, steps, model, batch)
    lb = _driver_losses(bf16, p, steps, model, batch)
    np.testing.assert_allclose(lb, lf, rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_int8_wire_convergence_window():
    """The documented loss window: int8 wire training tracks f32 within
    5% relative loss on the LM smoke (README's accuracy-vs-bytes note;
    the real-accuracy number comes from bench_convergence
    ``--wire-dtype int8``: Δacc within ±0.01 of f32)."""
    from repro.configs.base import get_config, reduced
    from repro.models.model import build_model

    model = build_model(reduced(get_config("qwen2-0.5b")))
    k = jax.random.key(0)
    toks = jax.random.randint(k, (8, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    p, steps = 4, 6
    base = SyncConfig(mode="mpi_sgd", allreduce_method="ring")
    q = SyncConfig(mode="mpi_sgd", allreduce_method="ring",
                   wire_dtype="int8")
    lf = _driver_losses(base, p, steps, model, batch)
    lq = _driver_losses(q, p, steps, model, batch)
    assert abs(lq[-1] - lf[-1]) / lf[-1] <= 0.05
    assert lq[-1] < lq[0]  # it still learns


def test_wire_cost_model_matches_measured_bytes():
    """cost_model's per-leg accounting == the jaxpr-measured ppermute
    bytes (the launch/analysis predictions and BENCH_wire.json agree by
    construction)."""
    from benchmarks.common import ppermute_bytes
    from repro.core import cost_model

    tree = {f"l{i}": jnp.zeros((640,)) for i in range(4)}
    spec = F.spec_for(tree)
    buf = spec.pack(tree)
    p = 8
    for wire in (None, "bf16", "int8"):
        comm = Communicator.world((AXIS,), (p,), method="ring",
                                  wire_dtype=wire)
        measured = ppermute_bytes(lambda b: comm.reduce_scatter(b), buf,
                                  axis=AXIS, p=p)
        # measured operates on the padded total; predict on the same
        _, total = F.shard_geometry(spec.size, p, 1)
        want = cost_model.grad_leg_bytes(total * 4, p, wire)
        assert measured == want
    assert cost_model.elastic_leg_bytes(1000, 8, "int8") == \
        pytest.approx(2 * cost_model.grad_leg_bytes(1000, 8, "int8"))
    assert cost_model.ps_push_bytes(4096, "int8") == \
        pytest.approx(4096 * 0.2578125)
