"""Model-layer correctness: chunked attention vs O(S^2) oracle, MoE
dispatch vs dense oracle, SSD chunked vs recurrent oracle, decode parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.attention import (
    AttnSpec,
    decode_attention,
    init_attention,
    init_kv_cache,
    multi_head_attention,
    reference_attention,
)
from repro.models.moe import init_moe, moe_block, reference_moe


def _spec(**kw):
    base = dict(num_heads=4, num_kv_heads=2, head_dim=16)
    base.update(kw)
    return AttnSpec(**base)


@pytest.mark.parametrize("spec_kw, S", [
    ({}, 64),
    ({"num_kv_heads": 1}, 96),                      # MQA (paligemma)
    ({"qk_norm": True}, 64),                        # qwen3
    ({"qkv_bias": True}, 64),                       # qwen2
    ({"sliding_window": 24}, 96),                   # mixtral
    ({"prefix_len": 16}, 64),                       # paligemma prefix-LM
    ({"causal": False}, 48),                        # whisper encoder
])
def test_chunked_attention_matches_reference(spec_kw, S):
    spec = _spec(**spec_kw)
    key = jax.random.key(0)
    params = init_attention(key, 32, spec, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, 32)) * 0.5
    got = multi_head_attention(params, x, spec, q_chunk=16, kv_chunk=16)
    want = reference_attention(params, x, spec)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cross_attention_matches_reference():
    spec = _spec(causal=False, use_rope=False)
    params = init_attention(jax.random.key(1), 32, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 20, 32))
    enc = jax.random.normal(jax.random.key(3), (2, 50, 32))
    got = multi_head_attention(params, x, spec, x_kv=enc, q_chunk=8, kv_chunk=16)
    want = reference_attention(params, x, spec, x_kv=enc)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_decode_matches_full_attention():
    """Token-by-token decode with the KV cache == full causal attention."""
    spec = _spec()
    params = init_attention(jax.random.key(4), 32, spec, jnp.float32)
    S = 12
    x = jax.random.normal(jax.random.key(5), (2, S, 32)) * 0.5
    full = reference_attention(params, x, spec)
    cache = init_kv_cache(2, S, spec, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = decode_attention(params, x[:, t : t + 1], cache, spec)
        outs.append(o)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), full, rtol=2e-4, atol=2e-4)


def test_decode_sliding_window_rolling_buffer():
    spec = _spec(sliding_window=8)
    params = init_attention(jax.random.key(6), 32, spec, jnp.float32)
    S = 20
    x = jax.random.normal(jax.random.key(7), (1, S, 32)) * 0.5
    full = reference_attention(params, x, spec)
    cache = init_kv_cache(1, S, spec, jnp.float32)
    assert cache["k"].shape[1] == 8  # rolling buffer is window-sized
    outs = []
    for t in range(S):
        o, cache = decode_attention(params, x[:, t : t + 1], cache, spec)
        outs.append(o)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), full, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_oracle_with_ample_capacity():
    E, K, d, f = 8, 2, 16, 32
    params = init_moe(jax.random.key(8), d, E, 1, f, jnp.float32)
    x = jax.random.normal(jax.random.key(9), (2, 10, d))
    got, aux = moe_block(params, x, num_experts=E, top_k=K,
                         capacity_factor=8.0, aux_weight=0.0)
    want = reference_moe(params, x, num_experts=E, top_k=K)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert aux == 0.0


def test_moe_capacity_drops_tokens_not_correctness():
    E, K, d, f = 4, 2, 8, 16
    params = init_moe(jax.random.key(10), d, E, 0, f, jnp.float32)
    x = jax.random.normal(jax.random.key(11), (1, 16, d))
    out, _ = moe_block(params, x, num_experts=E, top_k=K,
                       capacity_factor=0.5, aux_weight=0.0)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))


def test_moe_aux_loss_balanced_router_lower_than_collapsed():
    E, d = 4, 8
    params = init_moe(jax.random.key(12), d, E, 0, 16, jnp.float32)
    x = jax.random.normal(jax.random.key(13), (2, 32, d))
    # collapsed router: force all mass to expert 0
    collapsed = dict(params)
    collapsed["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    _, aux_bal = moe_block(params, x, num_experts=E, top_k=1,
                           capacity_factor=4.0, aux_weight=1.0)
    _, aux_col = moe_block(collapsed, x, num_experts=E, top_k=1,
                           capacity_factor=4.0, aux_weight=1.0)
    assert float(aux_col) > float(aux_bal)


def test_moe_is_differentiable():
    E, K, d, f = 4, 2, 8, 16
    params = init_moe(jax.random.key(14), d, E, 0, f, jnp.float32)
    x = jax.random.normal(jax.random.key(15), (1, 8, d))

    def loss(p):
        out, aux = moe_block(p, x, num_experts=E, top_k=K,
                             capacity_factor=2.0, aux_weight=0.01)
        return jnp.sum(out ** 2) + aux

    grads = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert not bool(jnp.any(jnp.isnan(leaf)))
    assert float(jnp.sum(jnp.abs(grads["moe_gate"]))) > 0


# ---------------------------------------------------------------------------
# SSD / Mamba2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,chunk", [(32, 8), (33, 8), (16, 16), (40, 64)])
def test_ssd_chunked_matches_recurrent(L, chunk):
    B, H, P, N = 2, 3, 4, 8
    key = jax.random.key(16)
    x = jax.random.normal(key, (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, L, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, L, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, L, N)) * 0.5
    y_c, h_c = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_r, h_r = ssm.ssd_recurrent_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y_c, y_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(h_c, h_r, rtol=1e-3, atol=1e-3)


def test_ssd_initial_state_carrying():
    """Prefill-then-continue == one long sequence (state handoff)."""
    B, L, H, P, N = 1, 24, 2, 4, 8
    key = jax.random.key(17)
    x = jax.random.normal(key, (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, L, H)))
    A = -jnp.exp(jnp.zeros((H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, L, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, L, N)) * 0.5
    y_full, h_full = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    half = L // 2
    y1, h1 = ssm.ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                             Cm[:, :half], chunk=8)
    y2, h2 = ssm.ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                             Cm[:, half:], chunk=8, h0=h1)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(h2, h_full, rtol=1e-3, atol=1e-3)


def test_mamba_block_decode_matches_full():
    """mamba_block over a sequence == mamba_decode token-by-token."""
    d, B, L = 16, 1, 6
    kw = dict(expand=2, head_dim=8, state=8)
    params = ssm.init_mamba(jax.random.key(18), d, conv_width=4, dtype=jnp.float32, **kw)
    x = jax.random.normal(jax.random.key(19), (B, L, d)) * 0.5
    full, _ = ssm.mamba_block(params, x, chunk=4, **kw)
    h, conv = ssm.init_mamba_state(B, d, conv_width=4, dtype=jnp.float32, **kw)
    outs = []
    for t in range(L):
        o, (h, conv) = ssm.mamba_decode(params, x[:, t : t + 1], h, conv, **kw)
        outs.append(o)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), full, rtol=2e-3, atol=2e-3)
