"""Policy autotuner: the cost-model ranking must reproduce the measured
BENCH_*.json byte counts, every ``CollectivePolicy.validate()`` guard
must show up as a pruned candidate (not a crash), and the ONE policy
field must round-trip through every config layer — including the flat
deprecation shim."""
import dataclasses
import json
import pathlib

import pytest

from repro.core.comm import CollectivePolicy, resolve_policy
from repro.launch.autotune import (
    autotune,
    autotune_for_model,
    enumerate_policies,
    format_table,
    fused_step_compute_s,
    policy_bytes_per_step,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]

# the geometry every BENCH_*.json measures: 8 devices, the reduced
# qwen2-0.5b packed f32 gradient payload
P = 8
NBYTES = 1572864


def _bench(name):
    path = ROOT / name
    if not path.exists():
        pytest.skip(f"{name} not generated (run benchmarks/)")
    return json.loads(path.read_text())


# --------------------------------------------------------------------------
# predicted bytes/step == measured bytes/step, per wire dtype
# --------------------------------------------------------------------------

def test_predicted_bytes_match_measured_wire_bench():
    """The scorer's bytes/step for a plain ring must equal the traced
    per-device wire bytes in BENCH_wire.json for every wire dtype."""
    measured = _bench("BENCH_wire.json")["grad"]["full_step_bytes_per_dev"]
    for wire, want in measured.items():
        pol = CollectivePolicy(
            method="ring", wire_dtype=None if wire == "f32" else wire)
        assert policy_bytes_per_step(pol, NBYTES, P) == want


def test_autotune_chooses_measured_best_at_bench_geometry():
    """ISSUE acceptance: at the default bench geometry the chosen policy's
    modeled bytes/step equals the best measured bytes/step across
    BENCH_fused_step / BENCH_wire / BENCH_overlap."""
    wire = _bench("BENCH_wire.json")
    fused = _bench("BENCH_fused_step.json")
    measured = set(wire["grad"]["full_step_bytes_per_dev"].values())
    measured |= set(fused["wire_bytes_per_dev"].values())
    result = autotune(nbytes=NBYTES, p=P,
                      compute_s=fused_step_compute_s(NBYTES))
    assert result.chosen.bytes_per_step == min(measured)
    # and the winner is the int8 ring — the cheapest measured wire
    assert result.chosen.policy.method in ("ring", "multi_ring",
                                           "scatter_gather")
    assert result.chosen.policy.wire == "int8"


def test_ranking_orders_wire_dtypes_like_measurements():
    """Among plain single-ring candidates the predicted order must be
    int8 < bf16 < f32 — the measured ratio ordering in BENCH_wire."""
    result = autotune(nbytes=NBYTES, p=P)
    ring = [s for s in result.ranked
            if s.policy.method == "ring" and not s.policy.overlap
            and s.policy.bucket_bytes is None]
    wires = [s.policy.wire for s in ring]
    assert wires == ["int8", "bf16", None]


def test_overlap_wins_when_compute_hides_the_wire():
    """With abundant backward compute and a large payload the overlapped
    int8 ring must beat every non-overlapped candidate (the hidden
    fraction is free; the extra per-bucket launch latency is noise)."""
    result = autotune(nbytes=float(1 << 30), p=P, compute_s=1.0)
    assert result.chosen.policy.overlap
    assert result.chosen.policy.wire == "int8"
    assert result.chosen.policy.num_rings == 1
    assert result.chosen.overlap_fraction > 0.5


def test_autotune_for_model_picks_overlapped_int8_ring():
    """A real model config (compute-heavy) selects the overlapped int8
    ring, and its bytes/step still equals the measured-best wire ratio."""
    from repro.configs.base import get_config

    cfg = get_config("qwen3-4b")
    result = autotune_for_model(cfg, p=P, tokens_per_step=1 << 20)
    pol = result.chosen.policy
    assert pol.method == "ring" and pol.wire == "int8" and pol.overlap
    ratio = _bench("BENCH_wire.json")["grad"]["ratio_vs_f32"]["int8"]
    full_f32 = 2 * (P - 1) / P * result.nbytes
    assert result.chosen.bytes_per_step == pytest.approx(full_f32 * ratio)


# --------------------------------------------------------------------------
# pruning coverage: every validate() guard appears as a pruned candidate
# --------------------------------------------------------------------------

def test_every_guard_prunes_at_least_one_candidate():
    result = autotune(nbytes=NBYTES, p=P)
    reasons = [pr.reason for pr in result.pruned]
    for needle in (
        "rides the explicit ring hops",      # wire on psum/tree/per_leaf
        "overlap schedules per-bucket",      # overlap off the ring family
        "num_rings must be 1",               # overlap x multi_ring
        "bucket_bytes does not compose with overlap",
    ):
        assert any(needle in r for r in reasons), needle


def test_grid_partitions_into_ranked_plus_pruned():
    result = autotune(nbytes=NBYTES, p=P)
    grid = enumerate_policies()
    assert len(result.ranked) + len(result.pruned) == len(grid)
    assert len(result.ranked) > 0 and len(result.pruned) > 0
    # pruned candidates never appear in the ranking
    pruned = {pr.policy for pr in result.pruned}
    assert not pruned & {s.policy for s in result.ranked}
    # every survivor actually validates
    for s in result.ranked:
        s.policy.validate()


def test_format_table_lists_the_chosen_policy_first():
    result = autotune(nbytes=NBYTES, p=P,
                      compute_s=fused_step_compute_s(NBYTES))
    table = format_table(result, top=5)
    lines = table.splitlines()
    assert lines[0].startswith("| # | method")
    first = lines[2]
    assert f"| {result.chosen.policy.method} |" in first
    assert (result.chosen.policy.wire_dtype or "f32") in first


def test_autotune_rejects_degenerate_geometry():
    with pytest.raises(ValueError, match="p >= 1"):
        autotune(nbytes=NBYTES, p=0)
    with pytest.raises(ValueError, match="positive payload"):
        autotune(nbytes=0, p=P)


# --------------------------------------------------------------------------
# CollectivePolicy round-trip through every config layer
# --------------------------------------------------------------------------

POL = CollectivePolicy(method="ring", num_rings=1, wire_dtype="int8",
                       overlap=True, overlap_buckets=6)


def test_policy_round_trips_through_sync_config():
    from repro.core.hierarchy import SyncConfig

    sc = SyncConfig(mode="mpi_sgd", policy=POL)
    assert sc.policy == POL
    # mirrors derive from the one field
    assert sc.allreduce_method == "ring" and sc.wire_dtype == "int8"
    assert sc.overlap and sc.overlap_buckets == 6 and sc.num_rings == 1
    # replace() on a mirror re-resolves into a consistent policy (the
    # mirror write routes through the deprecation shim)
    with pytest.warns(DeprecationWarning, match="CollectivePolicy"):
        sc2 = dataclasses.replace(sc, overlap=False)
    assert sc2.policy == POL.replace(overlap=False)
    # the documented migration path is silent: stale mirrors restating
    # the previous policy must not override the new one
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        sc3 = dataclasses.replace(sc, policy=sc.policy.replace(overlap=False))
    assert sc3.policy == POL.replace(overlap=False)


def test_policy_round_trips_through_train_settings_to_jobspec():
    from repro.configs.base import TrainSettings
    from repro.launch.launcher import JobSpec

    ts = TrainSettings(policy=POL)
    assert ts.policy == POL
    assert ts.sync_config().policy == POL  # lowered as ONE field

    spec = JobSpec(8, 2, 2, "qwen3-4b", "train_4k", policy=POL)
    assert spec.policy == POL
    spec.validate()
    # the job dict ships the policy losslessly
    assert CollectivePolicy.from_dict(POL.to_dict()) == POL


def test_flat_kwargs_shim_warns_once_and_resolves():
    from repro.configs.base import TrainSettings

    with pytest.warns(DeprecationWarning, match="CollectivePolicy"):
        ts = TrainSettings(allreduce_method="ring", wire_dtype="int8")
    assert ts.policy == CollectivePolicy(method="ring", num_rings=2,
                                         wire_dtype="int8")
    # restating the resolved policy through the mirrors stays silent
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        resolve_policy(None, {"method": "ring", "wire_dtype": "int8"},
                       base=ts.policy)


def test_policy_dict_round_trip_rejects_unknown_fields():
    d = POL.to_dict()
    assert CollectivePolicy.from_dict(d) == POL
    d["rings"] = 3
    with pytest.raises(ValueError, match="unknown CollectivePolicy"):
        CollectivePolicy.from_dict(d)
