"""End-to-end behaviour: the paper's full workflow at laptop scale —
hybrid PS+MPI training through the KVStore API on a real model (the
paper's ResNet family), ESGD vs SGD, and the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet50_cifar import ResNetConfig
from repro.core.algorithms import AlgoConfig, run
from repro.data.pipeline import DataConfig, ImagePipeline, TokenPipeline
from repro.models.resnet import init_resnet, resnet_apply, resnet_loss

RCFG = ResNetConfig(stage_sizes=(1, 1), width=8, image_size=8)


def _init(key):
    return init_resnet(key, RCFG)


_grad = jax.jit(jax.value_and_grad(
    lambda p, b: resnet_loss(p, b, RCFG)[0]))

_test_pipe = ImagePipeline(
    DataConfig(seed=0, batch_size=128, steps_per_epoch=1, shard=999),
    image_size=8)
_test_batch = _test_pipe.batch_at(99, 0)


def _eval(params):
    logits = resnet_apply(params, _test_batch["images"], RCFG)
    return float(jnp.mean(
        (jnp.argmax(logits, -1) == _test_batch["labels"]).astype(jnp.float32)))


def _pipe(w):
    return ImagePipeline(
        DataConfig(seed=0, batch_size=8, steps_per_epoch=8, shard=w),
        image_size=8)


@pytest.mark.slow
def test_resnet_mpi_sgd_end_to_end():
    """The paper's core claim at smoke scale: hybrid MPI+PS sync SGD on a
    ResNet learns, and the epoch-time model favors MPI grouping."""
    cfg = AlgoConfig(mode="mpi_sgd", num_workers=4, num_clients=2,
                     num_servers=1, lr=0.1, momentum=0.9, epochs=3,
                     steps_per_epoch=8, compute_time=0.5, jitter=0.0,
                     model_bytes=1e8)
    h = run(cfg, _init, _grad, _eval, _pipe)
    # 10 classes, chance = 0.1; a tiny resnet after 24 steps must clear it
    assert h.metrics[-1] > 0.15
    assert h.metrics[-1] >= h.metrics[0]
    cfg_d = AlgoConfig(mode="dist_sgd", num_workers=4, num_clients=2,
                       num_servers=1, lr=0.1, momentum=0.9, epochs=1,
                       steps_per_epoch=8, compute_time=0.5, jitter=0.0,
                       model_bytes=1e8)
    h_d = run(cfg_d, _init, _grad, _eval, _pipe)
    assert h.epoch_time < h_d.epoch_time


@pytest.mark.slow
def test_esgd_beats_asgd_under_staleness():
    """Fig 13's qualitative claim: with slow/jittery workers, mpi-ESGD
    reaches a given accuracy no later than dist-ASGD in simulated time."""
    common = dict(num_workers=4, num_servers=1, lr=0.1, momentum=0.9,
                  epochs=4, steps_per_epoch=8, compute_time=0.5,
                  jitter=0.4, model_bytes=5e8, esgd_interval=4, seed=1)
    h_esgd = run(AlgoConfig(mode="mpi_esgd", num_clients=2, **common),
                 _init, _grad, _eval, _pipe)
    h_asgd = run(AlgoConfig(mode="dist_asgd", num_clients=4, **common),
                 _init, _grad, _eval, _pipe)

    def time_to(acc, h):
        for t, m in zip(h.times, h.metrics):
            if m >= acc:
                return t
        return float("inf")

    target = 0.3
    assert time_to(target, h_esgd) <= time_to(target, h_asgd)


def test_language_model_end_to_end_with_serving():
    """Train a reduced qwen2 on the synthetic bigram language, then serve
    it: generated continuations must score better than random under the
    automaton — the full train->checkpoint->serve loop."""
    from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
    from repro.configs.base import get_config, reduced
    from repro.core.hierarchy import SyncConfig
    from repro.launch.serve import BatchedServer
    from repro.launch.train import make_train_state, make_train_step
    from repro.models.model import build_model
    from repro.optim.sgd import sgd
    import tempfile, os

    model = build_model(reduced(get_config("qwen2-0.5b")))
    pipe = TokenPipeline(DataConfig(seed=0, vocab_size=256, seq_len=64,
                                    batch_size=8, steps_per_epoch=40))
    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    state = make_train_state(model, opt, sync, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt, sync, None))
    first = last = None
    for i, batch in enumerate(pipe.epoch(0)):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.5  # learned structure

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, state["params"], step=40)
        like = jax.tree.map(jnp.zeros_like, state["params"])
        params, _ = restore_checkpoint(path, like)

    srv = BatchedServer(model, params, batch=2, max_seq=48)
    prompts = pipe.batch_at(1, 0)["tokens"][:2, :8]
    out = srv.generate(prompts, steps=8)
    assert out.shape == (2, 8)
    assert not bool(jnp.any(out < 0))
