"""Launcher (LSF analogue), serve driver, and dry-run analysis units."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import group_workers, masters
from repro.launch import analysis
from repro.launch.launcher import JobSpec, build_job, emit_scripts
from repro.launch.serve import BatchedServer


def test_group_workers_namespaces():
    ids = group_workers(6, 2)
    assert [w.mpi.client for w in ids] == [0, 0, 0, 1, 1, 1]
    assert [w.mpi.rank for w in ids] == [0, 1, 2, 0, 1, 2]
    assert [w.ps.rank for w in ids] == list(range(6))
    assert len(masters(ids)) == 2


def test_job_spec_validation():
    with pytest.raises(ValueError):
        build_job(JobSpec(5, 2, 2, "a", "s"))
    with pytest.raises(ValueError):
        build_job(JobSpec(4, 0, 2, "a", "s"))  # pure MPI needs 1 client


def test_job_spec_pure_mpi_mode():
    job = build_job(JobSpec(4, 0, 1, "qwen3-4b", "train_4k"))
    assert job["mode"] == "pure_mpi"
    assert job["servers"] == []


def test_emit_scripts(tmp_path):
    spec = JobSpec(8, 2, 2, "qwen3-4b", "train_4k", "multipod")
    paths = emit_scripts(spec, str(tmp_path))
    names = {os.path.basename(p) for p in paths}
    assert {"job_spec.json", "client_0.sh", "client_1.sh",
            "launch_all.sh"} <= names
    job = json.load(open(tmp_path / "job_spec.json"))
    assert job["total_chips"] == 8 * 16
    assert "mpirun -np 4" in job["clients"][0]["launch_cmd"]
    assert os.access(tmp_path / "launch_all.sh", os.X_OK)


def test_emit_scripts_tcp_roundtrip(tmp_path):
    """Satellite: every emitted script parses back to the facts that
    produced it — parse_script is how run_local spawns scripts without
    re-deriving commands, so the round-trip must stay exact."""
    from repro.launch.launcher import parse_script

    spec = JobSpec(4, 2, 4, "qwen3-4b", "train_4k",
                   scheduler_host="127.0.0.1", scheduler_port=9191,
                   transport="tcp", mode="dist_sgd",
                   faults="kill@2:unit=1", barrier_timeout=1.5)
    paths = emit_scripts(spec, str(tmp_path))
    names = {os.path.basename(p) for p in paths}
    assert {"server_0.sh", "server_1.sh", "client_0.sh", "client_1.sh",
            "client_2.sh", "client_3.sh"} <= names
    scripts = [p for p in paths if p.endswith(".sh")
               and os.path.basename(p) != "launch_all.sh"]
    assert len(scripts) == 6
    for path in scripts:
        base = os.path.basename(path)
        # the rendezvous env triple appears EXACTLY once per script
        text = open(path).read()
        for var in ("REPRO_RDZV_ADDR", "REPRO_ROLE", "REPRO_RANK"):
            assert text.count(f"export {var}=") == 1, (base, var)
        got = parse_script(path)
        assert got["rdzv_addr"] == "127.0.0.1:9191"
        role, _, rank = base[:-len(".sh")].rpartition("_")
        assert got["role"] == {"server": "server", "client": "worker"}[role]
        assert got["rank"] == int(rank)
        if role == "server":
            assert got["flags"]["rank"] == rank
            assert got["flags"]["rendezvous"] == "127.0.0.1:9191"
            assert "repro.net.kvserver" in got["cmd"]
        else:
            assert "repro.launch.train" in got["cmd"]
            assert got["flags"]["transport"] == "tcp"
            assert got["flags"]["mode"] == "dist_sgd"
            assert got["flags"]["client"] == rank
            assert got["flags"]["faults"] == "kill@2:unit=1"
            assert got["flags"]["barrier-timeout"] == "1.5"


def test_job_spec_tcp_validation():
    # tcp requires a transport-capable mode and one process per worker
    with pytest.raises(ValueError, match="mode"):
        build_job(JobSpec(4, 2, 4, "a", "s", transport="tcp"))
    with pytest.raises(ValueError, match="num_clients"):
        build_job(JobSpec(4, 2, 2, "a", "s", transport="tcp",
                          mode="dist_sgd"))
    with pytest.raises(ValueError, match="transport"):
        build_job(JobSpec(4, 2, 2, "a", "s", transport="carrier-pigeon"))


@pytest.mark.slow
def test_worker_entry_point_runs_launcher_cmd():
    """The command shape build_job emits (python -m repro.launch.train
    --arch ... --fused-update --bucket-bytes N) is a real worker: it
    parses the flags, trains, and reports."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen2-0.5b", "--shape", "train_4k",
         "--client", "0", "--num-clients", "2",
         "--scheduler", "frontend-0:9091",
         "--fused-update", "--bucket-bytes", "1048576", "--steps", "4"],
        env=env, capture_output=True, text=True, timeout=500, cwd=root)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "fused_update=True" in r.stdout
    assert "bucket_bytes=1048576" in r.stdout
    assert "[train] done" in r.stdout


# --- HLO collective parsing ---------------------------------------------------

HLO_SNIPPET = """
ENTRY %main {
  %ar = f32[1024,16]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[2048]{0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1},{1,0}}
  %rs = f32[128]{0} reduce-scatter(%w), replica_groups={{0,1}}, to_apply=%add
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = analysis.parse_collectives(HLO_SNIPPET)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "collective-permute": 1, "reduce-scatter": 1}
    ar_bytes = 1024 * 16 * 4
    assert stats.operand_bytes["all-reduce"] == ar_bytes
    # wire: all-reduce = 2*(g-1)/g*n with g=4
    want = 2 * (3 / 4) * ar_bytes
    want += (7 / 8) * 2048 * 2        # all-gather, iota groups g=8
    want += 64 * 4                    # permute
    want += (2 - 1) * 128 * 4         # reduce-scatter g=2
    assert stats.wire_bytes == pytest.approx(want)


def test_roofline_dominant_term():
    r = analysis.Roofline(chips=4, hlo_flops=4e12, hlo_bytes=4e9,
                          wire_bytes=4e9, compute_s=1e-3, memory_s=5e-3,
                          collective_s=2e-3, model_flops=2e12)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_helpers():
    assert analysis.train_model_flops(10, 10, 100) == 6 * 10 * 100
    assert analysis.decode_model_flops(10, 8) == 2 * 10 * 8


# --- batched serving driver ----------------------------------------------------

def test_batched_server_generates():
    from repro.configs.base import get_config, reduced
    from repro.models.model import build_model

    model = build_model(reduced(get_config("qwen2-0.5b")))
    params = model.init(jax.random.key(0))
    srv = BatchedServer(model, params, batch=2, max_seq=32)
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = srv.generate(prompts, steps=4)
    assert out.shape == (2, 4)
    assert int(jnp.max(out)) < model.cfg.padded_vocab


def test_cache_specs_shardable_dims_only():
    from jax.sharding import PartitionSpec as P
    from repro.launch.serve import cache_specs

    class M:
        shape = {"data": 16, "model": 16}

    cache = {
        "k": jax.ShapeDtypeStruct((24, 128, 4096, 8, 64), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((24, 128, 4096, 8, 64), jnp.bfloat16),
        "index": jax.ShapeDtypeStruct((24,), jnp.int32),
        "h": jax.ShapeDtypeStruct((24, 1, 24, 64, 128), jnp.float32),
    }
    specs = cache_specs(cache, M())
    assert specs["k"][1] == "data"      # batch 128 % 16 == 0
    assert specs["index"] == P()
    # h: batch=1 not shardable; heads 24 not divisible; P=64 divisible
    assert specs["h"][3] == "model"
