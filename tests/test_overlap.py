"""Backward-overlapped bucketed reduce-scatter (SyncConfig.overlap).

The contract under test, in layers:

  * the staged chain-VJP produces BIT-IDENTICAL gradients to the
    monolithic ``value_and_grad`` (same ops, same order — including the
    tied-embedding carry and remat'd layers), so bucketing the wire leg
    never changes the math;
  * the full overlapped step equals the non-overlapped fused flat step
    across the whole p∈{1,2,8} × wire∈{f32,bf16,int8} matrix — bit-for-
    bit where the arithmetic forces it (p=1; f32 two-term folds at p=2),
    within the codec's rounding band elsewhere — and equals a trailing-
    bucketed same-schedule reference bit-for-bit at p=8 for EVERY wire
    dtype (isolating the staged VJP from fold-order/ownership effects);
  * the TRACED program realizes the overlap structurally: per-bucket
    ppermute chains sit before the last backward-compute eqn at the top
    level of the jaxpr, in exactly the fraction the cost model claims;
  * the guard rails reject every configuration the schedule cannot
    honor (non-ring methods, unfused path, explicit bucket knobs, ...).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainSettings, get_config, reduced
from repro.core import collectives as C
from repro.core import comm as comm_lib
from repro.core import cost_model
from repro.core import flatbuf as F
from repro.core.hierarchy import SyncConfig
from repro.core.sync_engine import (
    make_sync_engine,
    optstate_sched_init,
    overlap_update,
)
from repro.launch import shard_driver as SD
from repro.launch.train import (
    make_grad_fn,
    make_overlap_grad_fn,
    make_train_state,
    make_train_step,
    overlap_schedule,
)
from repro.models.model import build_model
from repro.optim.sgd import adamw, sgd

AXIS = "ring"


@pytest.fixture(scope="module")
def model():
    return build_model(reduced(get_config("qwen2-0.5b")))


def _sync(overlap=True, wire=None, buckets=4, **kw):
    base = dict(mode="mpi_sgd", allreduce_method="ring", num_rings=1,
                wire_dtype=wire, overlap=overlap, overlap_buckets=buckets)
    base.update(kw)
    return SyncConfig(**base)


def _batch(B=8, S=16, seed=0):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (B, S), 0, 1024)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def _bits_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


# --------------------------------------------------------------------------
# Schedule substrate
# --------------------------------------------------------------------------

def test_schedule_tiles_spec_on_the_grid(model):
    stages, sched = overlap_schedule(model, _sync(), 8)
    grid = F.edge_grid()
    assert sched.num_buckets == 4 and stages.num_stages == 4
    assert sched.starts[0] == 0
    assert sum(sched.sizes) == sched.spec.size
    for s, n in zip(sched.starts, sched.sizes):
        assert s % grid == 0 and (s + n) % grid == 0
    assert sched.shard_size == sum(sched.chunks)
    assert sched.shard_offsets == tuple(
        sum(sched.chunks[:b]) for b in range(sched.num_buckets))
    for b in range(sched.num_buckets):
        assert sched.bucket_padded(b) == 8 * sched.chunks[b] >= sched.sizes[b]


def test_schedule_with_p_round_trips(model):
    _, sched = overlap_schedule(model, _sync(), 8)
    assert sched.with_p(8) is sched
    back = sched.with_p(1).with_p(8)
    assert back == sched
    # p=1 geometry: chunks are the bucket extents themselves (grid-aligned)
    assert sched.with_p(1).shard_size == sched.spec.size


def test_schedule_builder_rejects_bad_partitions():
    tree = {"a": jnp.zeros((256,)), "b": jnp.zeros((512,)),
            "c": jnp.zeros((128,))}
    spec = F.spec_for(tree)
    with pytest.raises(ValueError, match="tile the packed buffer"):
        F.bucket_schedule(spec, (1, 1), 2)
    with pytest.raises(ValueError, match="at least one leaf"):
        F.bucket_schedule(spec, (2, 0, 1), 2)
    with pytest.raises(ValueError, match=">= 0"):
        F.align_edge(-1)
    assert F.align_edge(1) == F.edge_grid()
    assert F.align_edge(0) == 0


def test_pack_bucket_rejects_mismatched_stage_tree(model):
    _, sched = overlap_schedule(model, _sync(), 2)
    with pytest.raises(ValueError, match="same overlap_stages split"):
        sched.pack_bucket(0, {"extra": jnp.zeros(4), "leaf": jnp.zeros(4)})


# --------------------------------------------------------------------------
# The staged chain-VJP vs the monolithic gradient — the tentpole's math
# --------------------------------------------------------------------------

def test_staged_grads_bit_identical_to_monolithic(model):
    """Replaying the loss as a stage chain (tied embedding riding the
    carry, remat'd scanned layers) must give the SAME bits as one
    ``value_and_grad`` — p=1 (LOCAL comm), so ``g_shard`` IS the packed
    staged-gradient buffer with no collective in the way."""
    sync = _sync()
    stages, sched = overlap_schedule(model, sync, 1)
    gfn = make_overlap_grad_fn(model, stages, sched, comm_lib.LOCAL)
    params = model.init(jax.random.key(0))
    batch = _batch()

    loss_o, metrics_o, g_shard = jax.jit(gfn)(params, batch)
    loss_m, _, grads = jax.jit(make_grad_fn(model))(params, batch)
    packed = sched.spec.pack(stages.stage(grads))

    assert float(loss_o) == float(loss_m)
    _bits_equal(g_shard, packed)


# --------------------------------------------------------------------------
# Full-step equivalence matrix: p × wire dtype
# --------------------------------------------------------------------------

# equivalence band per (p, wire) cell vs the NON-overlapped flat path.
# Bitwise where the math forces it: p=1 has no ring hops at all, and f32
# p=2 folds are two-term commutative sums. With a wire dtype at p>=2 the
# bucketed partition reassigns chunk ownership, so a DIFFERENT one of the
# fold terms gets wire-rounded — agreement is then bounded by the codec's
# rounding, not bitwise (the p=8 trailing-reference test below pins the
# staged VJP itself to the bit). f32 at p>=3 differs only by ring fold
# reassociation (ulp-level).
def _band(p, wire):
    if p == 1 or (p == 2 and wire is None):
        return None  # bitwise
    if wire is None:
        return dict(loss_rel=1e-6, rtol=1e-5, atol=1e-6)
    # bf16's 8 mantissa bits and int8's per-block scale both round the
    # wire terms at ~0.4% relative — the bands are the same order
    return dict(loss_rel=2e-3, rtol=1e-2, atol=2e-3)  # bf16 / int8


@pytest.mark.parametrize("wire", [None, "bf16", "int8"])
@pytest.mark.parametrize("p", [1, 2, 8])
def test_overlap_step_matrix_vs_flat_path(model, p, wire):
    """The full p × wire equivalence matrix against the non-overlapped
    fused flat step: same losses and same parameters within the band the
    arithmetic admits (see ``_band``)."""
    band = _band(p, wire)
    opt = sgd(0.1, momentum=0.9)
    batch = SD.shard_batch(_batch(B=8), p)
    s_o = SD.make_driver_state(model, opt, _sync(wire=wire), p,
                               jax.random.key(1))
    s_m = SD.make_driver_state(model, opt, _sync(False, wire=wire), p,
                               jax.random.key(1))
    step_o = jax.jit(SD.make_emulated_step(model, opt, _sync(wire=wire), p))
    step_m = jax.jit(SD.make_emulated_step(model, opt,
                                           _sync(False, wire=wire), p))
    for _ in range(2):
        s_o, m_o = step_o(s_o, batch)
        s_m, m_m = step_m(s_m, batch)
        if band is None:
            assert float(m_o["loss"]) == float(m_m["loss"])
        else:
            assert float(m_o["loss"]) == pytest.approx(
                float(m_m["loss"]), rel=band["loss_rel"])
    if band is None:
        _bits_equal(s_o["params"], s_m["params"])
    else:
        jax.tree.map(
            lambda x, y: np.testing.assert_allclose(
                np.asarray(x), np.asarray(y),
                rtol=band["rtol"], atol=band["atol"]),
            s_o["params"], s_m["params"])


@pytest.mark.parametrize("wire", [None, "bf16", "int8"])
def test_overlap_step_matches_trailing_reference_at_p8(model, wire):
    """p=8: bit-identical to a reference that computes the MONOLITHIC
    gradient and then runs the SAME schedule's bucket legs trailing
    backward — isolating the staged-VJP claim from ring fold order
    (which differs vs the monolithic partition for p≥3)."""
    p = 8
    opt = adamw(3e-3, eps=1e-5)
    hyper = opt.hyper
    sync = _sync(wire=wire)
    stages, sched = overlap_schedule(model, sync, p)
    comm = comm_lib.Communicator.world((AXIS,), (p,), method="ring",
                                       wire_dtype=wire)
    gfn_o = make_overlap_grad_fn(model, stages, sched, comm)
    grad_fn = make_grad_fn(model)

    def finish(params, opt_state, g_shard):
        staged = stages.stage(params)
        new_staged, new_opt = overlap_update(
            sched, g_shard, staged, opt_state, hyper=hyper, comm=comm)
        return stages.unstage(new_staged), new_opt

    def dev_overlap(pb, ax):
        (params, opt_state), batch = pb
        loss, _, g_shard = gfn_o(params, batch)
        return finish(params, opt_state, g_shard) + (loss,)

    def dev_trailing(pb, ax):
        (params, opt_state), batch = pb
        loss, _, grads = grad_fn(params, batch)
        gstaged = stages.stage(grads)
        g_shard = jnp.concatenate([
            comm.reduce_scatter_bucket(sched.pack_bucket(b, gstaged[b]),
                                       sched, b)
            for b in range(sched.num_buckets)])
        return finish(params, opt_state, g_shard) + (loss,)

    params = model.init(jax.random.key(0))
    stacked_p = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), params)
    opt0 = optstate_sched_init(hyper, sched)
    stacked_o = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), opt0)
    sbatch = SD.shard_batch(_batch(B=8), p)

    out_o = jax.jit(lambda pb: C.emulate(dev_overlap, pb))(
        ((stacked_p, stacked_o), sbatch))
    out_t = jax.jit(lambda pb: C.emulate(dev_trailing, pb))(
        ((stacked_p, stacked_o), sbatch))
    _bits_equal(out_o, out_t)


def test_uneven_last_bucket(model):
    """num_layers=3 with 4 buckets: the ceil split gives layer slices of
    2 and 1 — the schedule must tile anyway and the step stays
    bit-identical to the monolithic path at p=2."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              num_layers=3)
    m3 = build_model(cfg)
    stages, sched = overlap_schedule(m3, _sync(), 2)
    assert stages.num_stages == 4
    # uneven: the two layer-slice buckets cover different extents
    assert sched.sizes[1] != sched.sizes[2]
    assert sum(sched.sizes) == sched.spec.size

    opt = sgd(0.1, momentum=0.9)
    batch = SD.shard_batch(_batch(B=4), 2)
    s_o = SD.make_driver_state(m3, opt, _sync(), 2, jax.random.key(1))
    s_m = SD.make_driver_state(m3, opt, _sync(False), 2, jax.random.key(1))
    s_o, m_o = jax.jit(SD.make_emulated_step(m3, opt, _sync(), 2))(s_o, batch)
    s_m, m_m = jax.jit(SD.make_emulated_step(m3, opt, _sync(False), 2))(
        s_m, batch)
    assert float(m_o["loss"]) == float(m_m["loss"])
    _bits_equal(s_o["params"], s_m["params"])


def test_single_bucket_degenerate(model):
    """overlap_buckets=1: the whole loss is one stage, the one leg simply
    trails backward — still the fused bucketed machinery, zero overlap."""
    stages, sched = overlap_schedule(model, _sync(buckets=1), 2)
    assert stages.num_stages == 1 and sched.num_buckets == 1
    assert cost_model.overlap_fraction([sched.sizes[0] * 4], 2) == 0.0

    opt = sgd(0.1, momentum=0.9)
    batch = SD.shard_batch(_batch(B=4), 2)
    sync1 = _sync(buckets=1)
    s_o = SD.make_driver_state(model, opt, sync1, 2, jax.random.key(1))
    s_m = SD.make_driver_state(model, opt, _sync(False), 2,
                               jax.random.key(1))
    s_o, m_o = jax.jit(SD.make_emulated_step(model, opt, sync1, 2))(
        s_o, batch)
    s_m, m_m = jax.jit(SD.make_emulated_step(model, opt, _sync(False), 2))(
        s_m, batch)
    assert float(m_o["loss"]) == float(m_m["loss"])
    _bits_equal(s_o["params"], s_m["params"])


def test_two_axis_pod_data_driver(model):
    """2-axis (2,2) pod×data geometry: the overlapped step runs with
    nested per-axis bucket legs and matches the 2-axis monolithic flat
    path to fp-reassociation tolerance (total p=4 ≥ 3)."""
    geom = (2, 2)
    opt = sgd(0.1, momentum=0.9)
    batch = SD.shard_batch(_batch(B=8), 4)
    s_o = SD.make_driver_state(model, opt, _sync(), geom, jax.random.key(1))
    s_m = SD.make_driver_state(model, opt, _sync(False), geom,
                               jax.random.key(1))
    step_o = jax.jit(SD.make_emulated_step(model, opt, _sync(), geom))
    step_m = jax.jit(SD.make_emulated_step(model, opt, _sync(False), geom))
    for _ in range(2):
        s_o, m_o = step_o(s_o, batch)
        s_m, m_m = step_m(s_m, batch)
        assert float(m_o["loss"]) == pytest.approx(float(m_m["loss"]),
                                                   rel=1e-6)
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6),
        s_o["params"], s_m["params"])
    # device opt state carries the schedule geometry at total p=4
    _, sched4 = overlap_schedule(model, _sync(), 4)
    assert s_o["opt"].shape == (4, sched4.shard_size)


# --------------------------------------------------------------------------
# Structural: the traced program actually interleaves wire with backward
# --------------------------------------------------------------------------

_COMPUTE = {"dot_general", "conv_general_dilated", "scan", "scatter-add",
            "remat", "remat2", "checkpoint", "custom_vjp_call",
            "custom_vjp_call_jaxpr"}


def test_traced_program_interleaves_ppermute_with_backward(model):
    """Top-level eqn order of the staged grad fn IS the issue order: all
    but the last-issued bucket's ring chain must sit before the final
    backward-compute eqn (the embedding pullback), and the hidden
    fraction must equal the cost model's structural claim exactly."""
    p = 4
    sync = _sync()
    stages, sched = overlap_schedule(model, sync, p)
    comm = comm_lib.Communicator.world((AXIS,), (p,), method="ring")
    gfn = make_overlap_grad_fn(model, stages, sched, comm)
    params = model.init(jax.random.key(0))
    closed = jax.make_jaxpr(gfn, axis_env=[(AXIS, p)])(params, _batch(B=4))

    pp, last_compute = [], -1
    for i, eqn in enumerate(closed.jaxpr.eqns):
        if eqn.primitive.name == "ppermute":
            pp.append((i, sum(v.aval.size * v.aval.dtype.itemsize
                              for v in eqn.invars)))
        elif eqn.primitive.name in _COMPUTE:
            last_compute = i
    assert len(pp) == sched.num_buckets * (p - 1)
    before = [nb for i, nb in pp if i < last_compute]
    after = [nb for i, nb in pp if i > last_compute]
    # three buckets' legs interleave with backward; the last-issued
    # (embedding) leg necessarily trails it
    assert len(before) == (sched.num_buckets - 1) * (p - 1)
    assert len(after) == p - 1
    measured = sum(before) / (sum(before) + sum(after))
    modeled = cost_model.overlap_fraction(
        [n * 4 for n in sched.sizes], p)
    assert measured == pytest.approx(modeled, abs=1e-12)


# --------------------------------------------------------------------------
# Guard rails
# --------------------------------------------------------------------------

def test_sync_config_overlap_guards():
    with pytest.raises(ValueError, match="ring"):
        _sync(allreduce_method="psum").validate()
    with pytest.raises(ValueError, match="fused"):
        _sync(fused_update=False).validate()
    with pytest.raises(ValueError, match="mpi_sgd"):
        dataclasses.replace(_sync(), mode="mpi_esgd").validate()
    with pytest.raises(ValueError, match="overlap_buckets"):
        _sync(buckets=0).validate()
    with pytest.raises(ValueError, match="bucket_bytes"):
        _sync(bucket_bytes=1 << 20).validate()
    with pytest.raises(ValueError, match="num_rings"):
        _sync(num_rings=2).validate()
    with pytest.raises(ValueError, match="fsdp"):
        _sync(fsdp=True).validate()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), (AXIS,))
    with pytest.raises(ValueError, match="GSPMD"):
        _sync().validate(mesh)
    # a clean overlap config passes
    _sync().validate()


def test_train_settings_force_single_ring():
    ts = TrainSettings(allreduce_method="ring", num_rings=4, overlap=True)
    assert ts.sync_config().num_rings == 1
    assert ts.sync_config().overlap and ts.sync_config().overlap_buckets == 4
    ts_off = TrainSettings(allreduce_method="ring", num_rings=4)
    assert ts_off.sync_config().num_rings == 4


def test_overlap_update_rejects_knobs_and_wrong_p(model):
    stages, sched = overlap_schedule(model, _sync(), 1)
    params = model.init(jax.random.key(0))
    staged = stages.stage(params)
    g = jnp.zeros((sched.shard_size,))
    state = optstate_sched_init(sgd(0.1, momentum=0.9).hyper, sched)
    hyper = sgd(0.1, momentum=0.9).hyper
    with pytest.raises(ValueError, match="communicator"):
        overlap_update(sched, g, staged, state, hyper=hyper,
                       wire_dtype="bf16")
    with pytest.raises(ValueError, match="gradient group"):
        overlap_update(sched, g, staged, state, hyper=hyper,
                       comm=comm_lib.Communicator.world((AXIS,), (2,),
                                                        method="ring"))
    # the clean p=1 call round-trips
    new_staged, _ = overlap_update(sched, g, staged, state, hyper=hyper)
    assert jax.tree_util.tree_structure(new_staged) == \
        jax.tree_util.tree_structure(staged)


def test_make_train_step_overlap_guards(model):
    opt = sgd(0.1, momentum=0.9)
    with pytest.raises(ValueError, match="microbatch"):
        make_train_step(model, opt, _sync(), None, microbatch=2)
    bare = dataclasses.replace(model, overlap_stages=None)
    with pytest.raises(ValueError, match="overlap_stages"):
        overlap_schedule(bare, _sync(), 1)
    spec = overlap_schedule(model, _sync(), 1)[1].spec
    with pytest.raises(ValueError, match="overlap_schedule"):
        make_sync_engine(opt, _sync(), None, spec=spec, schedule=None)


def test_jobspec_overlap_guards():
    from repro.launch.launcher import JobSpec, build_job

    spec = JobSpec(4, 1, 1, "qwen2-0.5b", "train_4k", overlap=True)
    job = build_job(spec)
    assert "--overlap" in job["clients"][0]["launch_cmd"]
    assert job["sync"]["overlap"] is True
    with pytest.raises(ValueError, match="fused"):
        dataclasses.replace(spec, fused_update=False).validate()
    with pytest.raises(ValueError, match="bucket"):
        dataclasses.replace(spec, bucket_bytes=1 << 20).validate()
    with pytest.raises(ValueError, match="overlap_buckets"):
        dataclasses.replace(spec, overlap_buckets=0).validate()


def test_drive_rejects_faults_with_overlap(model):
    opt = sgd(0.1, momentum=0.9)
    with pytest.raises(ValueError, match="elastic re-layout"):
        SD.drive(model, opt, _sync(), [_batch(B=4)], p=2,
                 faults="kill@1:unit=1")


def test_train_state_overlap_opt_geometry(model):
    """make_train_state with overlap carries the LOCAL (p=1) schedule
    state: one full-length stream laid out bucket-major (== spec.size)."""
    opt = sgd(0.1, momentum=0.9)
    s = make_train_state(model, opt, _sync(), jax.random.key(0))
    _, sched = overlap_schedule(model, _sync(), 1)
    assert s["opt"].shape == (sched.shard_size,)
    assert sched.shard_size == sched.spec.size
