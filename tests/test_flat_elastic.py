"""Flat elastic exchange: the packed FlatBuffer + fused-Pallas-kernel
substrate must match the per-leaf reference (eqs. 2/3) exactly — for the
pair exchange, the C-client exchange, and the sharded cross-pod leg —
and the default mpi_esgd path must run ZERO per-leaf tree.map updates
(one Pallas launch for the whole tree)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatbuf as F
from repro.core.comm import CollectivePolicy, Communicator
from repro.core.elastic import (
    elastic_exchange,
    elastic_exchange_multiclient,
    elastic_exchange_multiclient_flat,
    elastic_exchange_packed,
    elastic_exchange_sharded,
)

AXIS = "pod"


def _tree(seed=0, C=None, dtype=jnp.float32):
    """Odd, lane-unfriendly leaf sizes on purpose (incl. a scalar)."""
    k = jax.random.key(seed)
    ks = jax.random.split(k, 4)
    lead = (C,) if C else ()
    return {
        "w": jax.random.normal(ks[0], lead + (13, 7), jnp.float32).astype(dtype),
        "b": jax.random.normal(ks[1], lead + (5,), jnp.float32).astype(dtype),
        "deep": {
            "u": jax.random.normal(ks[2], lead + (3, 11, 2),
                                   jnp.float32).astype(dtype),
            "s": jax.random.normal(ks[3], lead + (), jnp.float32).astype(dtype),
        },
    }


def _close(a, b, rtol=1e-5, atol=1e-6):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol),
        a, b)


# --------------------------------------------------------------------------
# packed pair exchange ≡ per-leaf reference
# --------------------------------------------------------------------------

def test_packed_exchange_matches_per_leaf():
    w, c = _tree(0), _tree(1)
    got = elastic_exchange_packed(w, c, 0.37)
    want = elastic_exchange(w, c, 0.37)
    _close(got, want)
    # dtypes restored on unpack
    assert jax.tree.map(lambda l: l.dtype, got[0]) == \
        jax.tree.map(lambda l: l.dtype, w)


def test_packed_exchange_conserves_sum():
    w, c = _tree(2), _tree(3)
    nw, nc = elastic_exchange_packed(w, c, 0.4)
    jax.tree.map(
        lambda a, b, x, y: np.testing.assert_allclose(a + b, x + y, rtol=1e-5),
        nw, nc, w, c)


# --------------------------------------------------------------------------
# multiclient flat exchange ≡ per-leaf, C ∈ {1, 2, 4}, bf16, odd sizes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("C", [1, 2, 4])
def test_multiclient_flat_matches_per_leaf(C):
    W, c = _tree(4, C=C), _tree(5)
    alpha = 0.5 / C
    got = elastic_exchange_multiclient_flat(W, c, alpha)
    want = elastic_exchange_multiclient(W, c, alpha)
    _close(got, want)


@pytest.mark.parametrize("C", [1, 2, 4])
def test_multiclient_flat_bf16(C):
    W, c = _tree(6, C=C, dtype=jnp.bfloat16), _tree(7, dtype=jnp.bfloat16)
    got = elastic_exchange_multiclient_flat(W, c, 0.3)
    want = elastic_exchange_multiclient(W, c, 0.3)
    # both compute in f32 and cast back to bf16 — must agree to bf16 ulps
    _close(got, want, rtol=2e-2, atol=2e-2)
    assert jax.tree_util.tree_leaves(got[0])[0].dtype == jnp.bfloat16


def test_multiclient_flat_odd_single_leaf_sizes():
    for n in (1, 3, 127, 129, 1025):
        W = {"x": jax.random.normal(jax.random.key(n), (3, n))}
        c = {"x": jax.random.normal(jax.random.key(n + 1), (n,))}
        got = elastic_exchange_multiclient_flat(W, c, 0.2)
        want = elastic_exchange_multiclient(W, c, 0.2)
        _close(got, want)


# --------------------------------------------------------------------------
# sharded cross-pod leg ≡ multiclient per-leaf (vmap emulation)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("p,num_rings,bucket_bytes",
                         [(1, 1, None), (2, 1, None), (4, 2, None),
                          (4, 1, 512), (8, 3, None)])
def test_sharded_exchange_matches_multiclient(p, num_rings, bucket_bytes):
    W, c = _tree(8, C=p), _tree(9)
    spec = F.spec_for(c)
    stacked_c = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (p,) + l.shape), c)
    alpha = 0.5 / p

    comm = Communicator.from_axis_name(AXIS, policy=CollectivePolicy(
        num_rings=num_rings, bucket_bytes=bucket_bytes))
    fn = jax.vmap(
        lambda wp, cp: elastic_exchange_sharded(
            spec, wp, cp, alpha, comm=comm),
        axis_name=AXIS)
    new_W, new_C = fn(W, stacked_c)
    want_W, want_c = elastic_exchange_multiclient(W, c, alpha)
    _close(new_W, want_W)
    for d in range(p):  # every device allgathers the SAME new center
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a[d]), np.asarray(b), rtol=1e-5, atol=1e-6),
            new_C, want_c)


def test_sharded_exchange_bf16(p=4):
    W = _tree(10, C=p, dtype=jnp.bfloat16)
    c = _tree(11, dtype=jnp.bfloat16)
    spec = F.spec_for(c)
    stacked_c = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (p,) + l.shape), c)
    fn = jax.vmap(
        lambda wp, cp: elastic_exchange_sharded(
            spec, wp, cp, 0.1, comm=Communicator.from_axis_name(AXIS)),
        axis_name=AXIS)
    new_W, new_C = fn(W, stacked_c)
    want_W, want_c = elastic_exchange_multiclient(W, c, 0.1)
    _close(new_W, want_W, rtol=2e-2, atol=2e-2)
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], new_C), want_c,
               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# int8-compressed packed exchange: roundtrip tolerance
# --------------------------------------------------------------------------

def test_compressed_packed_exchange_tolerance():
    """wire_dtype="int8" quantizes the packed w buffer (the PS-push wire
    form): the exchange must stay within the per-block absmax/127 error
    envelope of the exact exchange."""
    w, c = _tree(12), _tree(13)
    exact = elastic_exchange_packed(w, c, 0.5)
    quant = elastic_exchange_packed(w, c, 0.5, wire_dtype="int8")
    # max quantization error per value is scale/2 <= absmax/254; alpha
    # scales it into the outputs. Normal(0,1) leaves -> absmax ~< 4.
    leaves = jax.tree_util.tree_leaves(w)
    absmax = max(float(jnp.max(jnp.abs(l))) for l in leaves)
    tol = 0.5 * absmax / 127.0  # alpha * full quant step, generous
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=tol),
        quant, exact)
    # and the compressed exchange is not exactly the uncompressed one
    # (the quantization actually happened)
    diffs = jax.tree_util.tree_leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), quant[1], exact[1]))
    assert max(diffs) > 0


def test_kvstore_flat_elastic_matches_per_leaf():
    from repro.core.kvstore import KVStore

    w, c0 = _tree(14), _tree(15)
    out = {}
    for flat in (True, False):
        kv = KVStore.create("dist_async", num_workers=1, flat_exchange=flat)
        kv.init("centers", c0)
        kv.set_elastic(0.35)
        kv.push("centers", w)
        out[flat] = kv.value("centers")
    _close(out[True], out[False])


def test_kvstore_compressed_flat_push_quantizes_per_push():
    """Sync barrier + compress: each push is quantized BEFORE the barrier
    sums (the wire model), so flat matches per-leaf within the coarser
    packed-block quantization tolerance — and the byte accounting uses
    the true payload, never the lane-padded buffer size."""
    from repro.core.kvstore import KVStore

    c0 = _tree(18)
    pushes = [_tree(19), _tree(20)]
    out = {}
    for flat in (True, False):
        kv = KVStore.create("dist_sync", num_workers=2, wire_dtype="int8",
                            flat_exchange=flat)
        kv.init("centers", c0)
        kv.set_elastic(0.4)
        for w in pushes:
            kv.push("centers", w)
        out[flat] = (kv.value("centers"), kv.pushed_bytes,
                     kv.pushed_bytes_uncompressed)
    _close(out[True][0], out[False][0], rtol=1e-2, atol=2e-2)
    # compressed wire really is smaller than raw, for the packed form too
    assert out[True][1] < out[True][2]
    # tiny-tree regression: payload-based accounting, not padded size
    kv = KVStore.create("dist_async", num_workers=1, wire_dtype="int8")
    kv.init("c", jnp.zeros(2))
    kv.set_elastic(0.5)
    kv.push("c", jnp.ones(2))
    assert kv.pushed_bytes < kv.pushed_bytes_uncompressed


# --------------------------------------------------------------------------
# the default mpi_esgd path is structurally flat: ONE Pallas launch,
# zero per-leaf update arithmetic
# --------------------------------------------------------------------------

def _primitive_counts(fn, *args):
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr):
        names = []
        for eqn in jaxpr.eqns:
            names.append(eqn.primitive.name)
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else [val]
                for v in vals:
                    if hasattr(v, "jaxpr"):
                        names += walk(v.jaxpr)
        return names

    return walk(closed.jaxpr)


def test_flat_exchange_is_one_kernel_launch():
    C = 4
    W, c = _tree(16, C=C), _tree(17)
    flat_names = _primitive_counts(
        lambda w_, c_: elastic_exchange_multiclient_flat(w_, c_, 0.2), W, c)
    leaf_names = _primitive_counts(
        lambda w_, c_: elastic_exchange_multiclient(w_, c_, 0.2), W, c)
    num_leaves = len(jax.tree_util.tree_leaves(c))
    # flat: the whole exchange is ONE fused launch; the only other work
    # is the static-slice pack/unpack (no per-leaf sub/mul updates)
    assert flat_names.count("pallas_call") == 1
    assert flat_names.count("sub") == 0
    # per-leaf reference: zero kernel launches, O(num_leaves) updates
    assert leaf_names.count("pallas_call") == 0
    assert leaf_names.count("sub") >= num_leaves


def test_train_step_default_esgd_exchange_is_flat():
    """The production multiclient step's default exchange must ride the
    packed kernel — and match the per-leaf flag numerically."""
    from repro.configs.base import get_config, reduced
    from repro.core.hierarchy import SyncConfig
    from repro.launch.train import make_train_state, make_train_step
    from repro.models.model import build_model
    from repro.optim.sgd import sgd

    model = build_model(reduced(get_config("qwen2-0.5b")))
    opt = sgd(0.1, momentum=0.9)
    C = 2
    sync = SyncConfig(mode="mpi_esgd", num_clients=C, esgd_interval=1,
                      esgd_alpha=0.5)
    sync_leaf = dataclasses.replace(sync, flat_exchange=False)
    k = jax.random.key(0)
    toks = jax.random.randint(k, (4, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    cbatch = jax.tree.map(
        lambda a: a.reshape((C, a.shape[0] // C) + a.shape[1:]), batch)

    s_f = make_train_state(model, opt, sync, jax.random.key(1))
    s_l = make_train_state(model, opt, sync_leaf, jax.random.key(1))
    step_f = jax.jit(make_train_step(model, opt, sync, None))
    step_l = jax.jit(make_train_step(model, opt, sync_leaf, None))
    for _ in range(3):
        s_f, m_f = step_f(s_f, cbatch)
        s_l, m_l = step_l(s_l, cbatch)
    assert float(m_f["loss"]) == pytest.approx(float(m_l["loss"]), rel=1e-4)
    _close(s_f["params"], s_l["params"], rtol=2e-4, atol=2e-5)
    _close(s_f["center"], s_l["center"], rtol=2e-4, atol=2e-5)

    # structurally: both steps carry the ONE (vmapped) fused-SGD launch;
    # the default step adds exactly ONE more — the packed exchange — and
    # the per-leaf flag's exchange adds none
    names_f = _primitive_counts(step_f, s_f, cbatch)
    names_l = _primitive_counts(step_l, s_l, cbatch)
    assert names_l.count("pallas_call") == 1
    assert names_f.count("pallas_call") == 2
