"""Production train-step: microbatch equivalence, multi-client ESGD step,
hierarchy transforms, checkpoint/resume integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.hierarchy import (
    SyncConfig,
    clientize,
    clientize_specs,
    declientize,
    grad_sync_axes,
)
from repro.launch.train import (
    clientize_batch_specs,
    make_train_state,
    make_train_step,
    train_loop,
)
from repro.models.model import build_model
from repro.optim.sgd import sgd


def _model():
    return build_model(reduced(get_config("qwen2-0.5b")))


def _batch(B=4, S=32, seed=0):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (B, S), 0, 1024)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def test_microbatch_equals_full_batch():
    """grad accumulation over M microbatches == one big batch (momentum
    SGD is linear in the gradient)."""
    model = _model()
    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    s0 = make_train_state(model, opt, sync, jax.random.key(0))
    batch = _batch(B=8)
    step1 = jax.jit(make_train_step(model, opt, sync, None, microbatch=1))
    step4 = jax.jit(make_train_step(model, opt, sync, None, microbatch=4))
    s1, m1 = step1(s0, batch)
    s4, m4 = step4(s0, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-4),
        s1["params"], s4["params"])


def test_esgd_multiclient_step_runs_and_syncs():
    model = _model()
    opt = sgd(0.1, momentum=0.9)
    C = 2
    sync = SyncConfig(mode="mpi_esgd", num_clients=C, esgd_interval=2,
                      esgd_alpha=0.5)
    state = make_train_state(model, opt, sync, jax.random.key(0))
    # leading client dim everywhere
    lead = jax.tree_util.tree_leaves(state["params"])[0].shape[0]
    assert lead == C
    step = jax.jit(make_train_step(model, opt, sync, None))
    batch = _batch(B=4)
    cbatch = jax.tree.map(
        lambda a: a.reshape((C, a.shape[0] // C) + a.shape[1:]), batch)
    # different data per client -> replicas diverge
    s1, m1 = step(state, cbatch)
    diverged = jax.tree_util.tree_leaves(jax.tree.map(
        lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))), s1["params"]))
    assert max(diverged) > 0
    # run until an elastic exchange fires (step % interval == 0)
    s2, _ = step(s1, cbatch)
    s3, _ = step(s2, cbatch)
    # center must have moved away from init after the exchange
    moved = jax.tree_util.tree_leaves(jax.tree.map(
        lambda c0, c1: float(jnp.max(jnp.abs(c0 - c1))),
        state["center"], s3["center"]))
    assert max(moved) > 0


def test_esgd_pulls_replicas_together():
    """With elastic sync every step and alpha near .5, replicas contract."""
    model = _model()
    opt = sgd(0.0)  # freeze SGD: isolate the elastic force
    C = 2
    sync = SyncConfig(mode="mpi_esgd", num_clients=C, esgd_interval=1,
                      esgd_alpha=0.8)
    state = make_train_state(model, opt, sync, jax.random.key(0))
    # artificially separate the replicas
    state["params"] = jax.tree.map(
        lambda p: p.at[0].add(1.0), state["params"])
    spread0 = max(jax.tree_util.tree_leaves(jax.tree.map(
        lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))), state["params"])))
    step = jax.jit(make_train_step(model, opt, sync, None))
    batch = _batch(B=4)
    cbatch = jax.tree.map(
        lambda a: a.reshape((C, a.shape[0] // C) + a.shape[1:]), batch)
    for _ in range(6):
        state, _ = step(state, cbatch)
    spread1 = max(jax.tree_util.tree_leaves(jax.tree.map(
        lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))), state["params"])))
    assert spread1 < 0.25 * spread0


def test_train_loop_reduces_loss():
    from repro.data.pipeline import DataConfig, TokenPipeline

    model = _model()
    cfg = model.cfg
    pipe = TokenPipeline(DataConfig(seed=0, vocab_size=256, seq_len=64,
                                    batch_size=8, steps_per_epoch=30))
    batches = list(pipe.epoch(0))
    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    state, hist = train_loop(model, opt, sync, None, batches, log_every=1)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2


def test_clientize_roundtrip():
    p = {"w": jnp.arange(6.0).reshape(2, 3)}
    c = clientize(p, 4)
    assert c["w"].shape == (4, 2, 3)
    back = declientize(c, 4)
    np.testing.assert_allclose(back["w"], p["w"])


def test_clientize_specs_prepends_pod():
    from jax.sharding import PartitionSpec as P

    specs = {"w": P(None, "model")}
    out = clientize_specs(specs, 2)
    assert out["w"] == P("pod", None, "model")


def test_grad_sync_axes():
    class M:
        shape = {"pod": 2, "data": 16, "model": 16}

    assert grad_sync_axes(M(), 1) == ("pod", "data")
    assert grad_sync_axes(M(), 2) == ("data",)


def test_sync_config_validation():
    class M:
        shape = {"data": 16, "model": 16}

    with pytest.raises(ValueError):
        SyncConfig(mode="dist_asgd").validate(M())
    with pytest.raises(ValueError):
        SyncConfig(mode="mpi_esgd", num_clients=2).validate(M())


def test_checkpoint_resume_training(tmp_path):
    from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint

    model = _model()
    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    state = make_train_state(model, opt, sync, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt, sync, None))
    batch = _batch(B=4)
    for _ in range(3):
        state, _ = step(state, batch)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, state, step=3)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, meta = restore_checkpoint(path, like)
    assert meta["step"] == 3
    s_a, _ = step(state, batch)
    s_b, _ = step(restored, batch)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6),
        s_a["params"], s_b["params"])
