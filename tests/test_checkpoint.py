"""checkpoint/checkpoint.py: the save -> kill -> restore -> resume
roundtrip the PS task model's restartability story leans on (paper §8),
exercised against the real train stack for both lowerable sync modes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (core before optim: package init order)
from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import get_config, reduced
from repro.core.hierarchy import SyncConfig
from repro.launch.shard_driver import shard_batch
from repro.launch.train import make_train_state, make_train_step
from repro.models.model import build_model
from repro.optim.sgd import sgd


@pytest.fixture(scope="module")
def model():
    return build_model(reduced(get_config("qwen2-0.5b")))


def _batch(i, clients=1):
    k = jax.random.fold_in(jax.random.key(42), i)
    toks = jax.random.randint(k, (4 * max(clients, 1), 32), 0, 1024)
    b = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    return shard_batch(b, clients) if clients > 1 else b


def _run(step_fn, state, steps, *, clients=1, start=0):
    for i in range(start, start + steps):
        state, _ = step_fn(state, _batch(i, clients))
    return state


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def test_mpi_sgd_kill_restore_resume_bit_exact(model, tmp_path):
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    opt = sgd(0.1, momentum=0.9)
    step_fn = jax.jit(make_train_step(model, opt, sync, None))
    rng = jax.random.key(1)

    ref = _run(step_fn, make_train_state(model, opt, sync, rng), 4)

    state = _run(step_fn, make_train_state(model, opt, sync, rng), 2)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, state, step=2)
    del state                                    # the "kill"

    fresh = make_train_state(model, opt, sync, jax.random.key(999))
    restored, meta = restore_checkpoint(path, fresh)
    assert meta["step"] == 2
    assert int(restored["step"]) == 2
    resumed = _run(step_fn, restored, 2, start=2)

    for a, b in zip(_leaves(resumed), _leaves(ref)):
        np.testing.assert_array_equal(a, b)      # bit-exact


def test_mpi_esgd_kill_restore_resume(model, tmp_path):
    sync = SyncConfig(mode="mpi_esgd", num_clients=2, esgd_interval=2)
    opt = sgd(0.1, momentum=0.9)
    step_fn = jax.jit(make_train_step(model, opt, sync, None))
    rng = jax.random.key(1)

    ref = _run(step_fn, make_train_state(model, opt, sync, rng), 5,
               clients=2)

    state = _run(step_fn, make_train_state(model, opt, sync, rng), 3,
                 clients=2)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, state, step=3,
                    metadata={"mode": sync.mode, "clients": 2})
    del state

    restored, meta = restore_checkpoint(
        path, make_train_state(model, opt, sync, jax.random.key(777)))
    assert meta["mode"] == "mpi_esgd" and meta["clients"] == 2
    resumed = _run(step_fn, restored, 2, clients=2, start=3)

    for a, b in zip(_leaves(resumed), _leaves(ref)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_roundtrip_preserves_structure_and_dtypes(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.float32)],
            "c": {"t": jnp.asarray(3, jnp.int32)}}
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, tree, step=7, metadata={"note": "x"})
    got, meta = restore_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    assert meta["step"] == 7 and meta["note"] == "x"
    assert jax.tree_util.tree_structure(got) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_rejects_missing_leaf_and_bad_shape(tmp_path):
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, {"a": jnp.ones((2,))})
    with pytest.raises(KeyError, match="missing leaf"):
        restore_checkpoint(path, {"a": jnp.ones((2,)), "b": jnp.ones((1,))})
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(path, {"a": jnp.ones((3,))})


def test_save_overwrites_atomically(tmp_path):
    path = str(tmp_path / "t.npz")
    save_checkpoint(path, {"a": jnp.zeros((2,))}, step=1)
    save_checkpoint(path, {"a": jnp.ones((2,))}, step=2)
    got, meta = restore_checkpoint(path, {"a": jnp.zeros((2,))})
    assert meta["step"] == 2
    np.testing.assert_array_equal(np.asarray(got["a"]), 1.0)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
