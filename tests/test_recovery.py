"""Crash recovery for the multi-process PS tier: the restart@ fault
grammar, generation-indexed kills, durable KV snapshots (torn-file
safety included), the supervisor's scheduled/budget/give-up ladder, the
metrics merge across spawn generations, and the shard driver's mid-run
joins.

Unmarked tests are fast in-process units. ``transport``-marked tests
spawn REAL OS processes and SIGKILL them (the recovery-smoke CI tier);
the drive() join test rides the multi-device tier with the rest of the
shard-driver suite.
"""
import os

import numpy as np
import pytest

from repro.core import cost_model
from repro.core.algorithms import AlgoConfig
from repro.core.faults import FaultSchedule, as_schedule, injector
from repro.launch.supervisor import (JobFailed, RestartPolicy, Supervisor,
                                     Unit)

jax = pytest.importorskip("jax")


# ---------------------------------------------------------------------------
# restart@ grammar + generation-indexed lookups (core/faults.py)
# ---------------------------------------------------------------------------

def test_restart_grammar_roundtrip():
    text = "kill@2:unit=1;restart@2:unit=1:delay=0.1"
    sched = FaultSchedule.parse(text)
    assert sched.format() == text
    assert sched.kinds == {"kill", "restart"}
    r = [e for e in sched.events if e.kind == "restart"][0]
    assert r.step == 2 and r.unit == 1 and r.factor == 0.1


def test_restart_delay_defaults_to_zero():
    sched = FaultSchedule.parse("restart@3:unit=4")
    assert sched.events[0].factor == 0.0
    assert sched.format() == "restart@3:unit=4"   # no spurious :delay=


def test_restart_rejects_delay_on_other_kinds():
    with pytest.raises(ValueError, match="unknown fault field"):
        FaultSchedule.parse("kill@2:unit=1:delay=0.1")


def test_kills_are_generation_indexed():
    inj = injector("kill@3:unit=1;kill@5:unit=1;restart@3:unit=1:delay=0.2")
    # spawn generation 0 dies at the first kill, its respawn at the second
    assert inj.killed_at(1, attempt=0) == 3
    assert inj.killed_at(1, attempt=1) == 5
    assert inj.killed_at(1, attempt=2) is None
    assert inj.is_killed(1, 3, attempt=0)
    assert not inj.is_killed(1, 3, attempt=1)
    assert inj.is_killed(1, 5, attempt=1)
    # generation 0's death has a scheduled respawn; generation 1's does not
    assert inj.restart_delay(1, attempt=0) == 0.2
    assert inj.restart_delay(1, attempt=1) is None
    # other units are untouched
    assert inj.killed_at(0) is None and inj.restart_delay(0) is None


def test_restart_units_are_join_directives():
    inj = injector("restart@3:unit=4;restart@3:unit=6;restart@5:unit=4")
    assert inj.restart_units(3) == (4, 6)
    assert inj.restart_units(5) == (4,)
    assert inj.restart_units(0) == ()


def test_as_schedule_threads_restart_events():
    sched = as_schedule("kill@2:unit=1;restart@2:unit=1", seed=0)
    assert sched is not None and "restart" in sched.kinds
    assert as_schedule("", seed=0) is None


# ---------------------------------------------------------------------------
# durable snapshots survive crash-mid-write (checkpoint/checkpoint.py)
# ---------------------------------------------------------------------------

def test_latest_checkpoint_skips_torn_and_tmp_files(tmp_path):
    from repro.checkpoint import checkpoint as ckpt

    d = str(tmp_path)
    good = ckpt.checkpoint_path(d, 1)
    ckpt.save_packed(good, {"kv:0": np.arange(4, dtype=np.float32)}, step=1)
    # a crash mid-write leaves a torn newest file and a .tmp leftover;
    # neither may shadow the last complete snapshot
    with open(ckpt.checkpoint_path(d, 2), "wb") as f:
        f.write(b"PK\x03\x04 this is not a zip archive")
    with open(os.path.join(d, "ckpt_3.npz.tmp"), "wb") as f:
        f.write(b"partial")
    assert ckpt.latest_checkpoint(d) == good
    arrays, meta = ckpt.restore_packed(good)
    np.testing.assert_array_equal(arrays["kv:0"],
                                  np.arange(4, dtype=np.float32))
    assert meta["step"] == 1


def test_latest_checkpoint_empty_and_missing_dir(tmp_path):
    from repro.checkpoint import checkpoint as ckpt

    assert ckpt.latest_checkpoint(str(tmp_path)) is None
    assert ckpt.latest_checkpoint(str(tmp_path / "nope")) is None


def _mini_algo(**kw):
    base = dict(mode="dist_sgd", num_workers=2, num_clients=2,
                num_servers=1, lr=0.05, epochs=1, steps_per_epoch=2,
                seed=0, compute_time=0.0, jitter=0.0)
    base.update(kw)
    return AlgoConfig(**base)


def test_kvserver_snapshot_restore_roundtrip(tmp_path):
    """A respawned server restores the exact released-round sums and the
    parked per-unit state from its latest durable snapshot — the replay
    a riding worker depends on."""
    from repro.net import wire
    from repro.net.kvserver import KVServer

    cfg = _mini_algo(checkpoint_every=1)
    srv = KVServer(cfg, rank=0, ckpt_dir=str(tmp_path))
    vals = np.zeros(256, dtype=np.float32)
    meta, payload = wire.encode_buffer(vals, None)
    srv.handle("init", dict(meta, key="w"), payload)
    for unit in (0, 1):
        g = np.full(256, float(unit + 1), dtype=np.float32)
        gm, gp = wire.encode_buffer(g, None)
        srv.handle("push", dict(gm, key="w", unit=unit, step=0), gp)
    # both pushes arrived -> released -> snapshotted (checkpoint_every=1)
    assert srv.snapshots == 1
    pm, pp = srv.handle("pull", {"key": "w", "step": 0}, b"")
    released = wire.decode_buffer(pm, pp)
    # park unit 1's resume state (exact f32, bypasses the wire codec)
    parked = np.arange(8, dtype=np.float32)
    srv.handle("put_state",
               {"unit": 1, "step": 1, "sections": ["params"],
                "sizes": [8]}, parked.tobytes())
    srv.handle("snapshot", {"step": 0}, b"")

    fresh = KVServer(cfg, rank=0, ckpt_dir=str(tmp_path), attempt=1)
    info, _ = fresh.handle("restore", {}, b"")
    assert info["restored"] and info["step"] == 0
    assert fresh.restored_from is not None
    # the replayed pull of the released round is bit-identical
    rm, rp = fresh.handle("pull", {"key": "w", "step": 0}, b"")
    np.testing.assert_array_equal(wire.decode_buffer(rm, rp), released)
    assert rm["count"] == pm["count"] and not rm["degraded"]
    # the parked state came back exactly
    sm, sp = fresh.handle("get_state", {"unit": 1}, b"")
    assert sm["found"] and sm["step"] == 1 and sm["sections"] == ["params"]
    np.testing.assert_array_equal(np.frombuffer(sp, np.float32), parked)


# ---------------------------------------------------------------------------
# the supervisor ladder: scheduled -> budget -> give up (launch/supervisor.py)
# ---------------------------------------------------------------------------

class _FakeProc:
    """poll() walks a scripted exit-code sequence; None = still running."""

    def __init__(self, codes):
        self.codes = list(codes)

    def poll(self):
        return self.codes.pop(0) if self.codes else None


def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 0.01
        return t[0]

    return clock


def test_supervisor_scheduled_respawn_spares_budget():
    slept, spawned = [], []

    def spawn(u):
        spawned.append(u.attempt)
        return _FakeProc([0])

    sup = Supervisor(spawn, policy=RestartPolicy(max_restarts=0),
                     worker_injector=injector(
                         "kill@2:unit=1;restart@2:unit=1:delay=0.25"),
                     clock=_fake_clock(), sleep=slept.append)
    sup.register("client_1", _FakeProc([137]), role="worker", unit=1)
    report = sup.supervise(timeout=60.0)
    assert report["respawns"] and report["respawns"][0]["scheduled"]
    assert report["respawns"][0]["exit_code"] == 137
    assert 0.25 in slept                        # the scheduled delay
    assert sup.units["client_1"].used_budget == 0
    assert report["exhausted"] == [] and report["gave_up"] == []
    assert report["exit_history"]["client_1"] == [137, 0]
    assert spawned == [1]                       # respawn IS generation 1


def test_supervisor_budget_exhaustion_fails_loudly():
    sup = Supervisor(lambda u: _FakeProc([137]),
                     policy=RestartPolicy(max_restarts=1, backoff=0.0),
                     clock=_fake_clock(), sleep=lambda s: None)
    sup.register("client_1", _FakeProc([137]), role="worker", unit=1)
    sup.register("client_0", _FakeProc([0]), role="worker", unit=0)
    report = sup.supervise(timeout=60.0)
    assert report["exhausted"] == ["client_1"]
    assert report["exit_history"]["client_1"] == [137, 137]
    assert report["exit_history"]["client_0"] == [0]
    assert sup.units["client_1"].used_budget == 1
    assert len(report["respawns"]) == 1
    assert not report["respawns"][0]["scheduled"]


def test_supervisor_no_budget_keeps_quiet_eviction():
    """max_restarts=0 and no schedule: the unit just stays down (PR 9's
    eviction semantics) — gave_up, but NOT exhausted, so the job does
    not fail."""
    sup = Supervisor(lambda u: _FakeProc([0]), policy=RestartPolicy(),
                     clock=_fake_clock(), sleep=lambda s: None)
    sup.register("client_1", _FakeProc([137]), role="worker", unit=1)
    sup.register("client_0", _FakeProc([0]), role="worker", unit=0)
    report = sup.supervise(timeout=60.0)
    assert report["gave_up"] == ["client_1"]
    assert report["exhausted"] == []
    assert report["respawns"] == []


def test_supervisor_backoff_grows_exponentially():
    pol = RestartPolicy(max_restarts=5, backoff=0.1, backoff_factor=2.0,
                        max_backoff=0.5)
    assert [pol.delay(k) for k in range(5)] == [0.1, 0.2, 0.4, 0.5, 0.5]


def test_supervisor_respawned_server_is_not_waited_on():
    """supervise() returns when the WORKERS finish; a respawned server
    keeps running (it idles until the shutdown RPC)."""
    server_spawns = []

    def spawn(u):
        server_spawns.append(u.name)
        return _FakeProc([])                    # respawn never exits

    sup = Supervisor(spawn, policy=RestartPolicy(),
                     server_injector=injector(
                         "kill@1:unit=0;restart@1:unit=0"),
                     clock=_fake_clock(), sleep=lambda s: None)
    sup.register("server_0", _FakeProc([137]), role="server", unit=0)
    sup.register("client_0", _FakeProc([None, None, 0]),
                 role="worker", unit=0)
    report = sup.supervise(timeout=60.0)
    assert server_spawns == ["server_0"]
    assert report["attempts"]["server_0"] == 1
    assert not report["timed_out"]


def test_jobfailed_carries_partial_result():
    err = JobFailed("budget gone", result={"losses": [1.0]})
    assert err.result == {"losses": [1.0]}


def test_unit_dataclass_defaults():
    u = Unit(name="client_0", role="worker", unit=0, proc=None)
    assert u.attempt == 0 and not u.exhausted and u.exit_codes == []


# ---------------------------------------------------------------------------
# JobSpec validation (launch/launcher.py)
# ---------------------------------------------------------------------------

def _spec(**kw):
    from repro.launch.launcher import JobSpec

    base = dict(mode="dist_sgd", transport="tcp", barrier_timeout=1.0)
    base.update(kw)
    return JobSpec(2, 1, 2, "qwen3-4b", "train_4k", **base)


def test_jobspec_rejects_restart_budget_on_loopback():
    with pytest.raises(ValueError, match="transport='tcp'"):
        _spec(transport="loopback", restarts=1).validate()
    with pytest.raises(ValueError, match="SIGKILLed"):
        _spec(transport="loopback",
              faults="kill@2:unit=1;restart@2:unit=1").validate()
    with pytest.raises(ValueError, match="respawn"):
        _spec(transport="loopback",
              server_faults="kill@1:unit=0").validate()


def test_jobspec_server_kill_requires_checkpointing():
    with pytest.raises(ValueError, match="checkpoint_every"):
        _spec(server_faults="kill@1:unit=0;restart@1:unit=0").validate()
    # with durable snapshots it validates
    _spec(server_faults="kill@1:unit=0;restart@1:unit=0",
          checkpoint_every=1).validate()


def test_jobspec_recovery_fields_validate_and_thread():
    from repro.launch.launcher import build_job

    spec = _spec(restarts=2, restart_backoff=0.1, checkpoint_every=1,
                 faults="kill@2:unit=1;restart@2:unit=1")
    spec.validate()
    job = build_job(spec)
    rec = job["recovery"]
    assert rec["restarts"] == 2 and rec["checkpoint_every"] == 1
    with pytest.raises(ValueError, match="restarts"):
        _spec(restarts=-1).validate()
    with pytest.raises(ValueError, match="checkpoint_every"):
        _spec(checkpoint_every=-1).validate()


# ---------------------------------------------------------------------------
# the cost model's recovery legs (core/cost_model.py)
# ---------------------------------------------------------------------------

def test_restore_leg_bytes_is_exact_f32():
    assert cost_model.restore_leg_bytes(2048) == 8192
    # params + momentum on the logreg8 FlatBuffer
    assert cost_model.restore_leg_bytes(2 * 2048) == 16384


def test_join_reshard_bytes_matches_reshard_leg():
    n = 5_779_456
    assert (cost_model.join_reshard_bytes(n, 4)
            == cost_model.reshard_leg_bytes(n, 4))
    assert (cost_model.join_reshard_bytes(n, 4, survivors=3)
            == cost_model.reshard_leg_bytes(n, 4, survivors=3))


def test_recovery_time_composes_delay_restore_and_reconfig():
    net = cost_model.NetParams(alpha=1e-4, beta=1e-9, gamma=1e-10)
    # pure restore, no membership change: delay + bytes * beta
    t = cost_model.recovery_time(8192, 0.25, 4, 4, net)
    assert t == pytest.approx(0.25 + 8192 * net.beta)
    # a join (p change) adds the reconfig leg
    t_join = cost_model.recovery_time(0.0, 0.1, 4, 5, net,
                                      state_nbytes=1 << 20)
    assert t_join > 0.1
    assert t_join == pytest.approx(
        0.1 + cost_model.reconfig_time(1 << 20, 4, 5, net))


# ---------------------------------------------------------------------------
# merging pre-kill partial curves with the respawn's (launch/run_local.py)
# ---------------------------------------------------------------------------

def test_merge_worker_records_later_generation_wins():
    from repro.launch.run_local import _merge_worker_records

    pre = {"gsteps": [0, 1, 2], "losses": [1.0, 0.9, 0.8],
           "metric_epochs": [0], "metrics": [0.5]}
    post = {"gsteps": [2, 3], "losses": [0.79, 0.7],
            "metric_epochs": [0], "metrics": [0.6], "rank": 1}
    out = _merge_worker_records([pre, post])
    assert out["gsteps"] == [0, 1, 2, 3]
    # the replayed step 2 takes the LATER generation's value
    assert out["losses"] == [1.0, 0.9, 0.79, 0.7]
    assert out["metrics"] == [0.6]
    assert out["pieces"] == 2 and out["rank"] == 1


def test_collect_worker_metrics_orders_stashes_and_skips_torn(tmp_path):
    import json

    from repro.launch.run_local import _collect_worker_metrics

    d = str(tmp_path)
    with open(os.path.join(d, "metrics_worker_0.pre0.json"), "w") as f:
        json.dump({"gsteps": [0], "losses": [1.0], "metrics": []}, f)
    with open(os.path.join(d, "metrics_worker_0.pre1.json"), "w") as f:
        f.write('{"gsteps": [1], "lo')        # torn partial flush
    with open(os.path.join(d, "metrics_worker_0.json"), "w") as f:
        json.dump({"gsteps": [1, 2], "losses": [0.9, 0.8],
                   "metrics": []}, f)
    out = _collect_worker_metrics(d, num_workers=1)
    assert out[0]["losses"] == [1.0, 0.9, 0.8]
    assert out[0]["pieces"] == 2              # the torn piece was skipped


# ---------------------------------------------------------------------------
# mid-run joins on the shard driver (multi-device tier)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_drive_join_grows_layout_and_resharding_is_exact():
    """drive() admits a 5th device at restart@3: the stacked layout grows
    p=4 -> 5, optimizer state is re-sharded at the new count, and the
    moved bytes equal the cost model's join-reshard leg exactly."""
    import jax.numpy as jnp

    from repro.configs.base import get_config, reduced
    from repro.core.hierarchy import SyncConfig
    from repro.launch.shard_driver import drive
    from repro.models.model import build_model
    from repro.optim.sgd import sgd

    model = build_model(reduced(get_config("qwen2-0.5b")))
    k = jax.random.key(0)
    toks = jax.random.randint(k, (20, 32), 0, 1024)   # divides 4 and 5
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    state, hist = drive(model, sgd(0.1, momentum=0.9),
                        SyncConfig(mode="mpi_sgd", num_clients=1),
                        [batch] * 4, p=4, log_every=1,
                        faults="restart@3:unit=4")
    joins = [h for h in hist if h.get("event") == "join"]
    assert len(joins) == 1
    j = joins[0]
    assert j["p_old"] == 4 and j["p_new"] == 5
    assert j["joined"] == (4,) and j["survivors"] == (0, 1, 2, 3)
    # every leaf grew a 5th stacked row
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert leaf.shape[0] == 5
    # growing is a re-shard with every old shard surviving — exact bytes
    assert j["moved_bytes"] == pytest.approx(
        cost_model.join_reshard_bytes(j["state_nbytes"], 4))
    assert j["moved_bytes"] == pytest.approx(j["join_reshard_bytes"])
    assert j["recovery_time"] > 0.0
    # training continued through the join: all 4 steps logged a loss
    losses = [h["loss"] for h in hist if "loss" in h]
    assert len(losses) == 4
    assert all(np.isfinite(l) for l in losses)


# ---------------------------------------------------------------------------
# tcp: real OS processes, real SIGKILLs (recovery-smoke tier)
# ---------------------------------------------------------------------------

@pytest.mark.transport
def test_tcp_kill_respawn_is_bit_identical(tmp_path):
    """The tentpole acceptance gate: SIGKILL a worker mid-run with a
    scheduled respawn and a durable parking cadence — the merged loss
    curve is BIT-IDENTICAL to the fault-free run, with zero degraded
    releases (the respawn made the live barrier)."""
    from repro.launch.run_local import run_job

    clean = run_job(_mini_algo(steps_per_epoch=3), transport="tcp",
                    timeout=240.0)
    res = run_job(
        _mini_algo(steps_per_epoch=3,
                   faults="kill@2:unit=1;restart@2:unit=1",
                   checkpoint_every=1, barrier_timeout=120.0),
        transport="tcp", outdir=str(tmp_path), timeout=300.0)
    assert res.losses == clean.losses
    assert res.metrics == clean.metrics
    assert res.degraded_syncs == 0
    assert len(res.respawns) == 1
    assert res.respawns[0]["scheduled"]
    assert res.exit_history["client_1"][0] == 137
    assert res.exit_history["client_1"][-1] == 0   # the respawn finished


@pytest.mark.transport
def test_tcp_budget_exhaustion_raises_jobfailed(tmp_path):
    """Two SIGKILLs against a budget of one: the job fails LOUDLY with
    the per-unit exit-code history, never hangs."""
    from repro.launch.run_local import run_job

    with pytest.raises(JobFailed, match="client_1") as ei:
        run_job(
            _mini_algo(steps_per_epoch=4, restarts=1,
                       faults="kill@1:unit=1;kill@2:unit=1",
                       checkpoint_every=1, barrier_timeout=120.0),
            transport="tcp", outdir=str(tmp_path), timeout=300.0)
    assert "137" in str(ei.value)
    res = ei.value.result
    assert res is not None
    assert res.exit_history["client_1"] == [137, 137]
    assert res.exhausted == ["client_1"]


@pytest.mark.transport
def test_tcp_server_kill_restores_with_zero_lost_rounds(tmp_path):
    """Kill the KV SERVER right after it durably snapshots step 1: it
    respawns, restores the latest checkpoint, workers ride
    connect_with_retry and re-issue their push+pull pairs — the curve is
    bit-identical and EVERY round's loss lands."""
    from repro.launch.run_local import run_job

    clean = run_job(_mini_algo(steps_per_epoch=3), transport="tcp",
                    timeout=240.0)
    res = run_job(
        _mini_algo(steps_per_epoch=3,
                   server_faults="kill@1:unit=0;restart@1:unit=0",
                   checkpoint_every=1, barrier_timeout=120.0),
        transport="tcp", outdir=str(tmp_path), timeout=300.0)
    assert res.losses == clean.losses          # zero lost rounds
    assert res.metrics == clean.metrics
    assert res.degraded_syncs == 0
    assert len(res.respawns) == 1
    assert res.respawns[0]["role"] == "server"
    st = next(iter(res.server_stats.values()))
    assert st["restored_from"] and st["restored_step"] >= 1
