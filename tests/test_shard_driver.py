"""shard_map production driver (launch/shard_driver.py): the per-device
step — grads computed INSIDE the mapped function, explicit ring
collectives — must match the single-process drivers' losses and states
under vmap emulation, for both lowerable modes and every lowerable
optimizer family (momentum SGD / AdaGrad / AdamW)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.hierarchy import SyncConfig
from repro.launch import shard_driver as SD
from repro.launch.train import make_train_state, make_train_step
from repro.models.model import build_model
from repro.optim.sgd import adagrad, adamw, sgd

# the multi-device CI tier runs these under a forced 8-device host
# platform; they also pass on one device via vmap emulation
pytestmark = pytest.mark.multidevice

# adaptive eps is raised above gradient fp-noise scale (~1e-9): with the
# default eps, coordinates whose true gradient is ~0 get a full ±lr
# first-step update whose SIGN depends on reduction order (ring sum vs
# stacked mean), and one flipped coordinate makes every later gradient —
# and so the whole comparison — diverge chaotically. A larger eps turns
# sub-noise gradients into sub-noise updates without touching the path
# under test.
OPTIMIZERS = {
    "sgd": lambda: sgd(0.1, momentum=0.9),
    "adagrad": lambda: adagrad(0.05, eps=1e-5),
    "adamw": lambda: adamw(3e-3, eps=1e-5),
}


@pytest.fixture(scope="module")
def model():
    return build_model(reduced(get_config("qwen2-0.5b")))


def _batch(B=8, S=32, seed=0):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (B, S), 0, 1024)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def _close(a, b, rtol=2e-4, atol=2e-5):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol),
        a, b)


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
@pytest.mark.parametrize("p", [1, 2, 8])
def test_driver_sgd_matches_single_process(model, p, opt_name):
    """mpi_sgd: p devices, grads reduce-scattered inside the map + the
    fused K-stream update on the 1/p shard, must equal a single-process
    PER-LEAF data-parallel step — same per-shard gradients (adaptive
    optimizers turn any difference in how the gradient itself is computed
    into ±lr sign chaos on ~zero-gradient coordinates, which is not what
    this test guards), per-leaf tree.map update — for every lowerable
    optimizer family."""
    from repro.launch.train import make_grad_fn

    opt = OPTIMIZERS[opt_name]()
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    batch = _batch(B=8)

    grad_fn = make_grad_fn(model)
    ref_params = make_train_state(model, opt, sync, jax.random.key(1),
                                  abstract=False)["params"]
    ref_opt = opt.init(ref_params)

    @jax.jit
    def step_ref(params, opt_state, sbatch):
        losses, _, grads = jax.vmap(lambda b: grad_fn(params, b))(sbatch)
        mean_g = jax.tree.map(lambda g: jnp.mean(g, 0), grads)
        new_p, new_s = opt.update(mean_g, opt_state, params)
        return new_p, new_s, jnp.mean(losses)

    s_drv = SD.make_driver_state(model, opt, sync, p, jax.random.key(1))
    step_drv = jax.jit(SD.make_emulated_step(model, opt, sync, p))

    for _ in range(3):
        sbatch = SD.shard_batch(batch, p)
        ref_params, ref_opt, ref_loss = step_ref(ref_params, ref_opt,
                                                 sbatch)
        s_drv, m_drv = step_drv(s_drv, sbatch)
        assert float(m_drv["loss"]) == pytest.approx(
            float(ref_loss), rel=1e-4)

    # every device allgathered the same updated params == the reference
    # (adaptive updates still amplify ulp-level reduction noise a bit
    # more than SGD's linear ones, hence the slightly wider band)
    tight = (dict(rtol=2e-4, atol=2e-5) if opt_name == "sgd"
             else dict(rtol=2e-3, atol=2e-3))
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], s_drv["params"]),
               ref_params, **tight)
    # optimizer state stays sharded: exactly 1/p of the flat buffer per
    # device, for EVERY full-length stream (AdamW carries two)
    from repro.core import flatbuf as F
    from repro.launch.train import grad_spec

    shard = F.shard_size(grad_spec(model), p, sync.num_rings,
                         sync.bucket_bytes)
    if opt_name == "adamw":
        assert s_drv["opt"]["mv"].shape == (p, 2, shard)
        assert s_drv["opt"]["t"].shape == (p,)
    else:
        assert s_drv["opt"].shape == (p, shard)


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
def test_driver_esgd_matches_multiclient_step(model, opt_name):
    """mpi_esgd: device==client; local fused update (any lowerable
    optimizer) + the sharded flat elastic exchange must equal the
    single-process multiclient step."""
    p = 2
    opt = OPTIMIZERS[opt_name]()
    sync = SyncConfig(mode="mpi_esgd", num_clients=p, esgd_interval=2,
                      esgd_alpha=0.5)
    batch = _batch(B=8)
    cbatch = SD.shard_batch(batch, p)

    s_ref = make_train_state(model, opt, sync, jax.random.key(1))
    step_ref = jax.jit(make_train_step(model, opt, sync, None))
    s_drv = SD.make_driver_state(model, opt, sync, p, jax.random.key(1))
    step_drv = jax.jit(SD.make_emulated_step(model, opt, sync, p))

    for i in range(4):  # crosses two INTERVAL boundaries
        s_ref, m_ref = step_ref(s_ref, cbatch)
        s_drv, m_drv = step_drv(s_drv, cbatch)
        assert float(m_drv["loss"]) == pytest.approx(
            float(m_ref["loss"]), rel=1e-4), i
    # sgd stays tight; adaptive updates amplify reduction-order noise
    tol = dict() if opt_name == "sgd" else dict(rtol=5e-3, atol=5e-4)
    _close(s_drv["params"], s_ref["params"], **tol)
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], s_drv["center"]),
               s_ref["center"], **tol)


def test_driver_esgd_ring_variants_run(model):
    """num_rings / bucket_bytes geometry variants stay equivalent."""
    p = 4
    opt = sgd(0.1, momentum=0.9)
    base = SyncConfig(mode="mpi_esgd", num_clients=p, esgd_interval=1,
                      esgd_alpha=0.5)
    import dataclasses

    variant = dataclasses.replace(base, num_rings=3, bucket_bytes=4096)
    batch = SD.shard_batch(_batch(B=8), p)
    outs = []
    for sync in (base, variant):
        st = SD.make_driver_state(model, opt, sync, p, jax.random.key(2))
        step = jax.jit(SD.make_emulated_step(model, opt, sync, p))
        for _ in range(2):
            st, m = step(st, batch)
        outs.append((st, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-5)
    _close(outs[0][0]["params"], outs[1][0]["params"])


def test_driver_microbatch_equivalence(model):
    """Grad accumulation inside the mapped step (make_grad_fn is shared
    with launch/train.py) matches the unaccumulated step."""
    p = 2
    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    batch = SD.shard_batch(_batch(B=8), p)
    st1 = SD.make_driver_state(model, opt, sync, p, jax.random.key(3))
    st2 = SD.make_driver_state(model, opt, sync, p, jax.random.key(3))
    step1 = jax.jit(SD.make_emulated_step(model, opt, sync, p))
    step2 = jax.jit(SD.make_emulated_step(model, opt, sync, p,
                                          microbatch=2))
    s1, m1 = step1(st1, batch)
    s2, m2 = step2(st2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    _close(s1["params"], s2["params"], rtol=2e-2, atol=2e-4)


def test_driver_loop_learns(model):
    """drive() end-to-end: loss descends under emulation."""
    from repro.data.pipeline import DataConfig, TokenPipeline

    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_esgd", num_clients=2, esgd_interval=4,
                      esgd_alpha=0.5)
    pipe = TokenPipeline(DataConfig(seed=0, vocab_size=256, seq_len=32,
                                    batch_size=8, steps_per_epoch=12))
    _, hist = SD.drive(model, opt, sync, pipe.epoch(0), p=2, log_every=1)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2


def test_driver_rejects_non_flat_optimizer(model):
    import dataclasses

    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    # momentum-less SGD has no flat kernel form; neither does a disabled
    # fused_update. AdamW/AdaGrad are accepted since the K-stream kernels.
    with pytest.raises(ValueError, match="flat fused substrate"):
        SD.make_driver_state(model, sgd(0.1), sync, 2)
    with pytest.raises(ValueError, match="flat fused substrate"):
        SD.make_driver_state(
            model, adamw(1e-3),
            dataclasses.replace(sync, fused_update=False), 2)
    with pytest.raises(ValueError, match="one client per device"):
        SD.make_driver_state(
            model, sgd(0.1, momentum=0.9),
            SyncConfig(mode="mpi_esgd", num_clients=3), 2)


# ---------------------------------------------------------------------------
# 2-axis pod×data hierarchy (the Communicator API's headline layout)
# ---------------------------------------------------------------------------

TWO_AXIS_FACTORIZATIONS = [(2, 4), (4, 2)]


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
@pytest.mark.parametrize("PD", TWO_AXIS_FACTORIZATIONS)
def test_driver_2axis_sgd_matches_1axis(model, PD, opt_name):
    """mpi_sgd on the pod×data hierarchy: the gradient group spans BOTH
    axes (hierarchical reduce-scatter: pod level, then data level on the
    shard) and must equal the 1-axis p=P*D driver — same losses, same
    final params, same 1/(P*D) state shard geometry."""
    P_, D_ = PD
    p = P_ * D_
    opt = OPTIMIZERS[opt_name]()
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    batch = _batch(B=8)

    s1 = SD.make_driver_state(model, opt, sync, p, jax.random.key(1))
    s2 = SD.make_driver_state(model, opt, sync, (P_, D_), jax.random.key(1))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a.shape), np.asarray(b.shape)), s1["opt"], s2["opt"])
    step1 = jax.jit(SD.make_emulated_step(model, opt, sync, p))
    step2 = jax.jit(SD.make_emulated_step(model, opt, sync, (P_, D_)))
    for _ in range(3):
        s1, m1 = step1(s1, SD.shard_batch(batch, p))
        s2, m2 = step2(s2, SD.shard_batch(batch, (P_, D_)))
        assert float(m2["loss"]) == pytest.approx(float(m1["loss"]),
                                                  rel=1e-4)
    tight = (dict(rtol=2e-4, atol=2e-5) if opt_name == "sgd"
             else dict(rtol=5e-3, atol=5e-4))
    _close(jax.tree.map(lambda l: l[0], s2["params"]),
           jax.tree.map(lambda l: l[0], s1["params"]), **tight)


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
@pytest.mark.parametrize("PD", TWO_AXIS_FACTORIZATIONS)
def test_driver_2axis_esgd_matches_multiclient_step(model, PD, opt_name):
    """mpi_esgd on the pod×data hierarchy: client == pod (P clients, D
    devices each; gradient leg confined to 'data', optimizer state 1/D
    per device), elastic exchange across 'pod' — must equal the
    single-process stacked C-client step (C = P), crossing two INTERVAL
    boundaries."""
    P_, D_ = PD
    opt = OPTIMIZERS[opt_name]()
    sync = SyncConfig(mode="mpi_esgd", num_clients=P_, esgd_interval=2,
                      esgd_alpha=0.5)
    batch = _batch(B=8)
    cbatch = SD.shard_batch(batch, P_)

    s_ref = make_train_state(model, opt, sync, jax.random.key(1))
    step_ref = jax.jit(make_train_step(model, opt, sync, None))
    s_drv = SD.make_driver_state(model, opt, sync, (P_, D_),
                                 jax.random.key(1))
    step_drv = jax.jit(SD.make_emulated_step(model, opt, sync, (P_, D_)))

    for i in range(4):
        s_ref, m_ref = step_ref(s_ref, cbatch)
        s_drv, m_drv = step_drv(s_drv, SD.shard_batch(batch, (P_, D_)))
        assert float(m_drv["loss"]) == pytest.approx(
            float(m_ref["loss"]), rel=1e-4), i
    tol = (dict(rtol=2e-4, atol=2e-5) if opt_name == "sgd"
           else dict(rtol=5e-3, atol=5e-4))
    # device d of pod c holds client c's replica (pod-major stacking)
    for c in range(P_):
        _close(jax.tree.map(lambda l: l[c * D_], s_drv["params"]),
               jax.tree.map(lambda l: l[c], s_ref["params"]), **tol)
    _close(jax.tree.map(lambda l: l[0], s_drv["center"]),
           s_ref["center"], **tol)
    # optimizer state sharded over the client's data group: 1/D each
    from repro.core import flatbuf as F
    from repro.launch.train import grad_spec

    shard = F.shard_size(grad_spec(model), D_, sync.num_rings,
                         sync.bucket_bytes)
    opt_leaf = (s_drv["opt"]["mv"] if opt_name == "adamw" else s_drv["opt"])
    assert opt_leaf.shape[-1] == shard


def _ppermute_axis_names(fn, *args, axis_env):
    """All axis names ppermute eqns reference across the jaxpr and every
    sub-jaxpr — the acceptance criterion's inspection primitive.

    Deliberately independent of benchmarks/common.py's jaxpr walk: this
    test is the confinement PROOF that cross-checks the
    BENCH_hierarchy.json gate, so the two must not share plumbing."""
    closed = jax.make_jaxpr(fn, axis_env=axis_env)(*args)

    def subjaxprs(val):
        if hasattr(val, "jaxpr"):
            yield val.jaxpr
        elif hasattr(val, "eqns"):
            yield val
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from subjaxprs(v)

    def walk(jaxpr):
        found = []
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "ppermute":
                ax = eqn.params.get("axis_name")
                found += [ax] if isinstance(ax, str) else list(ax)
            for v in eqn.params.values():
                for sub in subjaxprs(v):
                    found += walk(sub)
        return found

    return set(walk(closed.jaxpr))


def test_2axis_ppermute_axis_confinement(model):
    """PROOF (jaxpr-level) of the hierarchy's traffic separation: in the
    2-axis mpi_esgd programs the gradient leg's ppermutes name ONLY the
    'data' axis and the elastic exchange's ppermutes name ONLY 'pod';
    the 2-axis mpi_sgd gradient group spans both."""
    from repro.core import comm as CM

    P_, D_ = 2, 4
    axis_env = [(SD.POD_AXIS, P_), (SD.DATA_AXIS, D_)]
    opt = sgd(0.1, momentum=0.9)
    batch_dev = jax.tree.map(lambda l: l[0],
                             SD.shard_batch(_batch(B=8), (P_, D_)))

    sync = SyncConfig(mode="mpi_esgd", num_clients=P_, esgd_interval=2)
    world = SD.driver_world(sync, (P_, D_))
    dev_step, dev_ex = SD.make_device_step(model, opt, sync, world=world)
    state_dev = jax.tree.map(
        lambda l: l[0], SD.make_driver_state(model, opt, sync, (P_, D_)))

    grad_axes = _ppermute_axis_names(dev_step, state_dev, batch_dev,
                                     axis_env=axis_env)
    assert grad_axes == {SD.DATA_AXIS}, grad_axes
    ex_axes = _ppermute_axis_names(dev_ex, state_dev, axis_env=axis_env)
    assert ex_axes == {SD.POD_AXIS}, ex_axes

    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    world = SD.driver_world(sync, (P_, D_))
    dev_step, dev_ex = SD.make_device_step(model, opt, sync, world=world)
    assert dev_ex is None
    state_dev = jax.tree.map(
        lambda l: l[0], SD.make_driver_state(model, opt, sync, (P_, D_)))
    grad_axes = _ppermute_axis_names(dev_step, state_dev, batch_dev,
                                     axis_env=axis_env)
    assert grad_axes == {SD.POD_AXIS, SD.DATA_AXIS}, grad_axes


# ---------------------------------------------------------------------------
# 2-axis driver vs the six-mode simulation (core/algorithms.py)
# ---------------------------------------------------------------------------

def _sim_setup(model, opt_name, mode, P_, D_, steps, interval, epochs=1):
    """Drive algorithms.run with the SAME model, init, and per-worker
    batch shards as the 2-axis driver: worker w of client c gets device
    (c, w % D)'s shard — the layouts coincide."""
    import dataclasses as DC

    from repro.core.algorithms import AlgoConfig, run as run_algo
    from repro.launch.train import make_grad_fn

    p = P_ * D_
    lr = dict(sgd=0.1, adamw=3e-3)[opt_name]

    def full_batch(epoch, step):
        k = jax.random.key(7000 + epoch * 131 + step)
        toks = jax.random.randint(k, (2 * p, 32), 0, 1024)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    gf = make_grad_fn(model)
    grad_fn = jax.jit(lambda prm, b: gf(prm, b)[::2])  # (loss, grads)

    class _Pipe:
        def __init__(self, w):
            self.w = w

        def batch_at(self, epoch, step):
            return jax.tree.map(lambda a: a[self.w],
                                SD.shard_batch(full_batch(epoch, step), p))

    cfg = AlgoConfig(
        mode=mode, num_workers=p, num_clients=P_, num_servers=1,
        lr=lr, momentum=0.9, optimizer=opt_name,
        esgd_alpha=0.5, esgd_interval=interval,
        epochs=epochs, steps_per_epoch=steps, jitter=0.0,
        allreduce_method="multi_ring", seed=0)
    hist = run_algo(cfg, lambda key: model.init(jax.random.key(1)),
                    grad_fn, lambda prm: 0.0, _Pipe)
    return hist, full_batch, lr


def _drive_2axis(model, opt_name, sync, P_, D_, steps, full_batch, lr):
    opt = {"sgd": lambda: sgd(lr, momentum=0.9),
           "adamw": lambda: adamw(lr)}[opt_name]()
    st = SD.make_driver_state(model, opt, sync, (P_, D_), jax.random.key(1))
    step = jax.jit(SD.make_emulated_step(model, opt, sync, (P_, D_)))
    losses = []
    for e in range(steps[0]):
        for s in range(steps[1]):
            st, m = step(st, SD.shard_batch(full_batch(e, s), (P_, D_)))
            losses.append(float(m["loss"]))
    return losses


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_2axis_sgd_matches_six_mode_simulation(model, opt_name):
    """pod×data mpi_sgd == the six-mode simulation's mpi_sgd (KVStore
    push/pull through registered worker groups) step for step: the
    sim's worker w IS device (pod, data) = divmod(w, D), the group
    collective is the data leg, the PS barrier the pod leg."""
    P_, D_, steps = 2, 4, 4
    hist, full_batch, lr = _sim_setup(model, opt_name, "mpi_sgd",
                                      P_, D_, steps, interval=64)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    losses = _drive_2axis(model, opt_name, sync, P_, D_, (1, steps),
                          full_batch, lr)
    assert len(hist.losses) == steps
    for i, (a, b) in enumerate(zip(losses, hist.losses)):
        assert a == pytest.approx(b, rel=1e-3), (i, losses, hist.losses)


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_2axis_esgd_matches_six_mode_simulation(model, opt_name):
    """pod×data mpi_esgd == the six-mode simulation's mpi_esgd over one
    INTERVAL window (where the exchange semantics provably coincide:
    the step-0 exchange is a no-op from identical init), epoch-mean
    losses; and stays within a few percent across the next window,
    where the sim's sequential per-client server rule and the driver's
    simultaneous summed exchange legitimately differ at O(alpha^2)."""
    P_, D_, steps, interval = 2, 4, 4, 4
    hist, full_batch, lr = _sim_setup(model, opt_name, "mpi_esgd",
                                      P_, D_, steps, interval, epochs=2)
    sync = SyncConfig(mode="mpi_esgd", num_clients=P_,
                      esgd_interval=interval, esgd_alpha=0.5)
    losses = _drive_2axis(model, opt_name, sync, P_, D_, (2, steps),
                          full_batch, lr)
    drv_epoch1 = float(np.mean(losses[:steps]))
    drv_epoch2 = float(np.mean(losses[steps:]))
    assert drv_epoch1 == pytest.approx(hist.losses[0], rel=1e-3)
    assert drv_epoch2 == pytest.approx(hist.losses[1], rel=5e-2)
