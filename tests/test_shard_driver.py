"""shard_map production driver (launch/shard_driver.py): the per-device
step — grads computed INSIDE the mapped function, explicit ring
collectives — must match the single-process drivers' losses and states
under vmap emulation, for both lowerable modes and every lowerable
optimizer family (momentum SGD / AdaGrad / AdamW)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.hierarchy import SyncConfig
from repro.launch import shard_driver as SD
from repro.launch.train import make_train_state, make_train_step
from repro.models.model import build_model
from repro.optim.sgd import adagrad, adamw, sgd

# the multi-device CI tier runs these under a forced 8-device host
# platform; they also pass on one device via vmap emulation
pytestmark = pytest.mark.multidevice

# adaptive eps is raised above gradient fp-noise scale (~1e-9): with the
# default eps, coordinates whose true gradient is ~0 get a full ±lr
# first-step update whose SIGN depends on reduction order (ring sum vs
# stacked mean), and one flipped coordinate makes every later gradient —
# and so the whole comparison — diverge chaotically. A larger eps turns
# sub-noise gradients into sub-noise updates without touching the path
# under test.
OPTIMIZERS = {
    "sgd": lambda: sgd(0.1, momentum=0.9),
    "adagrad": lambda: adagrad(0.05, eps=1e-5),
    "adamw": lambda: adamw(3e-3, eps=1e-5),
}


@pytest.fixture(scope="module")
def model():
    return build_model(reduced(get_config("qwen2-0.5b")))


def _batch(B=8, S=32, seed=0):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (B, S), 0, 1024)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def _close(a, b, rtol=2e-4, atol=2e-5):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol),
        a, b)


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
@pytest.mark.parametrize("p", [1, 2, 8])
def test_driver_sgd_matches_single_process(model, p, opt_name):
    """mpi_sgd: p devices, grads reduce-scattered inside the map + the
    fused K-stream update on the 1/p shard, must equal a single-process
    PER-LEAF data-parallel step — same per-shard gradients (adaptive
    optimizers turn any difference in how the gradient itself is computed
    into ±lr sign chaos on ~zero-gradient coordinates, which is not what
    this test guards), per-leaf tree.map update — for every lowerable
    optimizer family."""
    from repro.launch.train import make_grad_fn

    opt = OPTIMIZERS[opt_name]()
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    batch = _batch(B=8)

    grad_fn = make_grad_fn(model)
    ref_params = make_train_state(model, opt, sync, jax.random.key(1),
                                  abstract=False)["params"]
    ref_opt = opt.init(ref_params)

    @jax.jit
    def step_ref(params, opt_state, sbatch):
        losses, _, grads = jax.vmap(lambda b: grad_fn(params, b))(sbatch)
        mean_g = jax.tree.map(lambda g: jnp.mean(g, 0), grads)
        new_p, new_s = opt.update(mean_g, opt_state, params)
        return new_p, new_s, jnp.mean(losses)

    s_drv = SD.make_driver_state(model, opt, sync, p, jax.random.key(1))
    step_drv = jax.jit(SD.make_emulated_step(model, opt, sync, p))

    for _ in range(3):
        sbatch = SD.shard_batch(batch, p)
        ref_params, ref_opt, ref_loss = step_ref(ref_params, ref_opt,
                                                 sbatch)
        s_drv, m_drv = step_drv(s_drv, sbatch)
        assert float(m_drv["loss"]) == pytest.approx(
            float(ref_loss), rel=1e-4)

    # every device allgathered the same updated params == the reference
    # (adaptive updates still amplify ulp-level reduction noise a bit
    # more than SGD's linear ones, hence the slightly wider band)
    tight = (dict(rtol=2e-4, atol=2e-5) if opt_name == "sgd"
             else dict(rtol=2e-3, atol=2e-3))
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], s_drv["params"]),
               ref_params, **tight)
    # optimizer state stays sharded: exactly 1/p of the flat buffer per
    # device, for EVERY full-length stream (AdamW carries two)
    from repro.core import flatbuf as F
    from repro.launch.train import grad_spec

    shard = F.shard_size(grad_spec(model), p, sync.num_rings,
                         sync.bucket_bytes)
    if opt_name == "adamw":
        assert s_drv["opt"]["mv"].shape == (p, 2, shard)
        assert s_drv["opt"]["t"].shape == (p,)
    else:
        assert s_drv["opt"].shape == (p, shard)


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
def test_driver_esgd_matches_multiclient_step(model, opt_name):
    """mpi_esgd: device==client; local fused update (any lowerable
    optimizer) + the sharded flat elastic exchange must equal the
    single-process multiclient step."""
    p = 2
    opt = OPTIMIZERS[opt_name]()
    sync = SyncConfig(mode="mpi_esgd", num_clients=p, esgd_interval=2,
                      esgd_alpha=0.5)
    batch = _batch(B=8)
    cbatch = SD.shard_batch(batch, p)

    s_ref = make_train_state(model, opt, sync, jax.random.key(1))
    step_ref = jax.jit(make_train_step(model, opt, sync, None))
    s_drv = SD.make_driver_state(model, opt, sync, p, jax.random.key(1))
    step_drv = jax.jit(SD.make_emulated_step(model, opt, sync, p))

    for i in range(4):  # crosses two INTERVAL boundaries
        s_ref, m_ref = step_ref(s_ref, cbatch)
        s_drv, m_drv = step_drv(s_drv, cbatch)
        assert float(m_drv["loss"]) == pytest.approx(
            float(m_ref["loss"]), rel=1e-4), i
    # sgd stays tight; adaptive updates amplify reduction-order noise
    tol = dict() if opt_name == "sgd" else dict(rtol=5e-3, atol=5e-4)
    _close(s_drv["params"], s_ref["params"], **tol)
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], s_drv["center"]),
               s_ref["center"], **tol)


def test_driver_esgd_ring_variants_run(model):
    """num_rings / bucket_bytes geometry variants stay equivalent."""
    p = 4
    opt = sgd(0.1, momentum=0.9)
    base = SyncConfig(mode="mpi_esgd", num_clients=p, esgd_interval=1,
                      esgd_alpha=0.5)
    import dataclasses

    variant = dataclasses.replace(base, num_rings=3, bucket_bytes=4096)
    batch = SD.shard_batch(_batch(B=8), p)
    outs = []
    for sync in (base, variant):
        st = SD.make_driver_state(model, opt, sync, p, jax.random.key(2))
        step = jax.jit(SD.make_emulated_step(model, opt, sync, p))
        for _ in range(2):
            st, m = step(st, batch)
        outs.append((st, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-5)
    _close(outs[0][0]["params"], outs[1][0]["params"])


def test_driver_microbatch_equivalence(model):
    """Grad accumulation inside the mapped step (make_grad_fn is shared
    with launch/train.py) matches the unaccumulated step."""
    p = 2
    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    batch = SD.shard_batch(_batch(B=8), p)
    st1 = SD.make_driver_state(model, opt, sync, p, jax.random.key(3))
    st2 = SD.make_driver_state(model, opt, sync, p, jax.random.key(3))
    step1 = jax.jit(SD.make_emulated_step(model, opt, sync, p))
    step2 = jax.jit(SD.make_emulated_step(model, opt, sync, p,
                                          microbatch=2))
    s1, m1 = step1(st1, batch)
    s2, m2 = step2(st2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    _close(s1["params"], s2["params"], rtol=2e-2, atol=2e-4)


def test_driver_loop_learns(model):
    """drive() end-to-end: loss descends under emulation."""
    from repro.data.pipeline import DataConfig, TokenPipeline

    opt = sgd(0.1, momentum=0.9)
    sync = SyncConfig(mode="mpi_esgd", num_clients=2, esgd_interval=4,
                      esgd_alpha=0.5)
    pipe = TokenPipeline(DataConfig(seed=0, vocab_size=256, seq_len=32,
                                    batch_size=8, steps_per_epoch=12))
    _, hist = SD.drive(model, opt, sync, pipe.epoch(0), p=2, log_every=1)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2


def test_driver_rejects_non_flat_optimizer(model):
    import dataclasses

    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    # momentum-less SGD has no flat kernel form; neither does a disabled
    # fused_update. AdamW/AdaGrad are accepted since the K-stream kernels.
    with pytest.raises(ValueError, match="flat fused substrate"):
        SD.make_driver_state(model, sgd(0.1), sync, 2)
    with pytest.raises(ValueError, match="flat fused substrate"):
        SD.make_driver_state(
            model, adamw(1e-3),
            dataclasses.replace(sync, fused_update=False), 2)
    with pytest.raises(ValueError, match="one client per device"):
        SD.make_driver_state(
            model, sgd(0.1, momentum=0.9),
            SyncConfig(mode="mpi_esgd", num_clients=3), 2)
