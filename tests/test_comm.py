"""Communicator/Group API (core/comm.py): the paper's MPI-groups model.

Covers the group algebra (world/split/complement/local), the policy
ownership, hierarchical multi-axis collectives, the KVStore group
embedding, and the deprecation shims that keep bare ``axis_name=``
string signatures working.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives as C, comm as CM, flatbuf as F
from repro.core.hierarchy import SyncConfig


def _tree(key=0, leaves=4, n=513):
    ks = jax.random.split(jax.random.key(key), leaves)
    return {f"l{i}": jax.random.normal(k, (n,)) for i, k in enumerate(ks)}


def _stack(tree, n):
    return jax.tree.map(
        lambda l: jnp.stack([l * (i + 1) for i in range(n)]), tree)


# ---------------------------------------------------------------------------
# group algebra
# ---------------------------------------------------------------------------

def test_world_split_complement_local():
    w = CM.Communicator.world(("pod", "data"), (2, 4), method="multi_ring",
                              num_rings=3, bucket_bytes=1024)
    assert w.static_size == 8 and w.backend == "named_axis"
    d = w.split("data")
    assert d.axes == ("data",) and d.sizes == (4,)
    # policy is inherited through the split (the MPI_Comm_split model)
    assert d.method == "multi_ring" and d.num_rings == 3
    assert d.bucket_bytes == 1024
    assert w.complement("pod") == d
    p = w.split("pod")
    assert p.axes == ("pod",) and p.static_size == 2
    loc = w.local()
    assert loc.is_trivial and loc.static_size == 1
    assert loc.backend == "trivial" and loc.method == "multi_ring"


def test_split_unknown_axis_raises():
    w = CM.Communicator.world(("pod", "data"), (2, 4))
    with pytest.raises(ValueError, match="cannot split"):
        w.split("model")


def test_world_size_mismatch_raises():
    with pytest.raises(ValueError, match="axes but"):
        CM.Communicator.world(("pod", "data"), (2,))


def test_from_axis_name_adapter():
    c = CM.Communicator.from_axis_name(None)
    assert c.is_trivial and c.resolve_size() == 1
    c = CM.Communicator.from_axis_name("dev", num_rings=2)
    assert c.axes == ("dev",) and c.sizes is None and c.num_rings == 2


def test_from_sync_recipe():
    sync = SyncConfig(allreduce_method="multi_ring", num_rings=4,
                      bucket_bytes=2048)
    c = CM.from_sync(sync, ("dev",), (8,))
    assert c.method == "multi_ring" and c.num_rings == 4
    assert c.bucket_bytes == 2048 and c.static_size == 8


def test_sync_comms_algebra():
    w = CM.Communicator.world(("pod", "data"), (2, 4))
    g, e = CM.sync_comms(SyncConfig(mode="mpi_sgd"), w)
    assert g == w and e is None
    g, e = CM.sync_comms(SyncConfig(mode="mpi_esgd", num_clients=2), w)
    assert g.axes == ("data",) and e.axes == ("pod",)
    # 1-axis world: device == client (the axis plays the pod role)
    w1 = CM.Communicator.world(("dev",), (4,))
    g, e = CM.sync_comms(SyncConfig(mode="mpi_esgd", num_clients=4), w1)
    assert g.is_trivial and e == w1


# ---------------------------------------------------------------------------
# collectives: hierarchical multi-axis == flat reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["ring", "multi_ring", "tree", "psum",
                                    "scatter_gather"])
def test_2axis_allreduce_matches_flat_sum(method):
    w = CM.Communicator.world(("pod", "data"), (2, 4), method=method,
                              num_rings=2)
    x = jax.random.normal(jax.random.key(0), (2, 4, 1000))
    out = jax.vmap(jax.vmap(w.allreduce, axis_name="data"),
                   axis_name="pod")(x)
    want = jnp.sum(x, axis=(0, 1))
    np.testing.assert_allclose(out[1, 2], want, rtol=2e-5, atol=2e-5)


def test_2axis_reduce_scatter_allgather_roundtrip():
    w = CM.Communicator.world(("pod", "data"), (2, 2), num_rings=2)
    n = 2048
    x = jax.random.normal(jax.random.key(1), (2, 2, n))

    def dev(v):
        shard = w.reduce_scatter(v)
        assert shard.size == n // 4  # 1/(P*D) — single-axis geometry
        sel = w.shard_select(v)
        assert sel.shape == shard.shape
        return w.allgather(shard), sel

    full, _ = jax.vmap(jax.vmap(dev, axis_name="data"),
                       axis_name="pod")(x)
    want = jnp.sum(x, axis=(0, 1))
    for i in range(2):
        for j in range(2):
            np.testing.assert_allclose(full[i, j][:n], want,
                                       rtol=2e-5, atol=2e-4)


def test_2axis_shard_select_pairs_with_reduce_scatter():
    """shard_select of a replicated buffer lands on exactly the slice
    reduce_scatter leaves on the same device (the fused step pairs
    params with grads this way)."""
    w = CM.Communicator.world(("pod", "data"), (2, 2))
    n = 1024
    x = jax.random.normal(jax.random.key(2), (n,))
    stacked = jnp.broadcast_to(x, (2, 2, n))

    def dev(v):
        return w.reduce_scatter(v), w.shard_select(v)

    rs, sel = jax.vmap(jax.vmap(dev, axis_name="data"),
                       axis_name="pod")(stacked)
    # replicated input: the reduced shard is 4x the selected one
    np.testing.assert_allclose(rs, 4.0 * sel, rtol=2e-5, atol=2e-5)


def test_tensor_allreduce_via_comm_matches_per_leaf():
    tree = _tree()
    stacked = _stack(tree, 4)
    fused = CM.Communicator.world(("r",), (4,), method="multi_ring")
    leaf = fused.with_policy(method="per_leaf")
    a = fused.emulate_reduce(stacked)
    b = leaf.emulate_reduce(stacked)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=2e-5,
                                                         atol=2e-5), a, b)


def test_pushpull_fused_vs_tree():
    tree = _tree(3)
    stacked = _stack(tree, 4)
    group = CM.Communicator.world(("r",), (4,))
    fused = jax.vmap(lambda t: group.pushpull(t), axis_name="r")(stacked)
    unfused = jax.vmap(lambda t: group.pushpull(t, fused=False),
                       axis_name="r")(stacked)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=2e-5,
                                                         atol=2e-5),
                 fused, unfused)


def test_trivial_comm_everything_is_identity():
    c = CM.LOCAL
    x = jnp.arange(8.0)
    assert c.allreduce(x) is x or np.allclose(c.allreduce(x), x)
    np.testing.assert_allclose(c.reduce_scatter(x), x)
    np.testing.assert_allclose(c.allgather(x), x)
    np.testing.assert_allclose(c.shard_select(x), x)
    tree = {"a": x}
    out = c.emulate_reduce(tree)
    np.testing.assert_allclose(out["a"], x)


def test_rings_policy_resolution():
    c = CM.Communicator(policy=CM.CollectivePolicy(num_rings=2,
                                                   bucket_bytes=1024))
    assert c.rings_for(8 * 1024) == 8  # bucketing wins
    assert c.rings_for(1024) == 2      # explicit ring count wins


# ---------------------------------------------------------------------------
# axis_name strings were removed: hard error naming the comm= replacement
# ---------------------------------------------------------------------------

def _deprecations(rec):
    return [r for r in rec if issubclass(r.category, DeprecationWarning)]


def test_tensor_allreduce_axis_name_removed():
    tree = _tree(5)
    stacked = _stack(tree, 4)
    with pytest.raises(ValueError, match="Communicator.from_axis_name"):
        C.emulate(C.tensor_allreduce, stacked, method="multi_ring")
    # the comm= spelling is the one path
    group = CM.Communicator.world(
        ("ring",), (4,),
        policy=CM.CollectivePolicy(method="multi_ring", num_rings=2))
    new = group.emulate_reduce(stacked)
    want = jax.tree.map(lambda l: jnp.broadcast_to(jnp.sum(l, 0), l.shape),
                        stacked)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=2e-5,
                                                         atol=2e-5),
                 new, want)


def test_tensor_pushpull_axis_name_removed():
    tree = _tree(6)
    stacked = _stack(tree, 2)
    with pytest.raises(ValueError, match="Communicator.from_axis_name"):
        C.emulate(C.tensor_pushpull, stacked, fused=False)
    group = CM.Communicator.world(("ring",), (2,))
    out = jax.vmap(lambda t: C.tensor_pushpull(t, group, fused=False),
                   axis_name="ring")(stacked)
    want = jax.tree.map(lambda l: jnp.mean(l, 0), stacked)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        x[0], y, rtol=2e-5, atol=2e-5), out, want)
    # fused=False still rejects a non-tree method
    with pytest.raises(ValueError, match="only meaningful"):
        C.tensor_pushpull(tree, group, fused=False, method="multi_ring")


def test_scatter_update_gather_axis_name_removed():
    from repro.optim.sgd import momentum_shard_init, scatter_update_gather

    tree = _tree(7, leaves=3, n=257)
    spec = F.spec_for(tree)
    with pytest.raises(ValueError, match="Communicator.from_axis_name"):
        scatter_update_gather(spec, tree, tree, momentum_shard_init(spec),
                              0.1, 0.9, axis_name="d")


def test_scatter_update_gather_rejects_comm_with_axis_name():
    from repro.optim.sgd import momentum_shard_init, scatter_update_gather

    tree = _tree(8, leaves=2, n=129)
    spec = F.spec_for(tree)
    with pytest.raises(ValueError, match="Communicator.from_axis_name"):
        scatter_update_gather(spec, tree, tree, momentum_shard_init(spec),
                              0.1, 0.9, comm=CM.LOCAL, axis_name="d")


def test_elastic_exchange_sharded_axis_name_removed():
    from repro.core.elastic import elastic_exchange_sharded

    tree = _tree(9, leaves=3, n=257)
    center = jax.tree.map(lambda l: l * 0.5, tree)
    spec = F.spec_for(tree)
    p = 2
    sw = _stack(tree, p)
    sc = jax.tree.map(lambda l: jnp.stack([l] * p), center)

    with pytest.raises(ValueError, match="Communicator.from_axis_name"):
        elastic_exchange_sharded(spec, tree, center, 0.25, axis_name="d")

    group = CM.Communicator.world(("d",), (p,))
    new = lambda w, c: elastic_exchange_sharded(spec, w, c, 0.25, comm=group)
    nw, nc = jax.vmap(new, axis_name="d")(sw, sc)
    # eq. 2/3: every member pulls toward the center it sees, and the
    # exchanged center is identical across members
    jax.tree.map(lambda l: np.testing.assert_allclose(l[0], l[1], rtol=1e-6),
                 nc)
    jax.tree.map(
        lambda got, w, c: np.testing.assert_allclose(
            got, w - 0.25 * (w - c), rtol=1e-5, atol=1e-6),
        nw, sw, sc)


def test_canonical_paths_stay_quiet():
    """The re-routed internal call sites never hit the shims: building
    engines/steps through the comm API must not emit DeprecationWarning."""
    from repro.core.sync_engine import make_sync_engine
    from repro.optim.sgd import flat_sgd

    tree = _tree(10, leaves=2, n=129)
    spec = F.spec_for(tree)
    sync = SyncConfig(mode="mpi_sgd")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = make_sync_engine(flat_sgd(0.1, 0.9, spec), sync, None,
                               spec=spec)
        opt0 = eng.init_opt(tree)
        eng.update(tree, opt0, tree)
    assert not _deprecations(rec), [str(r.message) for r in rec]


# ---------------------------------------------------------------------------
# KVStore group embedding
# ---------------------------------------------------------------------------

def test_kvstore_register_group_and_group_push():
    from repro.core.kvstore import KVStore

    kv = KVStore.create("sync_mpi", num_workers=4, num_clients=2)
    group = CM.Communicator.world(("worker",), (2,))
    kv.register_group(0, group)
    kv.register_group(1, group)
    tree = {"w": jnp.ones((4,))}
    kv.init("grads", jax.tree.map(jnp.zeros_like, tree))
    # each client pushes its stacked member grads; the group collective
    # reduces them in-store, the PS barrier spans the two groups
    for c in range(2):
        stacked = {"w": jnp.stack([jnp.full((4,), c + 1.0),
                                   jnp.full((4,), c + 2.0)])}
        kv.push("grads", stacked, group=c)
    total = kv.pull("grads")[0]
    # client0: 1+2, client1: 2+3 -> 8 per coordinate
    np.testing.assert_allclose(total["w"], 8.0 * jnp.ones((4,)))
    assert kv.group_sync_count[0] == 1 and kv.group_sync_count[1] == 1


def test_kvstore_group_pushpull_async():
    from repro.core.kvstore import KVStore

    kv = KVStore.create("async_mpi", num_workers=2, num_clients=1)
    kv.register_group(0, CM.Communicator.world(("worker",), (2,)))
    kv.init("v", jnp.zeros((3,)))
    out = kv.pushpull("v", jnp.stack([jnp.ones(3), 2 * jnp.ones(3)]),
                      group=0)
    np.testing.assert_allclose(out[0], 3.0 * jnp.ones(3))


def test_kvstore_group_errors():
    from repro.core.kvstore import KVStore

    kv = KVStore.create("sync_mpi", num_workers=2, num_clients=2)
    kv.init("g", jnp.zeros(2))
    with pytest.raises(TypeError, match="Communicator"):
        kv.register_group(0, "worker")
    with pytest.raises(KeyError, match="register_group"):
        kv.push("g", jnp.zeros((1, 2)), group=7)


# ---------------------------------------------------------------------------
# SyncConfig.validate (the actionable-error satellite)
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


def test_validate_missing_pod_axis_is_actionable():
    sync = SyncConfig(mode="mpi_esgd", num_clients=4)
    with pytest.raises(ValueError) as ei:
        sync.validate(_FakeMesh(data=8))
    msg = str(ei.value)
    assert "'pod' mesh axis" in msg and "make_mesh" in msg
    assert "num_clients=4" in msg


def test_validate_pod_size_mismatch():
    sync = SyncConfig(mode="mpi_esgd", num_clients=2)
    with pytest.raises(ValueError, match="pod' axis size 4"):
        sync.validate(_FakeMesh(pod=4, data=2))
    sync.validate(_FakeMesh(pod=2, data=2))  # matching config passes
    sync.validate(None)                       # no mesh: emulation is fine


def test_validate_unknown_method():
    with pytest.raises(ValueError, match="allreduce_method"):
        SyncConfig(allreduce_method="nccl").validate(None)


def test_train_step_validates_mesh_early():
    """make_train_step surfaces the client/mesh mismatch BEFORE tracing
    (it used to blow up deep inside shard_map as a shape error)."""
    from repro.configs.base import get_config, reduced
    from repro.launch.train import make_train_step
    from repro.models.model import build_model
    from repro.optim.sgd import sgd

    model = build_model(reduced(get_config("qwen2-0.5b")))
    sync = SyncConfig(mode="mpi_esgd", num_clients=2)
    with pytest.raises(ValueError, match="'pod' mesh axis"):
        make_train_step(model, sgd(0.1, momentum=0.9), sync,
                        _FakeMesh(data=1))


def test_shard_geometry_honors_bucket_policy():
    """Communicator.shard_geometry agrees with the real sharding call
    sites (optstate_shard_init / reduce_scatter) when bucket_bytes is
    set — both resolve the ring count through rings_for."""
    c = CM.Communicator.world(("d",), (4,), num_rings=1, bucket_bytes=1024)
    n = 4096  # 16 KiB of f32 -> 16 buckets
    shard, total = c.shard_geometry(n)
    nr = c.rings_for(n * 4)
    from repro.core.flatbuf import shard_geometry as fg

    _, want_total = fg(n, 4, nr)
    assert (shard, total) == (want_total // 4, want_total)


def test_group_allreduce_honors_bucket_policy():
    """bucket_bytes is not a silent no-op on the group allreduce: the
    bucketed schedule emits more (smaller) ppermute hops, same result."""
    plain = CM.Communicator.world(("d",), (4,), method="multi_ring",
                                  num_rings=1)
    bucketed = plain.with_policy(bucket_bytes=1024)
    x = jax.random.normal(jax.random.key(0), (4, 4096))

    def count_ppermutes(comm):
        jaxpr = jax.make_jaxpr(comm.allreduce, axis_env=[("d", 4)])(x[0])
        return sum(e.primitive.name == "ppermute" for e in jaxpr.eqns)

    assert count_ppermutes(bucketed) > count_ppermutes(plain)
    a = jax.vmap(plain.allreduce, axis_name="d")(x)
    b = jax.vmap(bucketed.allreduce, axis_name="d")(x)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_sharded_legs_default_to_full_ring_policy():
    """reduce_scatter/shard_select with no explicit num_rings resolve
    the ring count through rings_for — so a bucket_bytes policy yields
    shards that agree with shard_geometry / optstate_shard_init, and
    allgather (resolving from the full-buffer bytes) inverts them."""
    c = CM.Communicator.world(("d",), (4,), num_rings=1, bucket_bytes=1024)
    n = 4096
    shard_len, total = c.shard_geometry(n)
    x = jax.random.normal(jax.random.key(3), (4, total))

    def dev(v):
        rs = c.reduce_scatter(v)
        assert rs.size == shard_len, (rs.size, shard_len)
        sel = c.shard_select(v)
        assert sel.shape == rs.shape
        return c.allgather(rs)

    full = jax.vmap(dev, axis_name="d")(x)
    np.testing.assert_allclose(full[2], jnp.sum(x, 0), rtol=2e-5, atol=2e-4)


def _ppermute_bytes_of(fn, x, axis_env):
    jaxpr = jax.make_jaxpr(fn, axis_env=axis_env)(x)
    return sum(
        sum(v.aval.size * v.aval.dtype.itemsize for v in e.invars)
        for e in jaxpr.eqns if e.primitive.name == "ppermute")


def test_2axis_ring_allreduce_at_one_axis_byte_cost():
    """The multi-axis ring allreduce composes hierarchical
    reduce-scatter + allgather, telescoping to EXACTLY the 1-axis
    ring's wire bytes (a per-axis allreduce loop would cost ~43% more
    at (2, 4))."""
    n = 4096
    x = jnp.ones((n,))
    two = CM.Communicator.world(("pod", "data"), (2, 4), method="ring")
    one = CM.Communicator.world(("dev",), (8,), method="ring")
    b2 = _ppermute_bytes_of(two.allreduce, x,
                            [("pod", 2), ("data", 4)])
    b1 = _ppermute_bytes_of(one.allreduce, x, [("dev", 8)])
    assert b2 == b1, (b2, b1)


def test_comm_plus_ring_knobs_raises():
    """Explicit num_rings/bucket_bytes alongside comm= is rejected (the
    policy lives on the communicator) instead of silently ignored."""
    from repro.core.elastic import elastic_exchange_sharded
    from repro.optim.sgd import momentum_shard_init, scatter_update_gather

    tree = _tree(11, leaves=2, n=129)
    spec = F.spec_for(tree)
    m = momentum_shard_init(spec)
    with pytest.raises(ValueError, match="policy lives on the communicator"):
        scatter_update_gather(spec, tree, tree, m, 0.1, 0.9,
                              comm=CM.LOCAL, num_rings=4)
    with pytest.raises(ValueError, match="policy lives on the communicator"):
        elastic_exchange_sharded(spec, tree, tree, 0.25, comm=CM.LOCAL,
                                 bucket_bytes=512)


def test_kvstore_group_reduce_multi_axis_hierarchy():
    """A multi-axis (pod×data) communicator registered whole reduces the
    flat member dim correctly: the store reshapes it to the group's axis
    sizes before the nested per-axis emulation."""
    from repro.core.kvstore import KVStore

    kv = KVStore.create("sync_mpi", num_workers=4, num_clients=1)
    kv.register_group(0, CM.Communicator.world(("pod", "data"), (2, 2)))
    stacked = {"w": jnp.stack([jnp.full((6,), float(i)) for i in range(4)])}
    out = kv.group_reduce(0, stacked)
    np.testing.assert_allclose(out["w"], 6.0 * jnp.ones((6,)))  # 0+1+2+3
    # member-count mismatch is rejected with an actionable error
    with pytest.raises(ValueError, match="stacked members"):
        kv.group_reduce(0, {"w": jnp.zeros((3, 6))})
    # groups without static sizes cannot be emulated in-process
    with pytest.raises(ValueError, match="static sizes"):
        kv.register_group(1, CM.Communicator.from_axis_name("worker"))


def test_tensor_collectives_reject_knobs_with_communicator():
    """tensor_allreduce/tensor_pushpull match the sibling entry points'
    contract: explicit method/num_rings alongside a Communicator raise
    instead of being silently dropped."""
    tree = _tree(12, leaves=2, n=65)
    group = CM.Communicator.world(("r",), (2,))
    with pytest.raises(ValueError, match="policy"):
        C.tensor_allreduce(tree, group, method="tree")
    with pytest.raises(ValueError, match="policy"):
        C.tensor_pushpull(tree, group, num_rings=4)
