"""KVStore-MPI API semantics (paper §3.2/§4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kvstore import KVStore, local_reduce
from repro.optim.sgd import sgd


def test_init_and_pull_broadcast():
    kv = KVStore.create("dist_sync", num_workers=3)
    kv.init("w", jnp.arange(4.0))
    vals = kv.pull("w", num_dst=2)
    assert len(vals) == 2
    np.testing.assert_allclose(vals[0], jnp.arange(4.0))


def test_double_init_raises():
    kv = KVStore.create("local")
    kv.init("w", jnp.zeros(2))
    with pytest.raises(KeyError):
        kv.init("w", jnp.zeros(2))


def test_push_uninitialized_raises():
    kv = KVStore.create("local")
    with pytest.raises(KeyError):
        kv.push("nope", jnp.zeros(2))


def test_sync_barrier_blocks_pull_until_all_push():
    kv = KVStore.create("dist_sync", num_workers=2)
    kv.init("g", jnp.zeros(3))
    kv.push("g", jnp.ones(3))
    with pytest.raises(RuntimeError):
        kv.pull("g")
    kv.push("g", 2 * jnp.ones(3))
    np.testing.assert_allclose(kv.pull("g")[0], 3 * jnp.ones(3))


def test_sync_mpi_expects_client_count_not_worker_count():
    kv = KVStore.create("sync_mpi", num_workers=6, num_clients=2)
    assert kv.expected_pushers == 2
    kv.init("g", jnp.zeros(1))
    kv.push("g", jnp.ones(1))
    kv.push("g", jnp.ones(1))
    np.testing.assert_allclose(kv.pull("g")[0], jnp.asarray([2.0]))


def test_local_reduce_tensor_semantics():
    """push(key, tensor_list): the group of per-device vectors is locally
    reduced first (paper fig. 4 line 2)."""
    tensor = [jnp.ones(5), 2 * jnp.ones(5), 3 * jnp.ones(5)]
    np.testing.assert_allclose(local_reduce(tensor), 6 * jnp.ones(5))
    # pytree-valued tensors also work
    trees = [{"a": jnp.ones(2)}, {"a": jnp.ones(2)}]
    np.testing.assert_allclose(local_reduce(trees)["a"], 2 * jnp.ones(2))


def test_async_applies_immediately():
    kv = KVStore.create("dist_async", num_workers=4)
    kv.init("g", jnp.zeros(2))
    kv.push("g", jnp.ones(2))
    np.testing.assert_allclose(kv.pull("g")[0], jnp.ones(2))


def test_server_optimizer_rule():
    """set_optimizer ships the update rule to the server (fig. 7 line 2)."""
    kv = KVStore.create("dist_async", num_workers=1)
    kv.init("w", jnp.ones(3))
    kv.set_optimizer(sgd(0.5), rescale=0.1)
    kv.push("w", jnp.ones(3))  # grad
    # w - lr * rescale * g = 1 - 0.5*0.1 = 0.95
    np.testing.assert_allclose(kv.pull("w")[0], 0.95 * jnp.ones(3))


def test_elastic_server_rule():
    """Elastic1 (eq. 2) on the server: center += alpha (w - center)."""
    kv = KVStore.create("dist_async", num_workers=1)
    kv.init("c", jnp.zeros(2))
    kv.set_elastic(0.5)
    kv.push("c", jnp.ones(2) * 4.0)
    np.testing.assert_allclose(kv.pull("c")[0], 2.0 * jnp.ones(2))


def test_pushpull_fused():
    kv = KVStore.create("dist_async", num_workers=1)
    kv.init("w", jnp.zeros(2))
    out = kv.pushpull("w", [jnp.ones(2), jnp.ones(2)], num_dst=3)
    assert len(out) == 3
    np.testing.assert_allclose(out[0], 2 * jnp.ones(2))


def test_invalid_type_rejected():
    with pytest.raises(ValueError):
        KVStore.create("bogus")


def test_bytes_per_server_contention_quantity():
    kv = KVStore.create("dist_sync", num_workers=12, num_servers=2)
    kv.init("w", jnp.zeros((1000,), jnp.float32))
    assert kv.bytes_per_server_per_sync("w") == 4000 * 12 // 2
