import os

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see the real single CPU device; only launch/dryrun.py (its
# own process) creates the 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
