"""Deterministic fault injection (core/faults.py) and its wiring through
the six-mode simulation: schedules parse/replay exactly, sync barriers
degrade instead of deadlocking, async/elastic runs survive kills."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faults import (
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    as_schedule,
    delivery_time,
    injector,
)

from test_algorithms import _cfg, eval_fn, grad_fn, init_fn, make_pipeline
from repro.core.algorithms import run


# -- schedule form ----------------------------------------------------------

def test_parse_format_roundtrip():
    text = ("kill@12:unit=1;straggle@0:unit=3:factor=4:duration=20;"
            "corrupt@5:unit=0:sigma=0.1;drop@3:unit=2:duration=2;"
            "delay@7:unit=1:factor=0.5")
    sched = FaultSchedule.parse(text, seed=7)
    assert FaultSchedule.parse(sched.format(), seed=7) == sched
    assert sched.kinds == {"kill", "straggle", "corrupt", "drop", "delay"}


def test_parse_rejects_malformed():
    for bad in ("kill:unit=1", "kill@3", "kill@3:unit=1:bogus=2",
                "explode@3:unit=1", "kill@3:unit"):
        with pytest.raises(ValueError):
            FaultSchedule.parse(bad)


def test_event_validation():
    with pytest.raises(ValueError):
        FaultEvent("kill", unit=-1, step=0)
    with pytest.raises(ValueError):
        FaultEvent("drop", unit=0, step=1, duration=0)


def test_as_schedule_normalizes():
    assert as_schedule(None) is None
    assert as_schedule("") is None
    assert as_schedule(FaultSchedule()) is None
    s = as_schedule("kill@1:unit=0", seed=3)
    assert isinstance(s, FaultSchedule) and s.seed == 3
    assert as_schedule(s) is s


# -- injector lookups -------------------------------------------------------

def test_kill_is_permanent():
    inj = injector("kill@5:unit=2")
    assert not inj.is_killed(2, 4)
    assert inj.is_killed(2, 5) and inj.is_killed(2, 99)
    assert not inj.is_killed(1, 99)
    assert inj.killed_at(2) == 5 and inj.killed_at(0) is None


def test_drop_consumes_attempts():
    inj = injector("drop@3:unit=1:duration=2")
    assert inj.should_drop(1, 3, attempt=0)
    assert inj.should_drop(1, 3, attempt=1)
    assert not inj.should_drop(1, 3, attempt=2)
    assert not inj.should_drop(1, 4, attempt=0)


def test_delivery_time_retry_backoff():
    inj = injector("drop@0:unit=0:duration=2")
    # attempts at +0.05, then +0.1 after the second drop -> lands at 0.15
    assert delivery_time(inj, 0, 0, 0.0, retries=2, backoff=0.05) == \
        pytest.approx(0.15)
    # gives up: 3 consecutive drops > 1 initial + 2 retries... duration=3
    inj3 = injector("drop@0:unit=0:duration=3")
    assert delivery_time(inj3, 0, 0, 0.0, retries=2) is None
    # clean pushes land at their arrival time
    assert delivery_time(None, 0, 0, 1.5) == 1.5
    assert delivery_time(inj, 0, 1, 1.5) == 1.5


def test_straggle_window_and_compounding():
    inj = injector("straggle@2:unit=0:factor=3:duration=4;"
                   "straggle@4:unit=0:factor=2")
    assert inj.straggle_factor(0, 1) == 1.0
    assert inj.straggle_factor(0, 2) == 3.0
    assert inj.straggle_factor(0, 4) == 6.0   # overlap compounds
    assert inj.straggle_factor(0, 6) == 1.0
    assert inj.straggle_factor(1, 3) == 1.0


def test_corrupt_replay_identical_and_float_only():
    inj = injector("corrupt@4:unit=1:sigma=0.5", seed=11)
    tree = {"w": jnp.ones((4, 3)), "n": jnp.arange(5)}
    a = inj.corrupt(tree, 1, 4)
    b = inj.corrupt(tree, 1, 4)
    assert jnp.array_equal(a["w"], b["w"])          # seeded per (unit, step)
    assert not jnp.array_equal(a["w"], tree["w"])   # noise applied
    assert jnp.array_equal(a["n"], tree["n"])       # int leaves untouched
    untouched = inj.corrupt(tree, 0, 4)
    assert jnp.array_equal(untouched["w"], tree["w"])


# -- six-mode simulation under faults --------------------------------------

SYNC_SCHED = "kill@12:unit=1;straggle@0:unit=0:factor=3:duration=5"


def test_sync_kill_degrades_then_shrinks_barrier():
    h = run(_cfg("mpi_sgd", faults=SYNC_SCHED, barrier_timeout=1.0),
            init_fn, grad_fn, eval_fn, make_pipeline)
    assert h.degraded_syncs >= 1          # the detection round
    assert h.live_clients == 1            # the dead client was evicted
    assert h.membership_epochs == 1
    assert h.metrics[-1] > 0.5            # survivors still converge


def test_sync_replay_bit_identical():
    a = run(_cfg("dist_sgd", faults=SYNC_SCHED, barrier_timeout=1.0),
            init_fn, grad_fn, eval_fn, make_pipeline)
    b = run(_cfg("dist_sgd", faults=SYNC_SCHED, barrier_timeout=1.0),
            init_fn, grad_fn, eval_fn, make_pipeline)
    assert a.losses == b.losses
    assert a.times == b.times
    assert a.metrics == b.metrics


def test_sync_kill_without_timeout_raises():
    with pytest.raises(ValueError, match="barrier_timeout"):
        run(_cfg("mpi_sgd", faults="kill@3:unit=0"),
            init_fn, grad_fn, eval_fn, make_pipeline)


def test_clean_path_unchanged_by_fault_knobs():
    """An empty schedule must run the EXACT clean code path."""
    a = run(_cfg("mpi_sgd"), init_fn, grad_fn, eval_fn, make_pipeline)
    b = run(_cfg("mpi_sgd", faults="", push_retries=5),
            init_fn, grad_fn, eval_fn, make_pipeline)
    assert a.losses == b.losses and a.times == b.times


def test_async_kill_and_drop():
    sched = "kill@8:unit=1;drop@3:unit=0:duration=9"
    h = run(_cfg("mpi_asgd", faults=sched),
            init_fn, grad_fn, eval_fn, make_pipeline)
    assert h.live_clients == 1
    assert h.late_pushes == 1            # duration=9 outlives the retries
    assert h.metrics[-1] > 0.5
    h2 = run(_cfg("mpi_asgd", faults=sched),
             init_fn, grad_fn, eval_fn, make_pipeline)
    assert h.losses == h2.losses and h.times == h2.times


def test_esgd_kill_plus_straggler_converges():
    """The acceptance bar: one client killed mid-run + one straggler
    leaves the elastic modes within ±0.01 of the fault-free accuracy."""
    sched = "kill@10:unit=1;straggle@0:unit=0:factor=3:duration=8"
    for mode in ("dist_esgd", "mpi_esgd"):
        clean = run(_cfg(mode), init_fn, grad_fn, eval_fn, make_pipeline)
        faulted = run(_cfg(mode, faults=sched),
                      init_fn, grad_fn, eval_fn, make_pipeline)
        assert abs(clean.metrics[-1] - faulted.metrics[-1]) <= 0.01, mode
        assert faulted.live_clients < clean.live_clients


def test_staleness_scaling_damps_stale_pushes():
    base = dict(num_workers=8, jitter=0.3)
    plain = run(_cfg("dist_asgd", **base),
                init_fn, grad_fn, eval_fn, make_pipeline)
    scaled = run(_cfg("dist_asgd", staleness_scaling=True, **base),
                 init_fn, grad_fn, eval_fn, make_pipeline)
    # same event order (scaling only touches the server update), and the
    # damped rule must still learn
    assert scaled.mean_staleness == plain.mean_staleness
    assert scaled.losses != plain.losses
    assert scaled.metrics[-1] > 0.5
