"""Elastic membership (core/membership.py): epoch tracking, the
Communicator re-split, the survivor optimizer-state re-shard, and the
KVStore barrier shrinking with the live count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model, flatbuf
from repro.core.comm import Communicator
from repro.core.kvstore import KVStore
from repro.core.membership import Membership, reshard_optstate
from repro.optim.sgd import adamw, optstate_shard_init, sgd


def _world(c):
    return Communicator.world(("client",), (c,))


# -- membership epochs ------------------------------------------------------

def test_epoch_advances_and_comm_resplits():
    m = Membership(4, _world(4))
    assert m.live == (0, 1, 2, 3) and m.epoch == 0
    assert m.comm.static_size == 4
    ep = m.fail(2)
    assert ep.kind == "fail" and ep.member == 2 and ep.epoch == 1
    assert m.live == (0, 1, 3) and m.comm.static_size == 3
    m.leave(0)
    assert m.live == (1, 3) and m.comm.static_size == 2
    m.join(2)
    assert m.live == (1, 2, 3) and m.comm.static_size == 3
    assert [e.kind for e in m.history] == ["init", "fail", "leave", "join"]


def test_rank_of_is_dense_survivor_rank():
    m = Membership(4)
    m.fail(1)
    assert m.rank_of(0) == 0 and m.rank_of(2) == 1 and m.rank_of(3) == 2
    with pytest.raises(KeyError):
        m.rank_of(1)


def test_membership_guards():
    m = Membership(2)
    with pytest.raises(ValueError):
        m.join(1)            # already live
    m.fail(0)
    with pytest.raises(ValueError):
        m.fail(1)            # last member
    with pytest.raises(ValueError):
        m.fail(0)            # not live
    with pytest.raises(ValueError):
        Membership([])
    with pytest.raises(ValueError):
        # trace-time adapter comms have nothing to re-split
        Membership(2, Communicator.world(("x",)))


def test_resized_guards():
    w = Communicator.world(("a", "b"), (2, 3))
    assert w.resized(4, axis="b").sizes == (2, 4)
    with pytest.raises(ValueError):
        w.resized(4)          # multi-axis needs axis=
    with pytest.raises(ValueError):
        w.resized(4, axis="c")
    with pytest.raises(ValueError):
        Communicator.world(("a",), (2,)).resized(0)


# -- optimizer-state re-shard ----------------------------------------------

PARAMS = {"w": jnp.zeros((13, 5)), "b": jnp.zeros((7,)),
          "s": jnp.zeros((3, 3))}


def _stacked_sgd(spec, p, nr=1):
    """Distinct per-position momentum values, sharded ring-major: device
    d owns full.reshape(nr, p, chunk)[:, d, :] of full = arange(total)."""
    chunk, total = flatbuf.shard_geometry(spec.size, p, nr)
    full = jnp.arange(total, dtype=jnp.float32) + 1.0
    view = full.reshape(nr, p, chunk)
    return jnp.stack([view[:, d, :].reshape(-1) for d in range(p)])


def _reconstruct(stacked, n, p, nr):
    chunk, total = flatbuf.shard_geometry(n, p, nr)
    full = jnp.zeros((nr, p, chunk))
    for d in range(p):
        full = full.at[:, d, :].set(stacked[d].reshape(nr, chunk))
    return full.reshape(-1)[:n]


@pytest.mark.parametrize("p_old,p_new", [(2, 1), (2, 2), (8, 7), (8, 4),
                                         (2, 3), (8, 8)])
@pytest.mark.parametrize("nr", [1, 2])
def test_reshard_carries_survivor_state(p_old, p_new, nr):
    spec = flatbuf.spec_for(PARAMS)
    stacked = _stacked_sgd(spec, p_old, nr)
    survivors = tuple(range(min(p_old, p_new)))
    new, info = reshard_optstate(
        sgd(0.1, momentum=0.9).hyper, spec, stacked, p_old, p_new,
        survivors=survivors, num_rings=nr)
    assert new.shape == (p_new, flatbuf.shard_size(spec, p_new, nr, None))
    # logical-offset carry-over: reconstructing the full stream at the
    # NEW geometry gives the old stream wherever a survivor owned it
    got = _reconstruct(new, spec.size, p_new, nr)
    want = _reconstruct(stacked, spec.size, p_old, nr)
    chunk_o, _ = flatbuf.shard_geometry(spec.size, p_old, nr)
    mask = np.zeros(spec.size, bool)
    for r in range(nr):
        for d in survivors:
            lo = (r * p_old + d) * chunk_o
            mask[lo:min(lo + chunk_o, spec.size)] = True
    np.testing.assert_array_equal(np.asarray(got)[mask],
                                  np.asarray(want)[mask])
    np.testing.assert_array_equal(np.asarray(got)[~mask], 0.0)
    assert info["p_old"] == p_old and info["p_new"] == p_new


def test_reshard_with_dead_member_zeroes_its_slice():
    spec = flatbuf.spec_for(PARAMS)
    stacked = _stacked_sgd(spec, 4)
    new, info = reshard_optstate(sgd(0.1, momentum=0.9).hyper, spec,
                                 stacked, 4, 3, survivors=(0, 1, 3))
    got = _reconstruct(new, spec.size, 3, 1)
    want = _reconstruct(stacked, spec.size, 4, 1)
    chunk, _ = flatbuf.shard_geometry(spec.size, 4, 1)
    dead = slice(2 * chunk, 3 * chunk)
    np.testing.assert_array_equal(np.asarray(got)[dead], 0.0)
    keep = np.ones(spec.size, bool)
    keep[dead] = False
    np.testing.assert_array_equal(np.asarray(got)[keep],
                                  np.asarray(want)[keep])
    assert info["survivors"] == (0, 1, 3)


def test_reshard_adamw_streams_and_t():
    spec = flatbuf.spec_for(PARAMS)
    opt = adamw(1e-3)
    state0 = optstate_shard_init(opt.hyper, spec, 4, 1)
    mv = jnp.stack([state0["mv"] + d for d in range(4)])
    t = jnp.asarray([5, 5, 5, 5], state0["t"].dtype)
    new, info = reshard_optstate(opt.hyper, spec, {"mv": mv, "t": t},
                                 4, 5, survivors=(0, 1, 2, 3))
    assert new["mv"].shape[0] == 5 and new["mv"].shape[1] == 2
    # survivors keep their step count; the joiner inherits it
    np.testing.assert_array_equal(np.asarray(new["t"]), 5)


def test_reshard_validates_inputs():
    spec = flatbuf.spec_for(PARAMS)
    stacked = _stacked_sgd(spec, 2)
    hyper = sgd(0.1, momentum=0.9).hyper
    with pytest.raises(ValueError, match="duplicate"):
        reshard_optstate(hyper, spec, stacked, 2, 2, survivors=(0, 0))
    with pytest.raises(ValueError, match="outside"):
        reshard_optstate(hyper, spec, stacked, 2, 2, survivors=(3,))
    with pytest.raises(ValueError, match="cannot fit"):
        reshard_optstate(hyper, spec, stacked, 2, 1, survivors=(0, 1))
    with pytest.raises(ValueError, match="shape"):
        reshard_optstate(hyper, spec, stacked[:, :-1], 2, 1)
    with pytest.raises(ValueError, match="flat families"):
        reshard_optstate({"name": "lbfgs"}, spec, stacked, 2, 1)


def test_reshard_bytes_match_cost_model():
    """The contract bench_faults.py gates on: moved_bytes equals the
    cost model's (s-1)-shard survivor allgather leg EXACTLY."""
    spec = flatbuf.spec_for(PARAMS)
    for p_old, survivors in [(2, (0,)), (4, (0, 2, 3)), (8, tuple(range(7)))]:
        stacked = _stacked_sgd(spec, p_old)
        _, info = reshard_optstate(sgd(0.1, momentum=0.9).hyper, spec,
                                   stacked, p_old, len(survivors),
                                   survivors=survivors)
        assert info["moved_bytes"] == cost_model.reshard_leg_bytes(
            info["state_nbytes"], p_old, survivors=len(survivors))


def test_reconfig_time_composition():
    net = cost_model.testbed()
    t = cost_model.reconfig_time(1e6, 4, 3, net, survivors=3)
    assert t == cost_model.resplit_time(3, net) + \
        cost_model.reshard_leg_bytes(1e6, 4, survivors=3) * net.beta
    assert cost_model.reshard_leg_bytes(1e6, 1) == 0.0
    assert cost_model.reshard_leg_bytes(1e6, 4, survivors=1) == 0.0


# -- KVStore barrier under membership --------------------------------------

@pytest.mark.parametrize("clients", [2, 4])
def test_barrier_shrinks_with_live_count(clients):
    kv = KVStore.create("sync_mpi", num_workers=clients * 2,
                        num_clients=clients)
    kv.init("g", jnp.zeros((3,)))
    m = Membership(clients)
    kv.attach_membership(m)
    assert kv.expected_pushers == clients
    m.fail(clients - 1)
    assert kv.expected_pushers == clients - 1
    for c in range(clients - 1):
        kv.push("g", jnp.ones((3,)))
    np.testing.assert_array_equal(np.asarray(kv.value("g")), clients - 1)
    assert kv.last_barrier_count == clients - 1


def test_degraded_release_and_late_push():
    kv = KVStore.create("dist_sync", num_workers=3, barrier_timeout=1.0)
    kv.init("g", jnp.zeros((2,)))
    kv.push("g", jnp.ones((2,)), at=0.0)
    kv.push("g", jnp.ones((2,)), at=0.5)
    # worker 2 never arrives; the pull at the deadline releases short
    out = kv.pull("g", now=1.0)[0]
    np.testing.assert_array_equal(np.asarray(out), 2.0)
    assert kv.degraded_syncs == 1 and kv.last_barrier_count == 2
    # its push finally lands late -> discarded, not applied
    kv.push("g", jnp.ones((2,)), at=0.0)   # next round opens at 0.0
    kv.push("g", jnp.full((2,), 7.0), at=2.0)
    assert kv.late_pushes == 1
    np.testing.assert_array_equal(np.asarray(kv.value("g")), 2.0)


def test_incomplete_barrier_still_raises_without_timeout():
    kv = KVStore.create("dist_sync", num_workers=2)
    kv.init("g", jnp.zeros((2,)))
    kv.push("g", jnp.ones((2,)))
    with pytest.raises(RuntimeError, match="barrier incomplete"):
        kv.pull("g")


def test_unregistered_key_errors_name_known_keys():
    kv = KVStore.create("local")
    kv.init("weights", jnp.zeros((2,)))
    with pytest.raises(KeyError, match="known keys: 'weights'"):
        kv.push("grads", jnp.ones((2,)))
    with pytest.raises(KeyError, match="kv.init\\('grads', value\\)"):
        kv.pull("grads")
    with pytest.raises(KeyError, match="unregistered key 'grads'"):
        kv.value("grads")
