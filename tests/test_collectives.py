"""Tensor-collective correctness: every algorithm == the mathematical
allreduce, via single-device vmap emulation of the named axis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import collectives as C
from repro.core.comm import CollectivePolicy, Communicator

METHODS = ["ring", "multi_ring", "tree", "psum"]


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("method", METHODS)
def test_allreduce_equals_sum(p, method):
    x = jax.random.normal(jax.random.key(0), (p, 731))
    got = C.emulate(C.allreduce, x, method=method)
    want = jnp.broadcast_to(jnp.sum(x, axis=0), got.shape)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("p", [3, 5])
def test_ring_works_on_non_power_of_two(p):
    x = jax.random.normal(jax.random.key(1), (p, 40))
    got = C.emulate(C.allreduce, x, method="ring")
    np.testing.assert_allclose(
        got, jnp.broadcast_to(jnp.sum(x, 0), got.shape), rtol=2e-5)


def test_tree_requires_power_of_two():
    x = jnp.ones((3, 8))
    with pytest.raises(AssertionError):
        C.emulate(C.allreduce, x, method="tree")


@settings(max_examples=30, deadline=None)
@given(
    p=st.sampled_from([2, 4, 8]),
    n=st.integers(1, 300),
    rings=st.integers(1, 4),
    seed=st.integers(0, 2**30),
)
def test_multi_ring_property(p, n, rings, seed):
    x = jax.random.normal(jax.random.key(seed), (p, n))
    got = C.emulate(C.ring_allreduce, x, num_rings=rings)
    np.testing.assert_allclose(
        got, jnp.broadcast_to(jnp.sum(x, 0), got.shape), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("p", [2, 4])
@pytest.mark.parametrize("n", [12, 97])
def test_reduce_scatter_allgather_roundtrip(p, n):
    x = jax.random.normal(jax.random.key(2), (p, n))
    rs = C.emulate(C.ring_reduce_scatter, x)
    chunk = -(-n // p)
    want = jnp.pad(jnp.sum(x, 0), (0, chunk * p - n)).reshape(p, chunk)
    np.testing.assert_allclose(rs, want, rtol=2e-5, atol=2e-5)
    ag = C.emulate(C.ring_allgather, rs)
    for d in range(p):
        np.testing.assert_allclose(ag[d][:n], jnp.sum(x, 0), rtol=2e-5,
                                   atol=2e-5)


def test_tensor_allreduce_fused_equals_per_leaf():
    p = 4
    tree = {
        "a": jax.random.normal(jax.random.key(3), (p, 6, 5)),
        "b": {"c": jax.random.normal(jax.random.key(4), (p, 13))},
    }
    grp_fused = Communicator.world(
        ("ring",), (p,),
        policy=CollectivePolicy(method="multi_ring", num_rings=2))
    grp_leaf = Communicator.world(
        ("ring",), (p,), policy=CollectivePolicy(method="per_leaf"))
    fused = jax.vmap(lambda t: C.tensor_allreduce(t, grp_fused),
                     axis_name="ring")(tree)
    leafwise = jax.vmap(lambda t: C.tensor_allreduce(t, grp_leaf),
                        axis_name="ring")(tree)
    jax.tree.map(
        lambda f, l: np.testing.assert_allclose(f, l, rtol=2e-5, atol=2e-5),
        fused, leafwise)


def test_pushpull_fused_equals_unfused():
    p = 4
    tree = {"g": jax.random.normal(jax.random.key(5), (p, 50))}
    grp = Communicator.world(("ring",), (p,))
    fused = jax.vmap(lambda t: C.tensor_pushpull(t, grp, fused=True),
                     axis_name="ring")(tree)
    unfused = jax.vmap(lambda t: C.tensor_pushpull(t, grp, fused=False),
                       axis_name="ring")(tree)
    np.testing.assert_allclose(fused["g"], unfused["g"], rtol=2e-5, atol=2e-5)
    want = jnp.broadcast_to(jnp.mean(tree["g"], 0), (p, 50))
    np.testing.assert_allclose(fused["g"], want, rtol=2e-5, atol=2e-5)


def test_allreduce_preserves_dtype_and_shape():
    p = 2
    x = jax.random.normal(jax.random.key(6), (p, 3, 4, 5)).astype(jnp.bfloat16)
    got = C.emulate(C.allreduce, x, method="ring")
    assert got.dtype == jnp.bfloat16
    assert got.shape == x.shape


def test_single_device_axis_is_identity():
    x = jax.random.normal(jax.random.key(7), (1, 64))
    for method in METHODS:
        got = C.emulate(C.allreduce, x, method=method)
        np.testing.assert_allclose(got, x, rtol=1e-6)
