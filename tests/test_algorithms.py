"""The six parallel-SGD modes: convergence, staleness, and timing-model
behaviour on a small real model (logistic regression on synthetic images)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import MODES, AlgoConfig, run
from repro.data.pipeline import DataConfig, ImagePipeline

D, NCLS = 8 * 8 * 3, 10


def init_fn(key):
    return {"w": jax.random.normal(key, (D, NCLS)) * 0.01,
            "b": jnp.zeros((NCLS,))}


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    logits = x @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(lse - gold)


grad_fn = jax.jit(jax.value_and_grad(_loss))

_test_pipe = ImagePipeline(
    DataConfig(seed=0, batch_size=256, steps_per_epoch=1, shard=12345),
    image_size=8)
_test_batch = _test_pipe.batch_at(999, 0)


def eval_fn(params):
    x = _test_batch["images"].reshape(256, -1)
    logits = x @ params["w"] + params["b"]
    return float(jnp.mean(
        (jnp.argmax(logits, -1) == _test_batch["labels"]).astype(jnp.float32)))


def make_pipeline(w):
    return ImagePipeline(
        DataConfig(seed=0, batch_size=16, steps_per_epoch=10, shard=w),
        image_size=8)


def _cfg(mode, **kw):
    base = dict(mode=mode, num_workers=4, num_clients=2, num_servers=1,
                lr=0.05, epochs=2, steps_per_epoch=10, esgd_interval=4,
                compute_time=0.2, jitter=0.1, model_bytes=1e7, seed=0)
    base.update(kw)
    return AlgoConfig(**base)


@pytest.mark.parametrize("mode", MODES)
def test_mode_learns(mode):
    h = run(_cfg(mode), init_fn, grad_fn, eval_fn, make_pipeline)
    assert h.metrics[-1] > 0.5, (mode, h.metrics)
    assert len(h.metrics) == 2


def test_sync_dist_and_mpi_numerically_identical():
    """Grouping workers into clients changes the comm pattern, not the
    math: dist-SGD and mpi-SGD produce identical curves (paper fig. 11
    shows them reaching the same accuracy; time differs)."""
    h_dist = run(_cfg("dist_sgd"), init_fn, grad_fn, eval_fn, make_pipeline)
    h_mpi = run(_cfg("mpi_sgd"), init_fn, grad_fn, eval_fn, make_pipeline)
    np.testing.assert_allclose(h_dist.losses, h_mpi.losses, rtol=1e-4)


def test_mpi_reduces_staleness_vs_dist():
    """Fewer async units => lower staleness (paper §2.3)."""
    h_dist = run(_cfg("dist_asgd", num_workers=8, jitter=0.3),
                 init_fn, grad_fn, eval_fn, make_pipeline)
    h_mpi = run(_cfg("mpi_asgd", num_workers=8, num_clients=2, jitter=0.3),
                init_fn, grad_fn, eval_fn, make_pipeline)
    assert h_mpi.mean_staleness < h_dist.mean_staleness


def test_contention_makes_dist_epochs_slower():
    """With a big model, PS ingress contention dominates: dist epochs are
    slower than mpi epochs (fig. 12)."""
    big = dict(model_bytes=5e8, compute_time=0.3)
    h_dist = run(_cfg("dist_sgd", num_workers=8, **big),
                 init_fn, grad_fn, eval_fn, make_pipeline)
    h_mpi = run(_cfg("mpi_sgd", num_workers=8, num_clients=2, **big),
                init_fn, grad_fn, eval_fn, make_pipeline)
    assert h_mpi.epoch_time < h_dist.epoch_time


def test_esgd_interval_reduces_comm_time():
    h_often = run(_cfg("mpi_esgd", esgd_interval=1, model_bytes=5e8),
                  init_fn, grad_fn, eval_fn, make_pipeline)
    h_lazy = run(_cfg("mpi_esgd", esgd_interval=8, model_bytes=5e8),
                 init_fn, grad_fn, eval_fn, make_pipeline)
    assert h_lazy.epoch_time < h_often.epoch_time


def test_determinism():
    h1 = run(_cfg("mpi_asgd"), init_fn, grad_fn, eval_fn, make_pipeline)
    h2 = run(_cfg("mpi_asgd"), init_fn, grad_fn, eval_fn, make_pipeline)
    np.testing.assert_allclose(h1.losses, h2.losses)
    assert h1.times == h2.times


def test_invalid_mode_rejected():
    with pytest.raises(ValueError):
        run(_cfg("hogwild"), init_fn, grad_fn, eval_fn, make_pipeline)


def test_uneven_clients_rejected():
    with pytest.raises(ValueError):
        run(_cfg("mpi_sgd", num_workers=5, num_clients=2),
            init_fn, grad_fn, eval_fn, make_pipeline)
