"""Substrate: data pipeline, checkpointing, optimizers, scheduler, sharding
rules, cost model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import cost_model
from repro.core.scheduler import AsyncEngine, StalenessTracker, UnitTiming
from repro.data.pipeline import DataConfig, ImagePipeline, TokenPipeline
from repro.optim.sgd import adagrad, adamw, sgd


# --- data ------------------------------------------------------------------

def test_token_pipeline_deterministic():
    p1 = TokenPipeline(DataConfig(seed=7, vocab_size=64, seq_len=16))
    p2 = TokenPipeline(DataConfig(seed=7, vocab_size=64, seq_len=16))
    b1, b2 = p1.batch_at(0, 3), p2.batch_at(0, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_token_pipeline_shards_differ():
    cfg = DataConfig(seed=7, vocab_size=64, seq_len=16)
    a = TokenPipeline(cfg).batch_at(0, 0)
    b = TokenPipeline(DataConfig(seed=7, vocab_size=64, seq_len=16,
                                 shard=1)).batch_at(0, 0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_token_labels_are_shifted_tokens():
    p = TokenPipeline(DataConfig(seed=0, vocab_size=32, seq_len=8))
    b = p.batch_at(0, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_token_pipeline_learnable_structure():
    """The bigram automaton has entropy well below log(V): learnable."""
    p = TokenPipeline(DataConfig(seed=0, vocab_size=128, seq_len=8))
    assert p.optimal_xent() < 0.8 * np.log(128)


def test_image_pipeline_epoch_iteration():
    p = ImagePipeline(DataConfig(seed=0, batch_size=4, steps_per_epoch=3),
                      image_size=8)
    batches = list(p.epoch(0))
    assert len(batches) == 3
    assert batches[0]["images"].shape == (4, 8, 8, 3)


# --- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.asarray(17, jnp.int32),
    }
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, step=17, metadata={"arch": "test"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore_checkpoint(path, like)
    assert meta["step"] == 17 and meta["arch"] == "test"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32),
                                                   np.asarray(b, np.float32)),
        restored, tree)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros((3,))})


def test_checkpoint_missing_leaf_rejected(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(path, {"w": jnp.zeros((2,)), "extra": jnp.zeros(1)})


# --- optimizers ---------------------------------------------------------------

def _quad_grad(p):
    return jax.tree.map(lambda x: 2 * x, p)


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9),
                                 adagrad(0.5), adamw(0.1)])
def test_optimizers_descend_quadratic(opt):
    p = {"x": jnp.asarray([3.0, -2.0])}
    s = opt.init(p)
    for _ in range(60):
        p, s = opt.update(_quad_grad(p), s, p)
    assert float(jnp.max(jnp.abs(p["x"]))) < 0.5


def test_sgd_weight_decay():
    opt = sgd(0.1, weight_decay=0.5)
    p = {"x": jnp.asarray([1.0])}
    zero_g = {"x": jnp.zeros(1)}
    p2, _ = opt.update(zero_g, opt.init(p), p)
    assert float(p2["x"][0]) == pytest.approx(1.0 - 0.1 * 0.5)


# --- scheduler ----------------------------------------------------------------

def test_async_engine_time_ordering():
    rngs = [np.random.default_rng(i) for i in range(3)]
    timing = [UnitTiming(base=b, jitter=0.0, rng=r)
              for b, r in zip([1.0, 2.0, 3.0], rngs)]
    engine = AsyncEngine(3, timing)
    order = []
    engine.start()
    engine.run(6, lambda u, now: order.append((u, now)) or 0.0)
    times = [t for _, t in order]
    assert times == sorted(times)
    assert order[0][0] == 0  # fastest unit completes first


def test_staleness_tracker():
    t = StalenessTracker()
    t.on_pull(0)
    t.on_pull(1)
    assert t.on_apply(0) == 0  # applied against fresh params
    assert t.on_apply(1) == 1  # one update landed since unit 1 pulled
    assert t.mean_staleness() == pytest.approx(0.5)


# --- sharding rules --------------------------------------------------------------

def test_param_specs_divisibility_safe():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import logical_to_pspec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # divisible -> sharded; non-divisible -> replicated
    assert logical_to_pspec(("vocab", None), (151936, 2048), FakeMesh()) == P("model")
    assert logical_to_pspec(("heads",), (24,), FakeMesh()) == P()
    assert logical_to_pspec((None, "ff"), (100, 1408), FakeMesh()) == P(None, "model")


def test_batch_pspec_fallbacks():
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import batch_pspec

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    assert batch_pspec(FakeMesh(), 256) == P(("pod", "data"), None)
    assert batch_pspec(FakeMesh(), 16) == P("data", None)
    assert batch_pspec(FakeMesh(), 1) == P(None, None)


# --- cost model -------------------------------------------------------------------

def test_ring_beats_tree_for_large_messages():
    net = cost_model.testbed()
    n, p = 100e6, 16
    assert cost_model.ring_allreduce_time(n, p, net) < \
        cost_model.tree_allreduce_time(n, p, net)


def test_multi_ring_overlap_helps_when_gamma_comparable():
    net = cost_model.NetParams(alpha=1e-6, beta=1 / 10e9, gamma=1 / 12e9)
    n, p = 64e6, 8
    assert cost_model.multi_ring_allreduce_time(n, p, net, 2) < \
        cost_model.ring_allreduce_time(n, p, net)


def test_ps_contention_scales_with_pushers():
    net = cost_model.testbed()
    t4 = cost_model.ps_pushpull_time(1e8, 4, 2, net)
    t16 = cost_model.ps_pushpull_time(1e8, 16, 2, net)
    assert t16 > 3 * t4


def test_epoch_time_mpi_beats_dist():
    net = cost_model.testbed()
    kw = dict(model_bytes=1e8, num_workers=12, num_servers=2,
              steps_per_epoch=100, compute_time_per_step=0.5, net=net)
    t_dist = cost_model.epoch_time(mode="dist", num_clients=12, **kw)
    t_mpi = cost_model.epoch_time(mode="mpi", num_clients=2, **kw)
    assert t_mpi < t_dist
