"""Sharded fused-step path: FlatBuffer pack/unpack round-trips, and
numerical equivalence of ``scatter_update_gather`` (reduce-scatter ->
Pallas fused momentum-SGD on the local 1/p shard -> allgather) against
the per-leaf allreduce+SGD baseline under vmap emulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import collectives as C
from repro.core import flatbuf as F
from repro.core.comm import CollectivePolicy, Communicator
from repro.optim.sgd import momentum_shard_init, scatter_update_gather, sgd

AXIS = "ring"


def _tree(seed=0, dtype=jnp.float32):
    """Odd, lane-unfriendly leaf sizes on purpose."""
    k = jax.random.key(seed)
    ks = jax.random.split(k, 4)
    return {
        "w": jax.random.normal(ks[0], (13, 7), jnp.float32).astype(dtype),
        "b": jax.random.normal(ks[1], (5,), jnp.float32).astype(dtype),
        "deep": {"u": jax.random.normal(ks[2], (3, 11, 2), jnp.float32).astype(dtype),
                 "s": jax.random.normal(ks[3], (), jnp.float32).astype(dtype)},
    }


# --------------------------------------------------------------------------
# FlatBuffer substrate
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flatbuf_roundtrip(dtype):
    t = _tree(dtype=dtype)
    spec = F.spec_for(t)
    buf = spec.pack(t)
    assert buf.shape == (spec.size,) and buf.dtype == jnp.float32
    assert spec.size % (F.LANE * F.SUBLANE) == 0
    back = spec.unpack(buf)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32)),
        back, t)
    assert jax.tree.map(lambda l: l.dtype, back) == \
        jax.tree.map(lambda l: l.dtype, t)


def test_flatbuf_spec_is_memoized_and_lane_aligned():
    t = _tree()
    spec = F.spec_for(t)
    assert spec is F.spec_for(jax.tree.map(lambda x: x + 1, t))
    assert all(off % F.LANE == 0 for off in spec.offsets)


def test_flatbuf_leaf_view():
    t = _tree()
    spec = F.spec_for(t)
    buf = spec.pack(t)
    leaves = jax.tree_util.tree_leaves(t)
    for i, leaf in enumerate(leaves):
        np.testing.assert_allclose(
            np.asarray(spec.leaf_view(buf, i)),
            np.asarray(leaf, np.float32), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 400), min_size=1, max_size=6),
    seed=st.integers(0, 2**30),
)
def test_flatbuf_roundtrip_property(sizes, seed):
    k = jax.random.key(seed)
    tree = {f"l{i}": jax.random.normal(jax.random.fold_in(k, i), (n,))
            for i, n in enumerate(sizes)}
    spec = F.make_flatbuf(tree)
    back = spec.unpack(spec.pack(tree))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6),
                 back, tree)


@pytest.mark.parametrize("p,nr", [(1, 1), (2, 3), (8, 2)])
def test_shard_geometry_lane_aligned(p, nr):
    chunk, total = F.shard_geometry(1024, p, nr)
    assert chunk % F.LANE == 0
    assert total == p * nr * chunk
    assert total >= 1024


def test_effective_rings_composes_bucket_bytes():
    # 4 MB buffer, 1 MB buckets -> 4 rings even if num_rings=2 asked less
    assert F.effective_rings(4 << 20, 2, 1 << 20) == 4
    assert F.effective_rings(4 << 20, 8, 1 << 20) == 8
    assert F.effective_rings(4 << 20, 3, None) == 3


# --------------------------------------------------------------------------
# scatter_update_gather ≡ per-leaf allreduce + momentum SGD
# --------------------------------------------------------------------------

def _baseline_steps(params, grads_per_dev, lr, mu, steps, p,
                    state_dtype=None):
    """Per-leaf reference: mean-allreduce grads, tree.map momentum SGD."""
    opt = sgd(lr, momentum=mu, state_dtype=state_dtype)
    st_ = opt.init(params)
    for s in range(steps):
        mean_g = jax.tree.map(lambda x: jnp.mean(x[s], 0), grads_per_dev)
        params, st_ = opt.update(mean_g, st_, params)
    return params


def _fused_steps(spec, params, grads_per_dev, lr, mu, steps, p, *,
                 num_rings=1, bucket_bytes=None):
    """vmap-emulated sharded fused step, momentum sharded 1/p."""
    nr = F.effective_rings(spec.nbytes, num_rings, bucket_bytes)
    mom = jnp.zeros((p, F.shard_size(spec, p, nr)))
    stacked_p = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), params)

    comm = Communicator.from_axis_name(AXIS, policy=CollectivePolicy(
        num_rings=num_rings, bucket_bytes=bucket_bytes))

    def dev_step(g, pp, m):
        return scatter_update_gather(
            spec, g, pp, m, jnp.float32(lr), jnp.float32(mu), comm=comm)

    step = jax.vmap(dev_step, axis_name=AXIS)
    for s in range(steps):
        g = jax.tree.map(lambda x: x[s], grads_per_dev)
        stacked_p, mom = step(g, stacked_p, mom)
    return stacked_p, mom


@pytest.mark.parametrize("p", [1, 2, 8])
def test_scatter_update_gather_equals_per_leaf(p):
    params = _tree(0)
    spec = F.spec_for(params)
    steps = 3
    k = jax.random.key(42)
    grads = jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.fold_in(k, x.size), (steps, p) + x.shape),
        params)
    want = _baseline_steps(params, grads, 0.05, 0.9, steps, p)
    got, mom = _fused_steps(spec, params, grads, 0.05, 0.9, steps, p)
    # momentum state stays sharded: 1/p of the padded buffer per device
    assert mom.shape[1] * p >= spec.size
    assert mom.shape[1] == F.shard_size(spec, p)
    for d in range(p):
        jax.tree.map(
            lambda g_, w: np.testing.assert_allclose(
                g_[d], w, rtol=2e-5, atol=2e-6),
            got, want)


@pytest.mark.parametrize("p,num_rings,bucket_bytes",
                         [(2, 3, None), (8, 1, 512), (4, 2, 1024)])
def test_scatter_update_gather_ring_and_bucket_variants(p, num_rings,
                                                        bucket_bytes):
    params = _tree(1)
    spec = F.spec_for(params)
    steps = 2
    k = jax.random.key(7)
    grads = jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.fold_in(k, x.size), (steps, p) + x.shape),
        params)
    want = _baseline_steps(params, grads, 0.1, 0.8, steps, p)
    got, _ = _fused_steps(spec, params, grads, 0.1, 0.8, steps, p,
                          num_rings=num_rings, bucket_bytes=bucket_bytes)
    for d in range(p):
        jax.tree.map(
            lambda g_, w: np.testing.assert_allclose(
                g_[d], w, rtol=2e-5, atol=2e-6),
            got, want)


@pytest.mark.parametrize("p", [2, 8])
def test_scatter_update_gather_bf16_params_f32_momentum(p):
    params = _tree(2, dtype=jnp.bfloat16)
    spec = F.spec_for(params)
    steps = 2
    k = jax.random.key(9)
    grads = jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.fold_in(k, x.size), (steps, p) + x.shape,
            jnp.float32).astype(jnp.bfloat16),
        params)
    # baseline keeps f32 momentum, like the flat buffer does
    want = _baseline_steps(params, grads, 0.05, 0.9, steps, p,
                           state_dtype=jnp.float32)
    got, mom = _fused_steps(spec, params, grads, 0.05, 0.9, steps, p)
    assert mom.dtype == jnp.float32
    assert jax.tree_util.tree_leaves(got)[0].dtype == jnp.bfloat16
    for d in range(p):
        jax.tree.map(
            lambda g_, w: np.testing.assert_allclose(
                np.asarray(g_[d], np.float32), np.asarray(w, np.float32),
                rtol=2e-2, atol=2e-2),
            got, want)


def test_scatter_gather_allreduce_method():
    p = 8
    x = jax.random.normal(jax.random.key(3), (p, 731))
    got = C.emulate(C.allreduce, x, method="scatter_gather", num_rings=2)
    np.testing.assert_allclose(
        got, jnp.broadcast_to(jnp.sum(x, 0), got.shape), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("p,nr", [(2, 2), (8, 3), (5, 2)])
def test_multi_ring_reduce_scatter_allgather_roundtrip(p, nr):
    n = 999  # n % p != 0 and n % LANE != 0
    x = jax.random.normal(jax.random.key(4), (p, n))
    rs = C.emulate(C.ring_reduce_scatter, x, num_rings=nr)
    ag = C.emulate(C.ring_allgather, rs, num_rings=nr)
    for d in range(p):
        np.testing.assert_allclose(ag[d][:n], jnp.sum(x, 0),
                                   rtol=3e-5, atol=3e-5)
    # shard_select picks exactly the slice reduce-scatter left here
    sel = C.emulate(C.shard_select, ag, num_rings=nr)
    np.testing.assert_allclose(sel, rs, rtol=1e-6)


def test_pushpull_unfused_rejects_ring_method():
    tree = {"g": jax.random.normal(jax.random.key(5), (4, 50))}
    group = Communicator.world(("ring",), (4,))
    with pytest.raises(ValueError, match="only meaningful"):
        C.tensor_pushpull(tree, group, fused=False, method="multi_ring")
    # the unfused path IS tree push + tree pull; no method argument
    out = jax.vmap(lambda t: C.tensor_pushpull(t, group, fused=False),
                   axis_name="ring")(tree)
    want = jnp.broadcast_to(jnp.mean(tree["g"], 0), (4, 50))
    np.testing.assert_allclose(out["g"], want, rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# The production train step takes the fused path and matches per-leaf
# --------------------------------------------------------------------------

def test_train_step_fused_matches_per_leaf():
    import dataclasses

    from repro.configs.base import get_config, reduced
    from repro.core.hierarchy import SyncConfig
    from repro.launch.train import (
        fused_path_active,
        make_train_state,
        make_train_step,
    )
    from repro.models.model import build_model

    model = build_model(reduced(get_config("qwen2-0.5b")))
    opt = sgd(0.1, momentum=0.9)
    k = jax.random.key(0)
    toks = jax.random.randint(k, (4, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    sync_f = SyncConfig(mode="mpi_sgd", num_clients=1, fused_update=True)
    sync_l = dataclasses.replace(sync_f, fused_update=False)
    assert fused_path_active(opt, sync_f, None)
    assert not fused_path_active(opt, sync_l, None)

    s_f = make_train_state(model, opt, sync_f, jax.random.key(1))
    s_l = make_train_state(model, opt, sync_l, jax.random.key(1))
    # fused: ONE flat momentum buffer; per-leaf: a momentum pytree
    assert isinstance(s_f["opt"], jax.Array) and s_f["opt"].ndim == 1

    # mismatched mesh between the two factories fails loudly, not deep
    # inside tree.map: per-leaf step fed the fused (flat) opt state
    bad_step = make_train_step(model, opt, sync_l, None)
    with pytest.raises(ValueError, match="same mesh"):
        bad_step(s_f, batch)

    step_f = jax.jit(make_train_step(model, opt, sync_f, None))
    step_l = jax.jit(make_train_step(model, opt, sync_l, None))
    for _ in range(3):
        s_f, m_f = step_f(s_f, batch)
        s_l, m_l = step_l(s_l, batch)
    assert float(m_f["loss"]) == pytest.approx(float(m_l["loss"]), rel=1e-4)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-5, atol=1e-5),
        s_f["params"], s_l["params"])
