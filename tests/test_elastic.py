"""Elastic averaging invariants (eqs. 2/3)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.elastic import (
    elastic_client_update,
    elastic_exchange,
    elastic_exchange_multiclient,
    elastic_server_update,
)


def _rand_tree(seed, scale=1.0):
    k = jax.random.key(seed)
    return {
        "a": scale * jax.random.normal(k, (7, 3)),
        "b": {"c": scale * jax.random.normal(jax.random.fold_in(k, 1), (11,))},
    }


def test_exchange_conserves_sum():
    w, c = _rand_tree(0), _rand_tree(1)
    nw, nc = elastic_exchange(w, c, 0.37)
    jax.tree.map(
        lambda a, b, x, y: np.testing.assert_allclose(a + b, x + y, rtol=1e-5),
        nw, nc, w, c)


def test_fixed_point_when_equal():
    w = _rand_tree(2)
    nw, nc = elastic_exchange(w, w, 0.9)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6), nw, w)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6), nc, w)


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(0.01, 0.49), seed=st.integers(0, 1000))
def test_contraction_property(alpha, seed):
    """|w' − c'| = (1 − 2α)|w − c| elementwise: the elastic force contracts."""
    w, c = _rand_tree(seed), _rand_tree(seed + 1)
    nw, nc = elastic_exchange(w, c, alpha)
    jax.tree.map(
        lambda a, b, x, y: np.testing.assert_allclose(
            a - b, (1 - 2 * alpha) * (x - y), rtol=1e-4, atol=1e-5),
        nw, nc, w, c)


def test_server_then_client_order_matches_paper():
    """Both sides use the PRE-update difference (the paper pushes w, the
    server applies eq. 2 on it, the client applies eq. 3 with the old w̃)."""
    w, c = _rand_tree(3), _rand_tree(4)
    alpha = 0.2
    nc = elastic_server_update(c, w, alpha)
    nw = elastic_client_update(w, c, alpha)
    ew, ec = elastic_exchange(w, c, alpha)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), nw, ew)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), nc, ec)


def test_multiclient_reduces_to_single():
    w = _rand_tree(5)
    c = _rand_tree(6)
    stacked = jax.tree.map(lambda x: x[None], w)
    nw_m, nc_m = elastic_exchange_multiclient(stacked, c, 0.3)
    nw_s, nc_s = elastic_exchange(w, c, 0.3)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a[0], b, rtol=1e-5), nw_m, nw_s)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), nc_m, nc_s)


def test_multiclient_center_moves_toward_client_mean():
    C = 4
    key = jax.random.key(7)
    clients = {"w": jax.random.normal(key, (C, 9))}
    center = {"w": jnp.zeros((9,))}
    _, nc = elastic_exchange_multiclient(clients, center, alpha=0.1)
    want = 0.1 * jnp.sum(clients["w"], axis=0)
    np.testing.assert_allclose(nc["w"], want, rtol=1e-5)


def test_consensus_convergence():
    """Iterating the exchange drives every client to the center (the ESGD
    consensus property that makes lazy cross-pod sync sound)."""
    C = 3
    clients = {"w": jnp.asarray([[1.0], [5.0], [9.0]])}
    center = {"w": jnp.asarray([0.0])}
    for _ in range(200):
        clients, center = elastic_exchange_multiclient(clients, center, 0.1)
    spread = float(jnp.max(jnp.abs(clients["w"] - center["w"])))
    assert spread < 1e-3
