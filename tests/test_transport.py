"""The socket-backed PS tier (net/): frames, codec, transports,
rendezvous, and the multi-process dist_sgd / dist_esgd runs.

Unmarked tests are fast in-process units (loopback transport, no
subprocesses). ``transport``-marked tests spawn REAL OS processes from
launcher-emitted scripts and belong to the transport-smoke CI tier.
"""
import json
import os

import numpy as np
import pytest

from repro.core import cost_model
from repro.core.algorithms import AlgoConfig, run
from repro.net import wire
from repro.net.transport import (LoopbackTransport, RemoteError,
                                 TcpTransport, transport_for)

jax = pytest.importorskip("jax")


# ---------------------------------------------------------------------------
# frames + payload codec
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    frame = wire.encode_frame("push", {"key": "grads", "unit": 3},
                              b"\x01\x02\x03")
    op, meta, payload = wire.decode_frame(frame)
    assert op == "push"
    assert meta == {"key": "grads", "unit": 3}
    assert payload == b"\x01\x02\x03"


def test_frame_rejects_bad_magic_and_truncation():
    frame = wire.encode_frame("x", {}, b"abc")
    with pytest.raises(wire.WireError, match="magic"):
        wire.decode_frame(b"XXXX" + frame[4:])
    with pytest.raises(wire.WireError):
        wire.decode_frame(frame[:-1])


@pytest.mark.parametrize("wd", [None, "f32", "bf16", "int8"])
def test_buffer_codec_roundtrip(wd):
    rng = np.random.default_rng(0)
    buf = rng.normal(size=(2048,)).astype(np.float32)
    meta, payload = wire.encode_buffer(buf, wd)
    assert len(payload) == wire.payload_nbytes(2048, wd)
    out = wire.decode_buffer(meta, payload)
    if wd in (None, "f32"):
        np.testing.assert_array_equal(out, buf)
    elif wd == "bf16":
        import ml_dtypes

        np.testing.assert_array_equal(
            out, buf.astype(ml_dtypes.bfloat16).astype(np.float32))
    else:
        # the int8 path must be the in-process wire codec bit-for-bit
        import jax.numpy as jnp

        from repro.kernels.quant_bucket.quant_bucket import (wire_decode,
                                                             wire_encode)

        codes, scales = wire_encode(jnp.asarray(buf))
        ref = np.asarray(wire_decode(codes, scales, 2048))
        np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("wd", [None, "bf16", "int8"])
def test_payload_bytes_match_cost_model(wd):
    """The socket payload is exactly what the cost model predicts for
    the PS leg — and, for WIRE_BLOCK-aligned sizes (every FlatBuffer
    spec.size), exactly ``ps_push_bytes`` of the f32 byte count."""
    for n in (128, 1024, 2048, 4096):
        got = wire.payload_nbytes(n, wd)
        assert got == cost_model.ps_wire_nbytes(n, wd)
        assert got == int(cost_model.ps_push_bytes(4 * n, wd))


def test_ps_wire_nbytes_int8_unaligned():
    # 130 values -> 2 buckets of 128: 256 codes + 2 scales
    assert cost_model.ps_wire_nbytes(130, "int8") == 256 + 8


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

def _echo(op, meta, payload):
    return dict(meta, op_seen=op), payload[::-1]


@pytest.mark.parametrize("name", ["tcp", "loopback"])
def test_transport_request_response(name):
    tr = transport_for(name)
    server = tr.serve(_echo)
    conn = tr.connect(server.addr)
    meta, payload = conn.request("ping", {"x": 1}, b"abc")
    assert meta["op_seen"] == "ping" and meta["x"] == 1
    assert payload == b"cba"
    conn.close()
    server.close()


@pytest.mark.parametrize("name", ["tcp", "loopback"])
def test_transport_remote_error(name):
    def boom(op, meta, payload):
        raise ValueError("no such key")

    tr = transport_for(name)
    server = tr.serve(boom)
    conn = tr.connect(server.addr)
    with pytest.raises(RemoteError, match="no such key"):
        conn.request("pull", {})
    conn.close()
    server.close()


def test_loopback_byte_accounting_matches_tcp():
    """Loopback requests round-trip the same frames as tcp, so the
    client-side byte counters agree — the precondition for gating tcp
    socket bytes against the loopback reference."""
    payload = b"z" * 1000
    counts = {}
    for name in ("tcp", "loopback"):
        tr = transport_for(name)
        server = tr.serve(_echo)
        conn = tr.connect(server.addr)
        conn.request("op", {"k": "v"}, payload)
        counts[name] = (conn.bytes_sent, conn.bytes_received)
        conn.close()
        server.close()
    assert counts["tcp"] == counts["loopback"]


# ---------------------------------------------------------------------------
# rendezvous
# ---------------------------------------------------------------------------

def _mini_algo(**kw):
    base = dict(mode="dist_sgd", num_workers=2, num_clients=2,
                num_servers=1, lr=0.05, epochs=1, steps_per_epoch=2,
                seed=0, compute_time=0.0, jitter=0.0)
    base.update(kw)
    return AlgoConfig(**base)


def test_algo_dict_roundtrip():
    from repro.net.rendezvous import algo_from_dict, algo_to_dict

    cfg = _mini_algo(faults="kill@2:unit=1", barrier_timeout=1.5)
    d = json.loads(json.dumps(algo_to_dict(cfg)))  # through real JSON
    back = algo_from_dict(d)
    assert back.mode == cfg.mode
    assert back.num_workers == cfg.num_workers
    assert back.barrier_timeout == cfg.barrier_timeout
    assert back.policy == cfg.policy
    from repro.core.faults import as_schedule

    assert (as_schedule(back.faults, seed=0).format()
            == as_schedule(cfg.faults, seed=0).format())


def test_rendezvous_assigns_launcher_identities():
    from repro.core.client import group_workers
    from repro.net.rendezvous import Rendezvous, algo_to_dict

    cfg = _mini_algo(num_workers=4, num_clients=4)
    rdzv = Rendezvous(num_workers=4, num_servers=1, num_clients=4,
                      algo=algo_to_dict(cfg))
    idents = group_workers(4, 4)
    for rank in (2, 0, 3, 1):  # join out of order
        rep, _ = rdzv.handle("join", {"role": "worker", "rank": rank}, b"")
        assert rep["ps"]["rank"] == idents[rank].ps.rank
        assert rep["mpi"]["client"] == idents[rank].mpi.client
    # the table is keyed by the WorkerIdentity values themselves
    assert set(rdzv.table) == set(idents)
    rep, _ = rdzv.handle("live", {}, b"")
    assert rep["live"] == [0, 1, 2, 3] and rep["epoch"] == 4
    rdzv.handle("leave", {"rank": 2}, b"")
    rep, _ = rdzv.handle("live", {}, b"")
    assert rep["live"] == [0, 1, 3] and rep["epoch"] == 5


def test_rendezvous_rejects_bad_ranks():
    from repro.net.rendezvous import Rendezvous

    rdzv = Rendezvous(num_workers=2, num_servers=1, num_clients=2, algo={})
    with pytest.raises(ValueError, match="worker rank"):
        rdzv.handle("join", {"role": "worker", "rank": 7}, b"")
    with pytest.raises(ValueError, match="server rank"):
        rdzv.handle("join", {"role": "server", "rank": 1, "addr": "x"}, b"")


def test_stable_server_of_matches_kvstore():
    from repro.core.kvstore import KVStore
    from repro.net.remote_kv import stable_server_of

    kv = KVStore.create("dist_sync", num_workers=4, num_servers=3)
    for key in ("grads", "centers", "w", 7):
        assert stable_server_of(key, 3) == kv.server_of(key)


# ---------------------------------------------------------------------------
# loopback end-to-end: the bit-exact reference
# ---------------------------------------------------------------------------

def _problem():
    from repro.net.problem import build_problem

    return build_problem("logreg8")


def test_loopback_dist_sgd_bit_identical_to_inprocess():
    """The whole point of the transport design: the same pushes, summed
    in the same unit order, divided by the same count — the multi-
    process loss curve IS the simulation's, bit for bit."""
    from repro.launch.run_local import run_job

    algo = _mini_algo(steps_per_epoch=4)
    prob = _problem()
    hist = run(algo, prob.init_fn, prob.grad_fn, prob.eval_fn,
               prob.make_pipeline)
    res = run_job(algo, transport="loopback", timeout=120.0)
    assert res.losses == hist.losses
    assert res.metrics == hist.metrics
    assert res.degraded_syncs == 0
    assert all(rc == 0 for rc in res.exit_codes.values())


def test_loopback_degraded_release_and_rejoin():
    """A straggler sleeping past barrier_timeout: the round releases
    short (degraded_syncs), the Membership evicts the straggler, and its
    NEXT push re-joins it — live count recovers."""
    from repro.launch.run_local import run_job

    algo = _mini_algo(
        num_workers=2, num_clients=2, steps_per_epoch=4,
        compute_time=0.4, barrier_timeout=0.9,
        faults="straggle@1:unit=1:factor=5")  # 1.6s extra > 0.9s timeout
    res = run_job(algo, transport="loopback", timeout=120.0)
    assert res.degraded_syncs >= 1
    st = res.server_stats[0]
    kinds = [e["kind"] for e in st["membership_history"]]
    assert "fail" in kinds and "join" in kinds  # evicted, then re-joined
    assert st["live"] == [0, 1]                 # recovered by the end
    assert len(res.losses) == 4                 # training completed


def test_loopback_wire_dtypes_pay_cost_model_bytes():
    from repro.core.comm import CollectivePolicy
    from repro.launch.run_local import run_job

    for wd, ratio in ((None, 1.0), ("bf16", 0.5), ("int8", 33 / 128)):
        algo = _mini_algo(steps_per_epoch=2,
                          policy=CollectivePolicy(wire_dtype=wd))
        res = run_job(algo, transport="loopback", timeout=120.0)
        kv = res.per_worker[0]["kv"]
        per_push = kv["pushed_bytes"] / kv["push_count"]
        assert per_push == cost_model.ps_wire_nbytes(2048, wd)
        assert per_push == pytest.approx(8192 * ratio)


# ---------------------------------------------------------------------------
# tcp: real OS processes (transport-smoke tier)
# ---------------------------------------------------------------------------

@pytest.mark.transport
def test_tcp_dist_sgd_bit_identical_across_processes(tmp_path):
    """1 server + 2 workers as REAL processes spawned from the emitted
    scripts: the loss curve is bit-identical to the in-process
    simulation at the same seed/config."""
    from repro.launch.run_local import run_job

    algo = _mini_algo(steps_per_epoch=4)
    prob = _problem()
    hist = run(algo, prob.init_fn, prob.grad_fn, prob.eval_fn,
               prob.make_pipeline)
    res = run_job(algo, transport="tcp", outdir=str(tmp_path),
                  timeout=150.0)
    assert all(rc == 0 for rc in res.exit_codes.values()), res.exit_codes
    assert res.losses == hist.losses
    assert res.metrics == hist.metrics
    # the scripts it ran are launcher-emitted and parse back
    names = {os.path.basename(p) for p in res.script_paths}
    assert {"server_0.sh", "client_0.sh", "client_1.sh"} <= names


@pytest.mark.transport
def test_tcp_kill_chaos_degrades_and_completes(tmp_path):
    """SIGKILL a worker process mid-run (fault schedule kill@2): the
    survivor's barrier degrades after barrier_timeout, the membership
    epoch shrinks the live set, and training completes."""
    from repro.launch.run_local import run_job

    algo = _mini_algo(
        steps_per_epoch=6, faults="kill@2:unit=1", barrier_timeout=1.5)
    res = run_job(algo, transport="tcp", outdir=str(tmp_path),
                  timeout=150.0)
    assert res.exit_codes["client_0"] == 0
    # /bin/sh reports the SIGKILLed python as 128+9
    assert res.exit_codes["client_1"] == 137
    assert res.degraded_syncs >= 1
    assert res.membership_epochs >= 1
    assert res.live == [0]
    assert len(res.losses) == 6          # the survivor finished the run
    # a SIGKILLed process writes no metrics file; the survivor does
    assert 0 in res.per_worker and 1 not in res.per_worker


@pytest.mark.transport
def test_tcp_dist_esgd_matches_inprocess_loss(tmp_path):
    """dist_esgd over real processes: same per-epoch mean loss as the
    in-process AsyncEngine run within ±0.01 (event order differs)."""
    from repro.launch.run_local import run_job

    algo = _mini_algo(mode="dist_esgd", steps_per_epoch=8,
                      esgd_interval=4, compute_time=0.01)
    prob = _problem()
    hist = run(algo, prob.init_fn, prob.grad_fn, prob.eval_fn,
               prob.make_pipeline)
    res = run_job(algo, transport="tcp", outdir=str(tmp_path),
                  timeout=150.0)
    assert all(rc == 0 for rc in res.exit_codes.values()), res.exit_codes
    assert res.losses, "no worker losses collected"
    epoch_mean = float(np.mean(res.losses))
    assert abs(epoch_mean - hist.losses[-1]) <= 0.01
    assert abs(res.metrics[-1] - hist.metrics[-1]) <= 0.05
