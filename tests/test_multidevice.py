"""Multi-device paths that need their own process (device count is locked
at first jax init, and conftest must NOT set it globally): run them in
subprocesses with XLA_FLAGS set."""
import os
import subprocess
import sys

import pytest

# subprocess selftests: slow (each spawns its own jax process) AND
# multi-device — the CI tiers select by these markers, not by file path
pytestmark = [pytest.mark.slow, pytest.mark.multidevice]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run(cmd, env_extra, timeout=500):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.update(env_extra)
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout, cwd=ROOT)


def test_collectives_on_real_shard_map_mesh():
    """Ring/multi-ring/tree/psum over a REAL 8-device mesh via shard_map."""
    r = _run(
        [sys.executable, "-m", "repro.core.collectives", "8"],
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "shard_map on 8 devices" in r.stdout


def test_shard_driver_on_real_mesh():
    """The shard_map production driver (grads inside the map, explicit
    ring collectives) matches the single-process reference losses on a
    REAL 8-device mesh, for both mpi_sgd and mpi_esgd — and for every
    lowerable optimizer family (momentum SGD / AdaGrad / AdamW)."""
    r = _run(
        [sys.executable, "-m", "repro.launch.shard_driver", "8"],
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        timeout=560,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "mode=mpi_sgd" in r.stdout
    assert "mode=mpi_esgd" in r.stdout
    for oname in ("sgd", "adamw", "adagrad"):
        assert f"opt={oname}" in r.stdout
    assert "shard_map on 8 devices" in r.stdout


def test_dryrun_single_combo_pod():
    """The deliverable path: lower+compile one (arch x shape) on the
    256-chip production mesh with 512 placeholder devices."""
    r = _run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "decode_32k", "--mesh", "pod"],
        {},
        timeout=560,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "dominant=" in r.stdout


def test_dryrun_skip_rule():
    r = _run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "long_500k", "--mesh", "pod"],
        {},
        timeout=300,
    )
    assert r.returncode == 0
    assert "dominant=" not in r.stdout  # skipped, not lowered


def test_multidevice_esgd_executes():
    """The production mpi-ESGD step EXECUTES (not just lowers) on a real
    (pod=2, data=2, model=2) host mesh: loss descends and the elastic
    exchange contracts replica spread."""
    r = _run(
        [sys.executable, "examples/multidevice_train.py"],
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        timeout=560,
    )
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    assert "consensus model" in r.stdout
    lines = [l for l in r.stdout.splitlines() if l.startswith("step")]
    first = float(lines[0].split()[3])
    last = float(lines[-1].split()[3])
    assert last < first  # learned
