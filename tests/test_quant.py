"""Beyond-paper int8 push compression: kernel vs oracle, KVStore
integration, and end-to-end ESGD convergence under compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.quant_bucket.ops import compress, compressed_bytes, decompress
from repro.kernels.quant_bucket.quant_bucket import (
    QBLOCK,
    dequantize_flat,
    quantize_flat,
)
from repro.kernels.quant_bucket.ref import dequantize_ref, quantize_ref


@pytest.mark.parametrize("n", [8, QBLOCK, QBLOCK + 17, 5 * QBLOCK])
def test_quantize_matches_ref(n):
    x = jax.random.normal(jax.random.key(0), (n,)) * 2.5
    c, s = quantize_flat(x)
    rc, rs = quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))
    np.testing.assert_allclose(s, rs, rtol=1e-6)
    back = dequantize_flat(c, s, n)
    rback = dequantize_ref(rc, rs, n)
    np.testing.assert_allclose(back, rback, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**29))
def test_quantization_error_bound(n, scale, seed):
    """Per-block relative error is bounded by 1/127 of the block absmax."""
    x = jax.random.normal(jax.random.key(seed), (n,)) * scale
    c, s = quantize_flat(x)
    back = dequantize_flat(c, s, n)
    err = np.asarray(jnp.abs(back - x))
    # error per element <= scale/2 of its block = absmax/254
    pad = (-n) % QBLOCK
    xp = np.asarray(jnp.pad(x, (0, pad))).reshape(-1, QBLOCK)
    bound = np.abs(xp).max(axis=1) / 127.0  # one quantization step
    errp = np.pad(err, (0, pad)).reshape(-1, QBLOCK)
    assert (errp <= bound[:, None] * 0.51 + 1e-9).all()


def test_compress_pytree_roundtrip_and_ratio():
    tree = {"a": jax.random.normal(jax.random.key(1), (QBLOCK * 3,)),
            "b": {"c": jax.random.normal(jax.random.key(2), (64, 9))}}
    codes, scales = compress(tree)
    rec = decompress(codes, scales, tree)
    jax.tree.map(
        lambda r, o: np.testing.assert_allclose(r, o, atol=0.06), rec, tree)
    raw = sum(l.size * 4 for l in jax.tree_util.tree_leaves(tree))
    assert raw / compressed_bytes(tree) > 3.5


def test_kvstore_compressed_push_counts_bytes():
    from repro.core.kvstore import KVStore

    kv = KVStore.create("dist_async", num_workers=1, wire_dtype="int8")
    kv.init("w", jnp.zeros((QBLOCK * 4,), jnp.float32))
    kv.set_elastic(0.5)
    kv.push("w", jnp.ones((QBLOCK * 4,), jnp.float32))
    assert kv.pushed_bytes < 0.3 * kv.pushed_bytes_uncompressed
    # server applied the (de-quantized) elastic update
    np.testing.assert_allclose(kv.value("w"), 0.5 * jnp.ones(QBLOCK * 4),
                               atol=0.01)


def test_esgd_converges_with_compressed_pushes():
    """ESGD tolerates int8 PS pushes (the quantization noise is absorbed
    by the elastic force) — the cheap-wire variant still learns."""
    from repro.core.algorithms import AlgoConfig, run
    from repro.data.pipeline import DataConfig, ImagePipeline

    D, NCLS = 8 * 8 * 3, 10

    def init_fn(key):
        return {"w": jax.random.normal(key, (D, NCLS)) * 0.01,
                "b": jnp.zeros((NCLS,))}

    def loss(params, batch):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        logits = x @ params["w"] + params["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
        return jnp.mean(lse - gold)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    test = ImagePipeline(DataConfig(seed=0, batch_size=256,
                                    steps_per_epoch=1, shard=321),
                         image_size=8)
    tb = test.batch_at(50, 0)

    def eval_fn(p):
        x = tb["images"].reshape(256, -1)
        logits = x @ p["w"] + p["b"]
        return float(jnp.mean(
            (jnp.argmax(logits, -1) == tb["labels"]).astype(jnp.float32)))

    def make_pipe(w):
        return ImagePipeline(DataConfig(seed=0, batch_size=16,
                                        steps_per_epoch=10, shard=w),
                             image_size=8)

    cfg = AlgoConfig(mode="mpi_esgd", num_workers=4, num_clients=2,
                     num_servers=1, lr=0.05, epochs=2, steps_per_epoch=10,
                     esgd_interval=4, compute_time=0.1, model_bytes=1e6,
                     wire_dtype="int8")
    h = run(cfg, init_fn, grad_fn, eval_fn, make_pipe)
    assert h.metrics[-1] > 0.5
