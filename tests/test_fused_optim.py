"""Fused flat AdaGrad/AdamW on the FlatBuffer substrate: the K-stream
Pallas kernels must match their oracles and the per-leaf ``optim.adagrad``
/ ``optim.adamw`` references (bf16 + f32 state, odd / non-lane-aligned
sizes, p ∈ {1, 2, 8} vmap-emulated sharding), the state must stay sharded
1/p per stream, the whole update must be ONE pallas_call, and the
production train step must ride the flat path for both optimizers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import flatbuf as F
from repro.core.comm import CollectivePolicy, Communicator
from repro.kernels.fused_optim.fused_optim import adagrad_flat, adamw_flat
from repro.kernels.fused_optim.ops import adagrad_fused, adamw_fused
from repro.kernels.fused_optim.ref import adagrad_ref, adamw_ref
from repro.optim.sgd import (
    FLAT_STATE_STREAMS,
    adagrad,
    adamw,
    flat_adagrad,
    flat_adamw,
    optstate_shard_init,
    scatter_update_gather,
)

AXIS = "ring"

ADAGRAD_HYPER = {"name": "adagrad", "lr": 0.05, "eps": 1e-10}
ADAMW_HYPER = {"name": "adamw", "lr": 0.01, "b1": 0.9, "b2": 0.95,
               "eps": 1e-8, "weight_decay": 0.01}


def _tree(seed=0, dtype=jnp.float32):
    """Odd, lane-unfriendly leaf sizes on purpose (incl. a scalar)."""
    k = jax.random.key(seed)
    ks = jax.random.split(k, 4)
    return {
        "w": jax.random.normal(ks[0], (13, 7), jnp.float32).astype(dtype),
        "b": jax.random.normal(ks[1], (5,), jnp.float32).astype(dtype),
        "deep": {"u": jax.random.normal(ks[2], (3, 11, 2),
                                        jnp.float32).astype(dtype),
                 "s": jax.random.normal(ks[3], (),
                                        jnp.float32).astype(dtype)},
    }


def _close(a, b, rtol=2e-5, atol=2e-6):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol),
        a, b)


# --------------------------------------------------------------------------
# kernels vs oracles (odd sizes, bf16 params with f32 state)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 127, 128, 129, 1000, 4096])
def test_adagrad_flat_matches_ref(n):
    k = jax.random.key(n)
    p = jax.random.normal(k, (n,))
    s = jnp.abs(jax.random.normal(jax.random.fold_in(k, 1), (n,)))
    g = jax.random.normal(jax.random.fold_in(k, 2), (n,))
    got_p, got_s = adagrad_flat(p, s, g, jnp.float32(0.05),
                                jnp.float32(1e-10))
    want_p, want_s = adagrad_ref(p, s, g, 0.05, 1e-10)
    np.testing.assert_allclose(got_p, want_p, rtol=2e-6, atol=1e-7)
    np.testing.assert_allclose(got_s, want_s, rtol=2e-6, atol=1e-7)


@pytest.mark.parametrize("n", [1, 5, 127, 129, 1000])
def test_adamw_flat_matches_ref(n):
    k = jax.random.key(n + 7)
    p = jax.random.normal(k, (n,))
    m = 0.1 * jax.random.normal(jax.random.fold_in(k, 1), (n,))
    v = jnp.abs(jax.random.normal(jax.random.fold_in(k, 2), (n,)))
    g = jax.random.normal(jax.random.fold_in(k, 3), (n,))
    t = 3
    c1 = 1.0 - 0.9 ** t
    c2 = 1.0 - 0.95 ** t
    got_p, got_mv = adamw_flat(
        p, jnp.stack([m, v]), g, jnp.float32(0.01), jnp.float32(0.9),
        jnp.float32(0.95), jnp.float32(1e-8),
        jnp.float32(0.01), jnp.float32(c1), jnp.float32(c2))
    want = adamw_ref(p, m, v, g, t, 0.01, 0.9, 0.95, 1e-8, 0.01)
    for a, b in zip((got_p, got_mv[0], got_mv[1]), want):
        np.testing.assert_allclose(a, b, rtol=2e-6, atol=1e-7)


def test_fused_ops_multiple_steps_match_optim():
    """kernels/fused_optim/ops pytree wrappers track the per-leaf
    optimizers over several steps (the fused_sgd ops parity check)."""
    k = jax.random.key(11)
    params = _tree(11)

    opt = adagrad(0.05)
    st_ = opt.init(params)
    p_k, s_k = params, jax.tree.map(jnp.zeros_like, params)
    p_l = params
    for i in range(3):
        g = jax.tree.map(
            lambda x: jax.random.normal(
                jax.random.fold_in(k, i * 13 + x.size), x.shape), params)
        p_l, st_ = opt.update(g, st_, p_l)
        p_k, s_k = adagrad_fused(p_k, s_k, g, jnp.float32(0.05),
                                 jnp.float32(1e-10))
    _close(p_k, p_l)

    opt = adamw(0.01, weight_decay=0.01)
    st_ = opt.init(params)
    p_k = params
    m_k = jax.tree.map(jnp.zeros_like, params)
    v_k = jax.tree.map(jnp.zeros_like, params)
    p_l = params
    for i in range(3):
        g = jax.tree.map(
            lambda x: jax.random.normal(
                jax.random.fold_in(k, 99 + i * 13 + x.size), x.shape), params)
        p_l, st_ = opt.update(g, st_, p_l)
        p_k, m_k, v_k = adamw_fused(
            p_k, m_k, v_k, g, jnp.int32(i + 1), jnp.float32(0.01),
            jnp.float32(0.9), jnp.float32(0.95), jnp.float32(1e-8),
            jnp.float32(0.01))
    _close(p_k, p_l, rtol=2e-4, atol=1e-5)


# --------------------------------------------------------------------------
# scatter_update_gather with K streams ≡ per-leaf allreduce + optimizer
# --------------------------------------------------------------------------

def _baseline_steps(opt, params, grads_per_dev, steps):
    st_ = opt.init(params)
    for s in range(steps):
        mean_g = jax.tree.map(lambda x: jnp.mean(x[s], 0), grads_per_dev)
        params, st_ = opt.update(mean_g, st_, params)
    return params


def _fused_steps(spec, hyper, params, grads_per_dev, steps, p, *,
                 num_rings=1, bucket_bytes=None):
    nr = F.effective_rings(spec.nbytes, num_rings, bucket_bytes)
    st0 = optstate_shard_init(hyper, spec, p, nr)
    state = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), st0)
    stacked_p = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), params)

    comm = Communicator.from_axis_name(AXIS, policy=CollectivePolicy(
        num_rings=num_rings, bucket_bytes=bucket_bytes))

    def dev_step(g, pp, s_):
        return scatter_update_gather(spec, g, pp, s_, hyper=hyper, comm=comm)

    step = jax.vmap(dev_step, axis_name=AXIS)
    for s in range(steps):
        g = jax.tree.map(lambda x: x[s], grads_per_dev)
        stacked_p, state = step(g, stacked_p, state)
    return stacked_p, state


def _grads(params, steps, p, seed=42, dtype=None):
    k = jax.random.key(seed)
    return jax.tree.map(
        lambda x: jax.random.normal(
            jax.random.fold_in(k, x.size), (steps, p) + x.shape,
            jnp.float32).astype(dtype or x.dtype),
        params)


@pytest.mark.parametrize("p", [1, 2, 8])
def test_flat_adagrad_equals_per_leaf(p):
    params = _tree(0)
    spec = F.spec_for(params)
    grads = _grads(params, 3, p)
    want = _baseline_steps(adagrad(0.05), params, grads, 3)
    got, state = _fused_steps(spec, ADAGRAD_HYPER, params, grads, 3, p)
    # accumulator stays sharded: 1/p of the padded buffer per device
    assert state.shape == (p, F.shard_size(spec, p))
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], got), want)


@pytest.mark.parametrize("p", [1, 2, 8])
def test_flat_adamw_equals_per_leaf(p):
    params = _tree(1)
    spec = F.spec_for(params)
    grads = _grads(params, 3, p)
    want = _baseline_steps(adamw(0.01, weight_decay=0.01), params, grads, 3)
    got, state = _fused_steps(spec, ADAMW_HYPER, params, grads, 3, p)
    # BOTH adaptive streams stay sharded 1/p; t counts the steps
    assert state["mv"].shape == (p, 2, F.shard_size(spec, p))
    assert state["mv"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(state["t"]), np.full((p,), 3))
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], got), want,
               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("p,num_rings,bucket_bytes",
                         [(2, 3, None), (8, 1, 512), (4, 2, 1024)])
def test_flat_adamw_ring_and_bucket_variants(p, num_rings, bucket_bytes):
    params = _tree(2)
    spec = F.spec_for(params)
    grads = _grads(params, 2, p, seed=7)
    want = _baseline_steps(adamw(0.01, weight_decay=0.01), params, grads, 2)
    got, _ = _fused_steps(spec, ADAMW_HYPER, params, grads, 2, p,
                          num_rings=num_rings, bucket_bytes=bucket_bytes)
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], got), want,
               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("hyper,leaf_opt", [
    (ADAGRAD_HYPER, adagrad(0.05)),
    (ADAMW_HYPER, adamw(0.01, weight_decay=0.01)),
])
def test_flat_optim_bf16_params_f32_state(hyper, leaf_opt):
    p = 4
    params = _tree(3, dtype=jnp.bfloat16)
    spec = F.spec_for(params)
    grads = _grads(params, 2, p, seed=9)
    want = _baseline_steps(leaf_opt, params, grads, 2)
    got, state = _fused_steps(spec, hyper, params, grads, 2, p)
    buf = state["mv"] if isinstance(state, dict) else state
    assert buf.dtype == jnp.float32
    assert jax.tree_util.tree_leaves(got)[0].dtype == jnp.bfloat16
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], got), want,
               rtol=2e-2, atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 300), min_size=1, max_size=5),
    seed=st.integers(0, 2**30),
    p=st.sampled_from([1, 2, 8]),
    lr=st.floats(1e-4, 0.1),
)
def test_flat_adagrad_property(sizes, seed, p, lr):
    k = jax.random.key(seed)
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(k, i), (n,))
              for i, n in enumerate(sizes)}
    spec = F.make_flatbuf(params)
    hyper = {"name": "adagrad", "lr": lr, "eps": 1e-10}
    grads = _grads(params, 2, p, seed=seed // 2 + 1)
    want = _baseline_steps(adagrad(lr), params, grads, 2)
    got, _ = _fused_steps(spec, hyper, params, grads, 2, p)
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], got), want,
               rtol=2e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 300), min_size=1, max_size=5),
    seed=st.integers(0, 2**30),
    p=st.sampled_from([1, 2, 8]),
    b1=st.floats(0.5, 0.99),
    b2=st.floats(0.8, 0.999),
)
def test_flat_adamw_property(sizes, seed, p, b1, b2):
    k = jax.random.key(seed)
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(k, i), (n,))
              for i, n in enumerate(sizes)}
    spec = F.make_flatbuf(params)
    hyper = {"name": "adamw", "lr": 0.01, "b1": b1, "b2": b2,
             "eps": 1e-8, "weight_decay": 0.0}
    grads = _grads(params, 2, p, seed=seed // 2 + 1)
    want = _baseline_steps(adamw(0.01, b1=b1, b2=b2), params, grads, 2)
    got, _ = _fused_steps(spec, hyper, params, grads, 2, p)
    for d in range(p):
        _close(jax.tree.map(lambda l: l[d], got), want,
               rtol=3e-4, atol=2e-5)


# --------------------------------------------------------------------------
# structural: the whole K-stream update is ONE pallas_call
# --------------------------------------------------------------------------

def _primitive_names(fn, *args):
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr):
        names = []
        for eqn in jaxpr.eqns:
            names.append(eqn.primitive.name)
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else [val]
                for v in vals:
                    if hasattr(v, "jaxpr"):
                        names += walk(v.jaxpr)
        return names

    return walk(closed.jaxpr)


@pytest.mark.parametrize("factory,leaf_opt", [
    (flat_adagrad, adagrad(0.05)),
    (flat_adamw, adamw(0.05)),
])
def test_flat_optim_is_one_kernel_launch(factory, leaf_opt):
    params = _tree(4)
    spec = F.spec_for(params)
    opt = factory(0.05, spec)
    state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    flat_names = _primitive_names(
        lambda g, s, p_: opt.update(g, s, p_), grads, state, params)
    leaf_names = _primitive_names(
        lambda g, s, p_: leaf_opt.update(g, s, p_),
        grads, leaf_opt.init(params), params)
    num_leaves = len(jax.tree_util.tree_leaves(params))
    assert flat_names.count("pallas_call") == 1
    assert leaf_names.count("pallas_call") == 0
    assert leaf_names.count("mul") >= num_leaves


def test_flat_wrappers_supported_by_engine():
    """The flat_* Optimizer wrappers (hyper name 'flat_adamw' etc.) must
    pass flat_update_supported — routing them to the per-leaf engine
    would make its layout guard reject their own init() state."""
    from repro.core.hierarchy import SyncConfig
    from repro.core.sync_engine import flat_update_supported
    from repro.optim.sgd import flat_sgd

    spec = F.spec_for(_tree(6))
    sync = SyncConfig(mode="mpi_sgd", num_clients=1)
    for fo in (flat_sgd(0.1, 0.9, spec), flat_adagrad(0.05, spec),
               flat_adamw(0.01, spec)):
        assert flat_update_supported(fo, sync, None), fo.hyper["name"]


def test_scatter_update_gather_rejects_mixed_hyper_forms():
    params = _tree(7)
    spec = F.spec_for(params)
    grads = jax.tree.map(jnp.ones_like, params)
    state = optstate_shard_init("sgd", spec)
    with pytest.raises(ValueError, match="not both"):
        scatter_update_gather(spec, grads, params, state,
                              hyper={"name": "sgd", "lr": 0.1,
                                     "momentum": 0.9},
                              weight_decay=0.01)


def test_optstate_shard_init_layouts():
    spec = F.spec_for(_tree(5))
    for p in (1, 2, 8):
        n = F.shard_size(spec, p)
        assert optstate_shard_init("sgd", spec, p).shape == (n,)
        assert optstate_shard_init("adagrad", spec, p).shape == (n,)
        ad = optstate_shard_init("adamw", spec, p)
        assert ad["mv"].shape == (2, n) and ad["t"].dtype == jnp.int32
    assert set(FLAT_STATE_STREAMS) == {"sgd", "adagrad", "adamw"}
    with pytest.raises(KeyError):
        optstate_shard_init("rmsprop", spec)


# --------------------------------------------------------------------------
# the production train step takes the flat path for adagrad/adamw
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    from repro.configs.base import get_config, reduced
    from repro.models.model import build_model

    return build_model(reduced(get_config("qwen2-0.5b")))


@pytest.mark.parametrize("opt", [adamw(3e-3), adagrad(0.05)],
                         ids=["adamw", "adagrad"])
def test_train_step_flat_adaptive_matches_per_leaf(model, opt):
    from repro.core.hierarchy import SyncConfig
    from repro.core.sync_engine import flat_update_supported
    from repro.launch.train import make_train_state, make_train_step

    k = jax.random.key(0)
    toks = jax.random.randint(k, (4, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    sync_f = SyncConfig(mode="mpi_sgd", num_clients=1, fused_update=True)
    sync_l = dataclasses.replace(sync_f, fused_update=False)
    assert flat_update_supported(opt, sync_f, None)
    assert not flat_update_supported(opt, sync_l, None)

    s_f = make_train_state(model, opt, sync_f, jax.random.key(1))
    s_l = make_train_state(model, opt, sync_l, jax.random.key(1))
    if opt.hyper["name"] == "adamw":
        # flat: the 2 adaptive streams in ONE (2, n) buffer + scalar t;
        # per-leaf: a {"m": tree, "v": tree, "t": scalar} pytree
        assert set(s_f["opt"]) == {"mv", "t"} and s_f["opt"]["mv"].ndim == 2
        assert set(s_l["opt"]) == {"m", "v", "t"}
    else:
        assert isinstance(s_f["opt"], jax.Array) and s_f["opt"].ndim == 1

    # mismatched factories fail loudly, not deep inside tree.map
    bad_step = make_train_step(model, opt, sync_l, None)
    with pytest.raises(ValueError, match="same mesh"):
        bad_step(s_f, batch)

    step_f = jax.jit(make_train_step(model, opt, sync_f, None))
    step_l = jax.jit(make_train_step(model, opt, sync_l, None))
    for _ in range(3):
        s_f, m_f = step_f(s_f, batch)
        s_l, m_l = step_l(s_l, batch)
    assert float(m_f["loss"]) == pytest.approx(float(m_l["loss"]), rel=1e-4)
    _close(s_f["params"], s_l["params"], rtol=2e-3, atol=1e-4)


def test_train_step_esgd_multiclient_adamw(model):
    """mpi_esgd C=2 with AdamW: per-client fused updates under vmap plus
    the flat elastic exchange, vs the per-leaf reference."""
    from repro.core.hierarchy import SyncConfig
    from repro.launch.train import make_train_state, make_train_step

    opt = adamw(3e-3)
    C = 2
    sync = SyncConfig(mode="mpi_esgd", num_clients=C, esgd_interval=2,
                      esgd_alpha=0.5)
    sync_leaf = dataclasses.replace(sync, fused_update=False)
    k = jax.random.key(0)
    toks = jax.random.randint(k, (4, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    cbatch = jax.tree.map(
        lambda a: a.reshape((C, a.shape[0] // C) + a.shape[1:]), batch)

    s_f = make_train_state(model, opt, sync, jax.random.key(1))
    s_l = make_train_state(model, opt, sync_leaf, jax.random.key(1))
    step_f = jax.jit(make_train_step(model, opt, sync, None))
    step_l = jax.jit(make_train_step(model, opt, sync_leaf, None))
    for i in range(4):  # crosses two INTERVAL boundaries
        s_f, m_f = step_f(s_f, cbatch)
        s_l, m_l = step_l(s_l, cbatch)
        assert float(m_f["loss"]) == pytest.approx(
            float(m_l["loss"]), rel=1e-4), i
    # AdamW's normalized updates amplify fp noise vs SGD; the loss match
    # above is the tight check
    _close(s_f["params"], s_l["params"], rtol=5e-3, atol=5e-4)
    _close(s_f["center"], s_l["center"], rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("mode", ["mpi_sgd", "mpi_asgd", "mpi_esgd"])
def test_algorithms_adamw_mode_runs(mode):
    """The six-mode simulation accepts the optimizer knob and lowers it
    onto the flat fused step (AlgoConfig.optimizer='adamw')."""
    from repro.core.algorithms import AlgoConfig, run
    from repro.data.pipeline import DataConfig, ImagePipeline

    D, NCLS = 8 * 8 * 3, 10

    def init_fn(key):
        return {"w": jax.random.normal(key, (D, NCLS)) * 0.01,
                "b": jnp.zeros((NCLS,))}

    def _loss(params, batch):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        logits = x @ params["w"] + params["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
        return jnp.mean(lse - gold)

    grad_fn = jax.jit(jax.value_and_grad(_loss))

    def eval_fn(params):
        return 0.0

    def make_pipeline(w):
        return ImagePipeline(
            DataConfig(seed=0, batch_size=16, steps_per_epoch=4, shard=w),
            image_size=8)

    cfg = AlgoConfig(mode=mode, num_workers=4, num_clients=2,
                     num_servers=1, lr=0.01, optimizer="adamw", epochs=1,
                     steps_per_epoch=4, compute_time=0.01, jitter=0.0,
                     model_bytes=1e6, seed=0, esgd_interval=2)
    h = run(cfg, init_fn, grad_fn, eval_fn, make_pipeline)
    assert len(h.losses) >= 1  # async/esgd drivers record coarser
    assert np.isfinite(h.losses).all()
