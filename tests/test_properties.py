"""Extra hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.moe import _dispatch_indices


@settings(max_examples=40, deadline=None)
@given(
    tk=st.integers(1, 200),
    e=st.integers(1, 16),
    cap=st.integers(1, 32),
    seed=st.integers(0, 2**29),
)
def test_dispatch_indices_invariants(tk, e, cap, seed):
    """Kept entries: slot < capacity, unique (expert, slot) pairs, and
    per-expert keep counts == min(count, capacity) (drops are overflow)."""
    ids = jax.random.randint(jax.random.key(seed), (tk,), 0, e)
    slot, keep = _dispatch_indices(ids, e, cap)
    slot, keep, ids = map(np.asarray, (slot, keep, ids))
    assert (slot[keep] < cap).all()
    pairs = set()
    for i in np.where(keep)[0]:
        pair = (int(ids[i]), int(slot[i]))
        assert pair not in pairs  # no slot collisions
        pairs.add(pair)
    for ex in range(e):
        n_ex = int((ids == ex).sum())
        n_kept = int(keep[ids == ex].sum())
        assert n_kept == min(n_ex, cap)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**29), theta=st.floats(1e2, 1e7))
def test_rope_preserves_norm_and_relativity(seed, theta):
    """RoPE is a rotation (norm preserved) and relative: the q·k dot
    depends only on position difference."""
    from repro.models.layers import apply_rope

    d = 32
    key = jax.random.key(seed)
    x = jax.random.normal(key, (1, 8, 1, d))
    pos = jnp.arange(8)[None, :]
    rot = apply_rope(x, pos, theta)
    np.testing.assert_allclose(
        jnp.linalg.norm(rot, axis=-1), jnp.linalg.norm(x, axis=-1),
        rtol=1e-4)
    # relativity: <rope(q,i), rope(k,j)> == <rope(q,i+s), rope(k,j+s)>
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, d))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]), theta)
        kj = apply_rope(k, jnp.asarray([[j]]), theta)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-3, abs=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**28), b=st.integers(1, 3),
       nc=st.integers(2, 4))
def test_sequence_xent_matches_full(seed, b, nc):
    """Chunked-vocab loss == full-logits loss for any chunking."""
    import repro.models.model as M
    from repro.configs.base import get_config, reduced

    cfg = reduced(get_config("qwen2-0.5b"))
    model = M.build_model(cfg)
    p = model.init(jax.random.key(seed))
    S = nc * M.XENT_CHUNK if M.XENT_CHUNK <= 64 else nc * 16
    old = M.XENT_CHUNK
    try:
        M.XENT_CHUNK = 16
        S = nc * 16
        h = jax.random.normal(jax.random.key(seed + 1), (b, S, cfg.d_model))
        labels = jax.random.randint(jax.random.key(seed + 2), (b, S), 0,
                                    cfg.vocab_size)
        chunked = M._sequence_xent(p, h, labels, cfg)
        full = M._xent(M._logits(p, h, cfg), labels)
        assert float(chunked) == pytest.approx(float(full), rel=1e-4)
    finally:
        M.XENT_CHUNK = old


@settings(max_examples=20, deadline=None)
@given(
    epoch=st.integers(0, 5), step=st.integers(0, 20),
    shard=st.integers(0, 8), seed=st.integers(0, 100),
)
def test_data_pipeline_pure_function_of_coords(epoch, step, shard, seed):
    from repro.data.pipeline import DataConfig, TokenPipeline

    cfg = DataConfig(seed=seed, vocab_size=64, seq_len=8, batch_size=2,
                     shard=shard)
    a = TokenPipeline(cfg).batch_at(epoch, step)
    b = TokenPipeline(cfg).batch_at(epoch, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    if step < 20:
        c = TokenPipeline(cfg).batch_at(epoch, step + 1)
        assert not np.array_equal(a["tokens"], c["tokens"])


def test_vocab_padding_multiples():
    from repro.configs.base import ARCH_IDS, get_config

    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab % 16 == 0  # model-axis divisibility


def test_param_count_matches_init():
    """Analytic param_count tracks the real init within 5% (excludes
    stub frontends / pos embeddings by design)."""
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models.model import build_model

    for arch in ("qwen2-0.5b", "mamba2-130m", "qwen2-moe-a2.7b"):
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.key(0))
        real = sum(l.size for l in jax.tree_util.tree_leaves(shapes))
        analytic = cfg.param_count()
        assert abs(real - analytic) / real < 0.05, (arch, real, analytic)
