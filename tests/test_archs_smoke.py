"""Per-arch smoke tests (required by the brief): a REDUCED variant of each
assigned architecture family runs one forward/train step on CPU with shape
assertions and no NaNs; decode shapes exercise serve_step where the family
supports decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config, reduced
from repro.core.hierarchy import SyncConfig
from repro.launch.train import make_train_state, make_train_step
from repro.models.model import build_model
from repro.optim.sgd import sgd

B, S = 2, 64


def _smoke_batch(cfg):
    batch = {
        "tokens": jax.random.randint(jax.random.key(0), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(1), (B, S), 0,
                                     cfg.vocab_size),
    }
    if cfg.num_image_tokens:
        text = S - cfg.num_image_tokens
        batch["tokens"] = batch["tokens"][:, :text]
        batch["labels"] = batch["labels"][:, :text]
        batch["image_embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_image_tokens, cfg.d_model),
            jnp.float32)
    if cfg.is_enc_dec:
        batch["audio_frames"] = jax.random.normal(
            jax.random.key(3), (B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def smoke_results():
    """Run each reduced arch once; individual tests assert on the result."""
    results = {}
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        optimizer = sgd(0.1, momentum=0.9)
        sync = SyncConfig(mode="mpi_sgd", num_clients=1)
        state = make_train_state(model, optimizer, sync, jax.random.key(0))
        step = jax.jit(make_train_step(model, optimizer, sync, mesh=None))
        batch = _smoke_batch(cfg)
        state, metrics = step(state, batch)
        state, metrics2 = step(state, batch)
        results[arch] = (cfg, model, state, metrics, metrics2)
    return results


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_finite_and_decreasing(smoke_results, arch):
    cfg, model, state, m1, m2 = smoke_results[arch]
    assert np.isfinite(float(m1["loss"])), arch
    assert np.isfinite(float(m2["loss"])), arch
    # two steps on the same batch must reduce the loss
    assert float(m2["loss"]) < float(m1["loss"]), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_params_finite_after_steps(smoke_results, arch):
    _, _, state, _, _ = smoke_results[arch]
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert not bool(jnp.any(jnp.isnan(leaf))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_logits_shape(smoke_results, arch):
    cfg, model, state, _, _ = smoke_results[arch]
    batch = _smoke_batch(cfg)
    logits = jax.jit(model.forward)(state["params"], batch)
    text = batch["tokens"].shape[1]
    expect_s = text + (cfg.num_image_tokens or 0)
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.padded_vocab
    assert logits.shape[1] == expect_s


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step_shapes_and_cache_progress(smoke_results, arch):
    cfg, model, state, _, _ = smoke_results[arch]
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.serve_step)(state["params"], cache, tok)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    logits3, cache3 = jax.jit(model.serve_step)(state["params"], cache2, tok)
    assert not bool(jnp.any(jnp.isnan(logits3)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_padded_vocab_logits_masked(smoke_results, arch):
    cfg, model, state, _, _ = smoke_results[arch]
    if cfg.padded_vocab == cfg.vocab_size:
        pytest.skip("no padding for this vocab")
    batch = _smoke_batch(cfg)
    logits = jax.jit(model.forward)(state["params"], batch)
    pad_region = logits[..., cfg.vocab_size :]
    assert float(jnp.max(pad_region)) < -1e20


def test_input_specs_cover_all_shapes():
    from repro.launch.dryrun import skip_reason

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for shape in INPUT_SHAPES.values():
            if skip_reason(cfg, shape):
                continue
            specs = model.input_specs(shape)
            assert "tokens" in specs
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_long_500k_skips_documented():
    """Skip rules match DESIGN.md: SSM/hybrid/SWA run, full-attn skip."""
    from repro.launch.dryrun import skip_reason

    runs = {a for a in ARCH_IDS
            if not skip_reason(get_config(a), INPUT_SHAPES["long_500k"])}
    assert runs == {"mamba2_130m", "zamba2_1_2b", "mixtral_8x7b"}
