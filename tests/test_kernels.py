"""Per-kernel allclose sweeps (interpret mode) against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.fused_elastic.ops import elastic_exchange_fused
from repro.kernels.fused_elastic.ref import elastic_exchange_ref
from repro.kernels.fused_sgd.ops import sgd_momentum_fused
from repro.kernels.fused_sgd.ref import sgd_momentum_ref
from repro.kernels.tensor_reduce.ops import group_reduce
from repro.kernels.tensor_reduce.ref import group_reduce_ref

SHAPES = [(2, 16), (4, 1000), (3, 7, 11), (8, 257), (2, 128, 3), (16, 8192)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_group_reduce_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, jnp.float32).astype(dtype)
    got = group_reduce(x)
    want = group_reduce_ref(x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [16, 128, None])
def test_group_reduce_block_sizes(block):
    x = jax.random.normal(jax.random.key(1), (5, 333))
    got = group_reduce(x, block=block)
    np.testing.assert_allclose(got, group_reduce_ref(x), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    g=st.integers(1, 9),
    n=st.integers(1, 600),
    seed=st.integers(0, 2**30),
)
def test_group_reduce_property(g, n, seed):
    x = jax.random.normal(jax.random.key(seed), (g, n))
    np.testing.assert_allclose(
        group_reduce(x), jnp.sum(x, axis=0), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [16, 255, 4096])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_elastic_matches_ref(n, dtype):
    w = jax.random.normal(jax.random.key(0), (n,), jnp.float32).astype(dtype)
    c = jax.random.normal(jax.random.key(1), (n,), jnp.float32).astype(dtype)
    nw, nc = elastic_exchange_fused(w, c, jnp.float32(0.43))
    rw, rc = elastic_exchange_ref(w, c, 0.43)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(nw, np.float32),
                               np.asarray(rw, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(nc, np.float32),
                               np.asarray(rc, np.float32), rtol=tol, atol=tol)


def test_fused_elastic_pytree_and_conservation():
    w = {"a": jax.random.normal(jax.random.key(0), (64, 3)),
         "b": jax.random.normal(jax.random.key(1), (9,))}
    c = jax.tree.map(jnp.zeros_like, w)
    nw, nc = elastic_exchange_fused(w, c, jnp.float32(0.25))
    # the elastic pair conserves w + c exactly
    for k in w:
        np.testing.assert_allclose(
            np.asarray(nw[k] + nc[k]), np.asarray(w[k] + c[k]), rtol=1e-5)


@pytest.mark.parametrize("n", [8, 1000, 5000])
def test_fused_sgd_matches_ref(n):
    key = jax.random.key(2)
    p = jax.random.normal(key, (n,))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    g = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    np_, nv = sgd_momentum_fused(p, v, g, jnp.float32(0.01), jnp.float32(0.9))
    rp, rv = sgd_momentum_ref(p, v, g, 0.01, 0.9)
    np.testing.assert_allclose(np_, rp, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(nv, rv, rtol=1e-6, atol=1e-6)


def test_fused_sgd_multiple_steps_match_optim():
    """Kernel-driven training matches the optim/sgd.py reference over steps."""
    from repro.optim.sgd import sgd

    opt = sgd(0.05, momentum=0.9)
    p_ref = {"w": jnp.ones((37,))}
    st_ref = opt.init(p_ref)
    p_k, v_k = p_ref, jax.tree.map(jnp.zeros_like, p_ref)
    for i in range(5):
        g = jax.tree.map(
            lambda x: jnp.sin(x + i).astype(jnp.float32), p_ref)
        p_ref, st_ref = opt.update(g, st_ref, p_ref)
        p_k, v_k = sgd_momentum_fused(p_k, v_k, g, jnp.float32(0.05),
                                      jnp.float32(0.9))
    np.testing.assert_allclose(p_k["w"], p_ref["w"], rtol=1e-5, atol=1e-6)
