"""Optional-hypothesis shim.

Test modules import ``given``/``settings``/``st`` from here instead of
from hypothesis directly. When hypothesis is installed the real names
pass through; when it is not (the CI container has no network), the
property tests degrade to clean skips while the plain tests in the same
module still collect and run — instead of the whole module erroring at
import time and killing collection.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in hypothesis-less CI
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy factory
        returns None (never drawn from — the test body is skipped)."""

        def __getattr__(self, name):
            def factory(*args, **kwargs):
                return None

            return factory

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def stub():
                pytest.skip("hypothesis not installed")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco
