"""The Transport abstraction: how RPC frames move between processes.

Two implementations with the SAME frame/codec layer (net/wire.py):

  ``TcpTransport``       real localhost sockets — length-prefixed frames,
                         one server thread per accepted connection, so a
                         blocking handler (the sync-barrier pull) stalls
                         only its own caller
  ``LoopbackTransport``  no sockets: the handler runs on the caller's
                         thread, but every request still round-trips
                         encode_frame/decode_frame, so byte accounting
                         and serialization are bit-identical to tcp —
                         this is the in-process reference the tcp loss
                         curves are gated bit-exact against

A server handler is ``handler(op, meta, payload) -> (meta, payload)``;
exceptions become ``{"ok": false, "error": ...}`` responses which
``Connection.request`` re-raises as ``RemoteError`` on the client.
"""
from __future__ import annotations

import socket
import threading
from typing import Callable, Optional

from repro.net import wire

Handler = Callable[[str, dict, bytes], "tuple[dict, bytes]"]


class RemoteError(RuntimeError):
    """The server-side handler raised; carries its message."""


class Connection:
    """One client endpoint: serialized request/response frames."""

    transport = "?"

    def request(self, op: str, meta: Optional[dict] = None,
                payload: bytes = b"") -> tuple[dict, bytes]:
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - trivial
        pass


class Server:
    """One serving endpoint; ``addr`` is what clients connect() to."""

    addr = "?"

    def close(self) -> None:  # pragma: no cover - trivial
        pass


def _check_response(meta: dict) -> dict:
    if not meta.pop("ok", True):
        raise RemoteError(meta.get("error", "remote handler failed"))
    return meta


def _run_handler(handler: Handler, op: str, meta: dict,
                 payload: bytes) -> bytes:
    try:
        out_meta, out_payload = handler(op, meta, payload)
        out_meta = dict(out_meta or {})
        out_meta["ok"] = True
    except Exception as e:  # noqa: BLE001 - ships the error to the caller
        out_meta = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        out_payload = b""
    return wire.encode_frame("response", out_meta, out_payload)


# ---------------------------------------------------------------------------
# TCP
# ---------------------------------------------------------------------------

class _TcpConnection(Connection):
    transport = "tcp"

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._lock = threading.Lock()
        self.bytes_sent = 0
        self.bytes_received = 0

    def _read_exact(self, n: int) -> bytes:
        chunks = []
        got = 0
        while got < n:
            chunk = self._sock.recv(n - got)
            if not chunk:
                raise wire.WireError(
                    f"connection closed mid-frame ({got}/{n} bytes)")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def request(self, op, meta=None, payload=b""):
        frame = wire.encode_frame(op, meta, payload)
        with self._lock:
            self._sock.sendall(frame)
            self.bytes_sent += len(frame)
            rop, rmeta, rpayload = wire.read_frame(self._read_exact)
        self.bytes_received += len(rpayload)
        assert rop == "response", rop
        return _check_response(rmeta), rpayload

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:  # pragma: no cover - already gone
            pass


class _TcpServer(Server):
    def __init__(self, handler: Handler, host: str, port: int):
        self._handler = handler
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self._closed = threading.Event()
        self.addr = "%s:%d" % self._sock.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"tcp-serve-{self.addr}",
            daemon=True)
        self._accept_thread.start()

    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        def read_exact(n: int) -> bytes:
            chunks, got = [], 0
            while got < n:
                chunk = conn.recv(n - got)
                if not chunk:
                    raise wire.WireError("eof")
                chunks.append(chunk)
                got += len(chunk)
            return b"".join(chunks)

        try:
            while not self._closed.is_set():
                try:
                    op, meta, payload = wire.read_frame(read_exact)
                except wire.WireError:
                    return  # peer went away (normal teardown, or a kill)
                conn.sendall(_run_handler(self._handler, op, meta, payload))
        finally:
            try:
                conn.close()
            except OSError:  # pragma: no cover
                pass

    def close(self) -> None:
        self._closed.set()
        try:
            self._sock.close()
        except OSError:  # pragma: no cover
            pass


# ---------------------------------------------------------------------------
# Loopback
# ---------------------------------------------------------------------------

_LOOPBACK: dict[str, Handler] = {}
_LOOPBACK_LOCK = threading.Lock()
_LOOPBACK_SEQ = [0]


class _LoopbackConnection(Connection):
    transport = "loopback"

    def __init__(self, addr: str):
        with _LOOPBACK_LOCK:
            if addr not in _LOOPBACK:
                raise ConnectionRefusedError(
                    f"no loopback server at {addr!r} "
                    f"(live: {sorted(_LOOPBACK)})")
        self._addr = addr
        self.bytes_sent = 0
        self.bytes_received = 0

    def request(self, op, meta=None, payload=b""):
        with _LOOPBACK_LOCK:
            handler = _LOOPBACK.get(self._addr)
        if handler is None:
            raise ConnectionResetError(f"loopback server {self._addr} closed")
        # full frame round-trip on purpose: the loopback run must put the
        # same bytes "on the wire" as tcp for the byte gates to mean it
        frame = wire.encode_frame(op, meta, payload)
        self.bytes_sent += len(frame)
        sop, smeta, spayload = wire.decode_frame(frame)
        rframe = _run_handler(handler, sop, smeta, spayload)
        rop, rmeta, rpayload = wire.decode_frame(rframe)
        self.bytes_received += len(rpayload)
        assert rop == "response", rop
        return _check_response(rmeta), rpayload


class _LoopbackServer(Server):
    def __init__(self, handler: Handler):
        with _LOOPBACK_LOCK:
            _LOOPBACK_SEQ[0] += 1
            self.addr = f"loopback:{_LOOPBACK_SEQ[0]}"
            _LOOPBACK[self.addr] = handler

    def close(self) -> None:
        with _LOOPBACK_LOCK:
            _LOOPBACK.pop(self.addr, None)


# ---------------------------------------------------------------------------
# The abstraction
# ---------------------------------------------------------------------------

class Transport:
    name = "?"

    def serve(self, handler: Handler, host: str = "127.0.0.1",
              port: int = 0) -> Server:
        raise NotImplementedError

    def connect(self, addr: str) -> Connection:
        raise NotImplementedError


class TcpTransport(Transport):
    name = "tcp"

    def serve(self, handler, host="127.0.0.1", port=0):
        return _TcpServer(handler, host, port)

    def connect(self, addr, timeout: float = 30.0):
        host, _, port = addr.rpartition(":")
        sock = socket.create_connection((host, int(port)), timeout=timeout)
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return _TcpConnection(sock)


class LoopbackTransport(Transport):
    name = "loopback"

    def serve(self, handler, host="127.0.0.1", port=0):
        return _LoopbackServer(handler)

    def connect(self, addr):
        return _LoopbackConnection(addr)


TRANSPORTS = {"tcp": TcpTransport, "loopback": LoopbackTransport}


def transport_for(name: str) -> Transport:
    try:
        return TRANSPORTS[name]()
    except KeyError:
        raise ValueError(
            f"transport must be one of {tuple(TRANSPORTS)}, got {name!r}"
        ) from None


def connect_with_retry(transport: Transport, addr: str,
                       timeout: float = 20.0,
                       interval: float = 0.1) -> Connection:
    """Connect, retrying while the peer process is still binding."""
    import time

    deadline = time.monotonic() + timeout
    while True:
        try:
            return transport.connect(addr)
        except (ConnectionError, OSError):
            if time.monotonic() >= deadline:
                raise
            time.sleep(interval)
