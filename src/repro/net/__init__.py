"""Real multi-process transport for the PS tier (paper §4.1).

Every mode in core/algorithms.py simulates the parameter-server tier
in-process. This package backs the SAME KVStore/Membership semantics
with actual inter-process communication on localhost:

  wire.py        length-prefixed binary frames (JSON header + payload)
                 and the PS-leg payload codec: the FlatBuffer-packed f32
                 buffer encoded per wire dtype (f32 raw / bf16 cast /
                 int8 codes+scales from kernels/quant_bucket), so the
                 socket carries exactly ``cost_model.ps_wire_nbytes``
  transport.py   the Transport abstraction: ``TcpTransport`` (real
                 sockets, one thread per connection) and
                 ``LoopbackTransport`` (same frames, same codec, no
                 sockets — the in-process reference)
  rendezvous.py  the scheduler process: joining servers publish their
                 address, joining workers get their PS + MPI identity
                 (core/client.py's launcher grouping) and the job
                 config; publishes the epoch'd live set
  kvserver.py    the server process: the UNTOUCHED core/kvstore.py
                 server rules on packed buffers, plus the transport-side
                 round buffering that makes the sync barrier, PR 6's
                 barrier_timeout degraded release, and membership-epoch
                 shrink/rejoin work over real sockets
  remote_kv.py   the worker-side endpoint: push/pull/pushpull/barrier/
                 register_group RPCs with the faults.py retry/backoff
                 policy applied to real deliveries
  worker.py      the per-process worker loop for dist_sgd / dist_esgd,
                 bit-compatible with core/algorithms.py's in-process
                 math (same grads, same barrier sum order, same update)
  problem.py     the shared train problem, so in-process and
                 multi-process runs compare the exact same functions

``launch/run_local.py`` spawns the launcher's emitted scripts as real OS
subprocesses and collects the per-worker metrics.
"""
