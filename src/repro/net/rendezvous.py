"""The rendezvous / scheduler endpoint (paper §4.1.2's front-end role).

The launcher computes the grouping; this process hands it out. Lifecycle:

  1. the runner (or ``python -m repro.net.rendezvous``) serves this
     handler at the address every emitted script carries in
     ``REPRO_RDZV_ADDR``
  2. each KV server binds its own serving socket, then ``join``s with
     ``role=server`` publishing that address
  3. each worker ``join``s with ``role=worker`` and receives its PS and
     MPI identity (core/client.py's ``group_workers`` — the rendezvous
     table is keyed by ``WorkerIdentity``) plus the job config
  4. workers block on ``servers`` until the full server tier is up, then
     connect their ``RemoteKVStore``s
  5. worker 0 inits the keys and raises a flag; the rest ``wait_flag``
  6. joins/leaves advance the epoch'd live set (``live`` op); barrier-
     level failure detection lives in the KV server (net/kvserver.py)

Crash recovery (PR 10): workers report their step via ``progress``; a
re-join of a rank already in the table (the supervisor's respawn, or a
push-announced straggler return) is re-admitted at a NEW epoch with a
``resume`` record carrying the tier's current step — the respawned
worker then pulls its parked state from the PS (kvserver
``get_state``) and replays forward instead of re-initializing. A
server re-join simply replaces its published address, so workers
riding ``connect_with_retry`` find the respawned server.

Ops: config, join, servers, live, leave, set_flag, wait_flag, workers,
progress, shutdown.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

from repro.core.client import WorkerIdentity, group_workers
from repro.net.transport import Connection, Transport, transport_for

#: AlgoConfig constructor args the job config ships (everything the
#: worker loop needs; ``net`` stays the default cost-model preset and
#: the collective policy rides as its own ``policy`` sub-dict)
_ALGO_FIELDS = (
    "mode", "num_workers", "num_clients", "num_servers", "lr", "momentum",
    "esgd_alpha", "esgd_interval", "epochs", "steps_per_epoch",
    "compute_time", "jitter", "model_bytes", "seed",
    "optimizer", "fused_update", "flat_exchange", "barrier_timeout",
    "push_retries", "push_backoff",
    "checkpoint_every", "restarts", "restart_backoff", "server_faults",
)


def algo_to_dict(cfg) -> dict:
    """JSON-safe AlgoConfig: the wire form the rendezvous hands out."""
    from repro.core.faults import as_schedule

    out = {k: getattr(cfg, k) for k in _ALGO_FIELDS}
    out["policy"] = cfg.policy.to_dict()
    sched = as_schedule(cfg.faults, seed=cfg.seed)
    out["faults"] = sched.format() if sched is not None else ""
    return out


def algo_from_dict(d: dict):
    from repro.core.algorithms import AlgoConfig
    from repro.core.comm import CollectivePolicy

    kw = {k: v for k, v in d.items() if k in _ALGO_FIELDS or k == "faults"}
    if not kw.get("faults"):
        kw["faults"] = None
    if not kw.get("server_faults"):
        kw["server_faults"] = None
    pol = d.get("policy")
    if pol is not None:
        kw["policy"] = CollectivePolicy.from_dict(pol)
    return AlgoConfig(**kw)


class Rendezvous:
    """Server-side rendezvous state + frame handler."""

    def __init__(self, *, num_workers: int, num_servers: int,
                 num_clients: int, algo: dict, problem: str = "logreg8",
                 outdir: str = "", transport: str = "tcp"):
        self.num_workers = num_workers
        self.num_servers = num_servers
        self.num_clients = num_clients
        self.config = {
            "algo": algo, "problem": problem, "outdir": outdir,
            "transport": transport, "num_workers": num_workers,
            "num_servers": num_servers, "num_clients": num_clients,
        }
        self.identities = group_workers(num_workers, num_clients)
        # the rendezvous table: WorkerIdentity -> join record (frozen
        # dataclasses hash stably, so identities ARE the keys)
        self.table: dict[WorkerIdentity, dict] = {}
        self.server_addrs: dict[int, str] = {}
        self._live: set[int] = set()
        self._events: list[dict] = []
        self._flags: set[str] = set()
        self._progress: dict[int, int] = {}    # rank -> last reported step
        self.shutdown = threading.Event()
        self._cond = threading.Condition()

    # -- state ---------------------------------------------------------------
    @property
    def epoch(self) -> int:
        return len(self._events)

    def _bump(self, kind: str, rank: int) -> None:
        self._events.append(
            {"epoch": self.epoch + 1, "kind": kind, "rank": rank,
             "live": sorted(self._live)})

    # -- handler -------------------------------------------------------------
    def handle(self, op: str, meta: dict, payload: bytes):
        if op == "config":
            return dict(self.config), b""
        if op == "join":
            return self._join(meta), b""
        if op == "servers":
            timeout = float(meta.get("timeout", 60.0))
            with self._cond:
                ok = self._cond.wait_for(
                    lambda: len(self.server_addrs) >= self.num_servers,
                    timeout=timeout)
            if not ok:
                raise TimeoutError(
                    f"only {len(self.server_addrs)}/{self.num_servers} "
                    f"servers joined within {timeout:g}s")
            return {"addrs": {str(r): a
                              for r, a in sorted(self.server_addrs.items())}
                    }, b""
        if op == "live":
            with self._cond:
                return {"epoch": self.epoch, "live": sorted(self._live),
                        "events": list(self._events)}, b""
        if op == "leave":
            with self._cond:
                self._live.discard(int(meta["rank"]))
                self._bump("leave", int(meta["rank"]))
            return {"epoch": self.epoch}, b""
        if op == "set_flag":
            with self._cond:
                self._flags.add(meta["name"])
                self._cond.notify_all()
            return {}, b""
        if op == "wait_flag":
            timeout = float(meta.get("timeout", 60.0))
            name = meta["name"]
            with self._cond:
                ok = self._cond.wait_for(lambda: name in self._flags,
                                         timeout=timeout)
            if not ok:
                raise TimeoutError(f"flag {name!r} not raised in {timeout:g}s")
            return {}, b""
        if op == "workers":
            with self._cond:
                return {"workers": [
                    dict(rec, rank=ident.ps.rank)
                    for ident, rec in sorted(
                        self.table.items(), key=lambda kv: kv[0].ps.rank)
                ]}, b""
        if op == "progress":
            rank, step = int(meta["rank"]), int(meta["step"])
            with self._cond:
                self._progress[rank] = max(self._progress.get(rank, -1),
                                           step)
                return {"step": self._current_step()}, b""
        if op == "shutdown":
            self.shutdown.set()
            with self._cond:
                self._cond.notify_all()
            return {}, b""
        raise ValueError(f"unknown rendezvous op {op!r}")

    def _current_step(self) -> int:
        """The tier's current step: the max any worker has reported
        (-1 before the first report)."""
        return max(self._progress.values(), default=-1)

    def _join(self, meta: dict) -> dict:
        role = meta["role"]
        rank = int(meta["rank"])
        if role == "server":
            if not 0 <= rank < self.num_servers:
                raise ValueError(
                    f"server rank {rank} outside [0, {self.num_servers})")
            with self._cond:
                self.server_addrs[rank] = meta["addr"]
                self._cond.notify_all()
            return {"config": self.config}
        if role != "worker":
            raise ValueError(f"role must be server/worker, got {role!r}")
        if not 0 <= rank < self.num_workers:
            raise ValueError(
                f"worker rank {rank} outside [0, {self.num_workers})")
        ident = self.identities[rank]
        with self._cond:
            rejoin = ident in self.table
            self.table[ident] = {
                "ps": dataclasses.asdict(ident.ps),
                "mpi": dataclasses.asdict(ident.mpi),
            }
            self._live.add(rank)
            self._bump("resume" if rejoin else "join", rank)
            rec = self.table[ident]
            out = {"config": self.config, "ps": rec["ps"],
                   "mpi": rec["mpi"], "epoch": self.epoch}
            if rejoin:
                # re-admission at a new epoch: tell the respawn where
                # the tier is so it can validate its parked-state resume
                out["resume"] = {"step": self._current_step(),
                                 "epoch": self.epoch}
        return out


def join_rendezvous(conn: Connection, role: str, rank: int,
                    addr: Optional[str] = None) -> dict:
    """Client-side join; returns the assignment dict."""
    meta: dict[str, Any] = {"role": role, "rank": rank}
    if addr is not None:
        meta["addr"] = addr
    reply, _ = conn.request("join", meta)
    return reply


def wait_servers(conn: Connection, timeout: float = 60.0) -> dict[int, str]:
    reply, _ = conn.request("servers", {"timeout": timeout})
    return {int(r): a for r, a in reply["addrs"].items()}


def main() -> None:  # pragma: no cover - process entry, tested via run_local
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser(description="rendezvous/scheduler process")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=9091)
    ap.add_argument("--config", required=True,
                    help="path to a JSON job config (the 'config' op's "
                         "payload: algo/problem/outdir/num_*)")
    ap.add_argument("--transport", default="tcp")
    ap.add_argument("--max-seconds", type=float, default=600.0,
                    help="orphan guard: exit even without a shutdown op")
    args = ap.parse_args()
    with open(args.config) as f:
        cfg = json.load(f)
    rdzv = Rendezvous(
        num_workers=cfg["num_workers"], num_servers=cfg["num_servers"],
        num_clients=cfg["num_clients"], algo=cfg["algo"],
        problem=cfg.get("problem", "logreg8"),
        outdir=cfg.get("outdir", ""),
        transport=cfg.get("transport", args.transport))
    server = transport_for(args.transport).serve(
        rdzv.handle, args.host, args.port)
    print(f"rendezvous at {server.addr}", flush=True)
    deadline = time.monotonic() + args.max_seconds
    while not rdzv.shutdown.is_set() and time.monotonic() < deadline:
        rdzv.shutdown.wait(0.2)
    server.close()


if __name__ == "__main__":  # pragma: no cover
    main()
