"""The per-process worker loop for dist_sgd / dist_esgd over a Transport.

Parity with core/algorithms.py is the contract, so the loop reuses the
in-process building blocks verbatim — ``_member_grads`` / ``_client_grad``
for gradients, ``_make_opt`` for the update rule, the elastic client
update for esgd — and only replaces the simulated KVStore calls with
RemoteKVStore RPCs:

  dist_sgd   compute grads -> push(grads) -> blocking pull of the round's
             SUM -> divide by ``count * workers_per_client`` (the same
             rescale the in-process faulted runner uses; on full rounds
             count == num_workers, so the clean run divides by exactly
             the in-process ``num_workers``) -> opt.update
  dist_esgd  local SGD; every ``esgd_interval`` iterations an atomic
             elastic_exchange (old center out, Elastic1 in) and the
             Elastic2 client update

Faults run REAL here: ``kill`` SIGKILLs the process mid-run (the
server's barrier_timeout is the failure detector), ``straggle``/``delay``
sleep wall-clock seconds, ``drop`` rides RemoteKVStore's retry/backoff.
"""
from __future__ import annotations

import os
import signal
import time
from typing import Any, Callable, Optional

import numpy as np


class WorkerKilled(Exception):
    """Raised instead of SIGKILL when the worker runs in a thread."""


def _sigkill() -> None:  # pragma: no cover - by design unreachable after
    os.kill(os.getpid(), signal.SIGKILL)


def run_worker(*, rank: int, rendezvous_addr: str, transport: str = "tcp",
               on_kill: Optional[Callable[[], None]] = None,
               rdzv_conn=None) -> dict:
    """Join the rendezvous, run the assigned mode, return the metrics
    dict (also written to ``outdir/metrics_worker_<rank>.json`` by
    ``main``). ``on_kill`` fires when the fault schedule kills this
    worker (default: real SIGKILL; loopback threads raise instead)."""
    from repro.core.faults import injector
    from repro.net.problem import build_problem
    from repro.net.remote_kv import RemoteKVStore
    from repro.net.rendezvous import (algo_from_dict, join_rendezvous,
                                      wait_servers)
    from repro.net.transport import connect_with_retry, transport_for

    tr = transport_for(transport)
    conn = rdzv_conn or connect_with_retry(tr, rendezvous_addr)
    reply = join_rendezvous(conn, "worker", rank)
    config = reply["config"]
    cfg = algo_from_dict(config["algo"])
    if cfg.workers_per_client != 1:
        raise ValueError(
            "transport workers are one process per worker: "
            "num_clients must equal num_workers "
            f"(got {cfg.num_clients} clients / {cfg.num_workers} workers)")
    prob = build_problem(config.get("problem", "logreg8"))
    addrs = wait_servers(conn)
    conns = {r: connect_with_retry(tr, a) for r, a in addrs.items()}
    inj = injector(cfg.faults, seed=cfg.seed)
    rkv = RemoteKVStore(conns, wire_dtype=cfg.effective_wire_dtype,
                        injector=inj, push_retries=cfg.push_retries,
                        push_backoff=cfg.push_backoff)
    kill = on_kill or _sigkill
    try:
        if cfg.mode == "dist_sgd":
            out = _run_dist_sgd(cfg, prob, rkv, conn, rank, inj, kill)
        elif cfg.mode == "dist_esgd":
            out = _run_dist_esgd(cfg, prob, rkv, conn, rank, inj, kill)
        else:
            raise ValueError(
                f"transport mode must be dist_sgd/dist_esgd, got "
                f"{cfg.mode!r} (async/mpi modes stay in-process for now)")
        out["rank"] = rank
        out["ps"] = reply.get("ps")
        out["mpi"] = reply.get("mpi")
        out["kv"] = rkv.stats()
        return out
    finally:
        try:
            conn.request("leave", {"rank": rank})
        except Exception:  # noqa: BLE001 - rendezvous may already be gone
            pass
        rkv.close()


def _init_key(cfg, prob, rkv, conn, rank: int, key: str, tree: Any) -> None:
    """Worker 0 inits the key server-side and raises the rendezvous
    flag; everyone else pins the local spec and waits for the flag."""
    rkv.register(key, tree)
    if rank == 0:
        rkv.init(key, tree)
        rkv.register_group(0, ("worker",), (cfg.workers_per_client,))
        conn.request("set_flag", {"name": f"init:{key}"})
    else:
        conn.request("wait_flag", {"name": f"init:{key}", "timeout": 120.0})


def _straggle_sleep(inj, unit: int, gstep: int, compute_time: float) -> None:
    if inj is None:
        return
    extra = ((inj.straggle_factor(unit, gstep) - 1.0) * compute_time
             + inj.delay(unit, gstep))
    if extra > 0:
        time.sleep(extra)


def _run_dist_sgd(cfg, prob, rkv, conn, rank, inj, kill) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.algorithms import _make_opt, _member_grads

    params = prob.init_fn(jax.random.key(cfg.seed))
    _init_key(cfg, prob, rkv, conn, rank, "grads",
              jax.tree.map(jnp.zeros_like, params))
    pipeline = prob.make_pipeline(rank)
    opt = _make_opt(cfg, params)
    opt_state = opt.init(params)
    wpc = cfg.workers_per_client

    losses: list[float] = []
    gsteps: list[int] = []
    metrics: list[float] = []
    degraded_seen = 0
    for epoch in range(cfg.epochs):
        for step in range(cfg.steps_per_epoch):
            gstep = epoch * cfg.steps_per_epoch + step
            if inj is not None and inj.is_killed(rank, gstep):
                kill()
                return {"killed_at": gstep, "losses": losses,
                        "gsteps": gsteps, "metrics": metrics}
            batches = [pipeline.batch_at(epoch, step)]
            loss, stacked = _member_grads(prob.grad_fn, params, batches)
            if inj is not None:
                stacked = inj.corrupt(stacked, rank, gstep)
            g = jax.tree.map(lambda l: l[0], stacked)
            _straggle_sleep(inj, rank, gstep, cfg.compute_time)
            rkv.push("grads", g, step=gstep, unit=rank)
            total, info = rkv.pull("grads", step=gstep, unit=rank)
            if info.get("degraded"):
                degraded_seen += 1
            if total is not None and info["count"]:
                k = info["count"]
                mean_g = jax.tree.map(lambda x: x / (k * wpc), total)
                params, opt_state = opt.update(mean_g, opt_state, params)
            losses.append(loss)
            gsteps.append(gstep)
        metrics.append(prob.eval_fn(params))
    return {"losses": losses, "gsteps": gsteps, "metrics": metrics,
            "degraded_seen": degraded_seen}


def _run_dist_esgd(cfg, prob, rkv, conn, rank, inj, kill) -> dict:
    import jax

    from repro.core.algorithms import _client_grad, _make_opt, _worker_group
    from repro.core.elastic import (elastic_client_packed,
                                    elastic_client_update)

    params0 = prob.init_fn(jax.random.key(cfg.seed))
    _init_key(cfg, prob, rkv, conn, rank, "centers", params0)
    pipeline = prob.make_pipeline(rank)
    group = _worker_group(cfg)
    opt = _make_opt(cfg, params0)
    params = params0
    opt_state = opt.init(params0)

    losses: list[float] = []
    gsteps: list[int] = []
    metrics: list[float] = []
    exchanges = 0
    for it in range(cfg.epochs * cfg.steps_per_epoch):
        if inj is not None and inj.is_killed(rank, it):
            kill()
            return {"killed_at": it, "losses": losses, "gsteps": gsteps,
                    "metrics": metrics, "exchanges": exchanges}
        epoch = min(it // cfg.steps_per_epoch, cfg.epochs - 1)
        step = it % cfg.steps_per_epoch
        batches = [pipeline.batch_at(epoch, step)]
        loss, g = _client_grad(prob.grad_fn, params, batches, group)
        if it % cfg.esgd_interval == 0:
            pushed = params
            if inj is not None:
                pushed = inj.corrupt(pushed, rank, it)
            _straggle_sleep(inj, rank, it, cfg.compute_time)
            old_center, _info = rkv.elastic_exchange(
                "centers", pushed, step=it, unit=rank)
            if old_center is not None:
                exchanges += 1
                if cfg.flat_exchange:
                    params = elastic_client_packed(
                        params, old_center, cfg.esgd_alpha)
                else:
                    params = elastic_client_update(
                        params, old_center, cfg.esgd_alpha)
        params, opt_state = opt.update(g, opt_state, params)
        losses.append(loss)
        gsteps.append(it)
        if step == cfg.steps_per_epoch - 1:
            metrics.append(prob.eval_fn(rkv.value("centers")))
    return {"losses": losses, "gsteps": gsteps, "metrics": metrics,
            "exchanges": exchanges,
            "final_center_metric": float(metrics[-1]) if metrics else None}


def main() -> None:  # pragma: no cover - process entry, tested via run_local
    import argparse
    import json

    ap = argparse.ArgumentParser(description="transport worker process")
    ap.add_argument("--rendezvous",
                    default=os.environ.get("REPRO_RDZV_ADDR"))
    ap.add_argument("--rank", type=int,
                    default=int(os.environ.get("REPRO_RANK", "0")))
    ap.add_argument("--transport", default="tcp")
    args = ap.parse_args()
    if not args.rendezvous:
        ap.error("--rendezvous (or REPRO_RDZV_ADDR) is required")
    out = run_worker(rank=args.rank, rendezvous_addr=args.rendezvous,
                     transport=args.transport)
    from repro.net.transport import connect_with_retry, transport_for

    conn = connect_with_retry(transport_for(args.transport), args.rendezvous)
    config, _ = conn.request("config")
    conn.close()
    outdir = config.get("outdir")
    if outdir:
        path = os.path.join(outdir, f"metrics_worker_{args.rank}.json")
        with open(path, "w") as f:
            json.dump(_jsonable(out), f, indent=2)


def _jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    return obj


if __name__ == "__main__":  # pragma: no cover
    main()
