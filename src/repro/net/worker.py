"""The per-process worker loop for dist_sgd / dist_esgd over a Transport.

Parity with core/algorithms.py is the contract, so the loop reuses the
in-process building blocks verbatim — ``_member_grads`` / ``_client_grad``
for gradients, ``_make_opt`` for the update rule, the elastic client
update for esgd — and only replaces the simulated KVStore calls with
RemoteKVStore RPCs:

  dist_sgd   compute grads -> push(grads) -> blocking pull of the round's
             SUM -> divide by ``count * workers_per_client`` (the same
             rescale the in-process faulted runner uses; on full rounds
             count == num_workers, so the clean run divides by exactly
             the in-process ``num_workers``) -> opt.update
  dist_esgd  local SGD; every ``esgd_interval`` iterations an atomic
             elastic_exchange (old center out, Elastic1 in) and the
             Elastic2 client update

Faults run REAL here: ``kill`` SIGKILLs the process mid-run (the
server's barrier_timeout is the failure detector), ``straggle``/``delay``
sleep wall-clock seconds, ``drop`` rides RemoteKVStore's retry/backoff.

Crash recovery (PR 10):

  resume        a respawned process (REPRO_ATTEMPT > 0) re-joins the
                rendezvous (re-admitted with a ``resume`` record), pulls
                its parked packed params + optimizer state from the PS
                (``get_state``) instead of re-initializing, and REPLAYS
                forward from the parked step: replayed pushes to already-
                released rounds are discarded as late, replayed pulls
                return each round's STORED sum (net/kvserver.py), so the
                catch-up updates are bit-identical — and at the live
                round its fresh push completes the barrier whole
  generation    kills are generation-indexed (core/faults.py): spawn a
                dies at the (a+1)-th scheduled kill, so a respawn is not
                instantly re-killed by the event that killed its parent
  state upload  every ``cfg.checkpoint_every`` completed steps the
                worker parks exact-f32 packed params+opt server-side
                (``put_state``) — the resume source
  flush         partial metrics are flushed atomically after EVERY step,
                so the pre-kill curve survives for run_local's merge
                (the killed worker's losses come from ITS data shard —
                the aggregated mean needs them)
  server death  the push+pull pair (and the esgd exchange) retries
                through ``RemoteKVStore.refresh`` with addresses
                re-resolved from the rendezvous, riding a KV server
                respawn mid-round
"""
from __future__ import annotations

import os
import signal
import time
from typing import Any, Callable, Optional

import numpy as np


class WorkerKilled(Exception):
    """Raised instead of SIGKILL when the worker runs in a thread."""


def _sigkill() -> None:  # pragma: no cover - by design unreachable after
    os.kill(os.getpid(), signal.SIGKILL)


def run_worker(*, rank: int, rendezvous_addr: str, transport: str = "tcp",
               on_kill: Optional[Callable[[], None]] = None,
               rdzv_conn=None, attempt: int = 0) -> dict:
    """Join the rendezvous, run the assigned mode, return the metrics
    dict (also written to ``outdir/metrics_worker_<rank>.json`` by
    ``main``). ``on_kill`` fires when the fault schedule kills this
    worker (default: real SIGKILL; loopback threads raise instead).
    ``attempt`` is the spawn generation (REPRO_ATTEMPT): respawns resume
    from their parked server-side state."""
    import json

    from repro.core.faults import injector
    from repro.net.problem import build_problem
    from repro.net.remote_kv import RemoteKVStore
    from repro.net.rendezvous import (algo_from_dict, join_rendezvous,
                                      wait_servers)
    from repro.net.transport import connect_with_retry, transport_for

    tr = transport_for(transport)
    conn = rdzv_conn or connect_with_retry(tr, rendezvous_addr)
    reply = join_rendezvous(conn, "worker", rank)
    config = reply["config"]
    cfg = algo_from_dict(config["algo"])
    if cfg.workers_per_client != 1:
        raise ValueError(
            "transport workers are one process per worker: "
            "num_clients must equal num_workers "
            f"(got {cfg.num_clients} clients / {cfg.num_workers} workers)")
    prob = build_problem(config.get("problem", "logreg8"))
    addrs = wait_servers(conn)
    conns = {r: connect_with_retry(tr, a) for r, a in addrs.items()}
    inj = injector(cfg.faults, seed=cfg.seed)

    def reconnect(server_rank: int):
        """Fresh connection to a (possibly respawned) server: re-resolve
        the address from the rendezvous each try — the respawn publishes
        a NEW port when it re-joins."""
        deadline = time.monotonic() + 60.0
        while True:
            fresh = wait_servers(conn)
            try:
                return tr.connect(fresh[server_rank], timeout=2.0)
            except (ConnectionError, OSError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.1)

    rkv = RemoteKVStore(conns, wire_dtype=cfg.effective_wire_dtype,
                        injector=inj, push_retries=cfg.push_retries,
                        push_backoff=cfg.push_backoff, reconnect=reconnect)
    kill = on_kill or _sigkill

    flush = None
    outdir = config.get("outdir")
    if outdir:
        path = os.path.join(outdir, f"metrics_worker_{rank}.json")

        def flush(partial: dict) -> None:
            tmp = path + ".part"
            with open(tmp, "w") as f:
                json.dump(_jsonable(dict(partial, rank=rank,
                                         attempt=attempt)), f)
            os.replace(tmp, path)

    try:
        if cfg.mode == "dist_sgd":
            out = _run_dist_sgd(cfg, prob, rkv, conn, rank, inj, kill,
                                attempt=attempt, flush=flush)
        elif cfg.mode == "dist_esgd":
            out = _run_dist_esgd(cfg, prob, rkv, conn, rank, inj, kill,
                                 attempt=attempt, flush=flush)
        else:
            raise ValueError(
                f"transport mode must be dist_sgd/dist_esgd, got "
                f"{cfg.mode!r} (async/mpi modes stay in-process for now)")
        out["rank"] = rank
        out["attempt"] = attempt
        out["resume"] = reply.get("resume")
        out["ps"] = reply.get("ps")
        out["mpi"] = reply.get("mpi")
        out["kv"] = rkv.stats()
        return out
    finally:
        try:
            conn.request("leave", {"rank": rank})
        except Exception:  # noqa: BLE001 - rendezvous may already be gone
            pass
        rkv.close()


def _init_key(cfg, prob, rkv, conn, rank: int, key: str, tree: Any) -> None:
    """Worker 0 inits the key server-side and raises the rendezvous
    flag; everyone else pins the local spec and waits for the flag."""
    rkv.register(key, tree)
    if rank == 0:
        rkv.init(key, tree)
        rkv.register_group(0, ("worker",), (cfg.workers_per_client,))
        conn.request("set_flag", {"name": f"init:{key}"})
    else:
        conn.request("wait_flag", {"name": f"init:{key}", "timeout": 120.0})


def _straggle_sleep(inj, unit: int, gstep: int, compute_time: float) -> None:
    if inj is None:
        return
    extra = ((inj.straggle_factor(unit, gstep) - 1.0) * compute_time
             + inj.delay(unit, gstep))
    if extra > 0:
        time.sleep(extra)


def _riding(rkv, fn, tries: int = 3):
    """Run ``fn()`` riding a KV-server respawn: on a connection failure
    refresh every server connection (addresses re-resolved) and retry.
    For the sync push+pull PAIR the whole pair must re-issue together —
    the re-push is either discarded as late (round in the snapshot) or
    re-forms the restored round; both read the same stored sum."""
    from repro.net import wire as _wire

    last: Optional[BaseException] = None
    for _ in range(tries):
        try:
            return fn()
        except (ConnectionError, OSError, _wire.WireError) as e:
            last = e
            if rkv.reconnect is None:
                raise
            rkv.refresh()
    assert last is not None
    raise last


def _progress(conn, rank: int, gstep: int) -> None:
    try:
        conn.request("progress", {"rank": rank, "step": gstep})
    except Exception:  # noqa: BLE001 - progress is advisory
        pass


def _park_state(cfg, rkv, rank: int, gstep: int, pspec, ospec,
                params, opt_state) -> None:
    """Upload exact-f32 packed params (+ opt state) after completing
    ``gstep`` — the respawn's resume point."""
    import numpy as _np

    sections = {"params": _np.asarray(pspec.pack(params), _np.float32)}
    if ospec is not None:
        sections["opt"] = _np.asarray(ospec.pack(opt_state), _np.float32)
    _riding(rkv, lambda: rkv.put_state(rank, gstep, sections))


def _unpark_state(rkv, rank: int, pspec, ospec):
    """The parked (params, opt_state, step) for a respawn, or None."""
    import jax.numpy as jnp

    st = _riding(rkv, lambda: rkv.get_state(rank))
    if st is None:
        return None
    params = pspec.unpack(jnp.asarray(st["sections"]["params"]))
    opt_state = None
    if ospec is not None and "opt" in st["sections"]:
        opt_state = ospec.unpack(jnp.asarray(st["sections"]["opt"]))
    return params, opt_state, st["step"]


def _run_dist_sgd(cfg, prob, rkv, conn, rank, inj, kill, *,
                  attempt: int = 0, flush=None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import flatbuf
    from repro.core.algorithms import _make_opt, _member_grads

    params = prob.init_fn(jax.random.key(cfg.seed))
    _init_key(cfg, prob, rkv, conn, rank, "grads",
              jax.tree.map(jnp.zeros_like, params))
    pipeline = prob.make_pipeline(rank)
    opt = _make_opt(cfg, params)
    opt_state = opt.init(params)
    wpc = cfg.workers_per_client
    pspec = flatbuf.spec_for(params)
    ospec = (flatbuf.spec_for(opt_state)
             if jax.tree_util.tree_leaves(opt_state) else None)

    start = 0
    resumed_from = None
    if attempt > 0:
        parked = _unpark_state(rkv, rank, pspec, ospec)
        if parked is not None:
            params, parked_opt, parked_step = parked
            if parked_opt is not None:
                opt_state = parked_opt
            start = parked_step + 1
            resumed_from = parked_step

    losses: list[float] = []
    gsteps: list[int] = []
    metrics: list[float] = []
    metric_epochs: list[int] = []
    degraded_seen = 0

    def partial() -> dict:
        return {"losses": losses, "gsteps": gsteps, "metrics": metrics,
                "metric_epochs": metric_epochs,
                "degraded_seen": degraded_seen,
                "resumed_from": resumed_from, "partial": True}

    ckpt = int(getattr(cfg, "checkpoint_every", 0) or 0)
    for gstep in range(start, cfg.epochs * cfg.steps_per_epoch):
        epoch, step = divmod(gstep, cfg.steps_per_epoch)
        if inj is not None and inj.is_killed(rank, gstep, attempt):
            kill()
            return dict(partial(), killed_at=gstep)
        batches = [pipeline.batch_at(epoch, step)]
        loss, stacked = _member_grads(prob.grad_fn, params, batches)
        if inj is not None:
            stacked = inj.corrupt(stacked, rank, gstep)
        g = jax.tree.map(lambda l: l[0], stacked)
        _straggle_sleep(inj, rank, gstep, cfg.compute_time)

        def pair(g=g, gstep=gstep):
            rkv.push("grads", g, step=gstep, unit=rank)
            return rkv.pull("grads", step=gstep, unit=rank)

        total, info = _riding(rkv, pair)
        if info.get("degraded"):
            degraded_seen += 1
        if total is not None and info["count"]:
            k = info["count"]
            mean_g = jax.tree.map(lambda x: x / (k * wpc), total)
            params, opt_state = opt.update(mean_g, opt_state, params)
        losses.append(loss)
        gsteps.append(gstep)
        if step == cfg.steps_per_epoch - 1:
            metrics.append(prob.eval_fn(params))
            metric_epochs.append(epoch)
        if ckpt and (gstep + 1) % ckpt == 0:
            _park_state(cfg, rkv, rank, gstep, pspec, ospec,
                        params, opt_state)
        _progress(conn, rank, gstep)
        if flush is not None:
            flush(partial())
    return dict(partial(), partial=False)


def _run_dist_esgd(cfg, prob, rkv, conn, rank, inj, kill, *,
                   attempt: int = 0, flush=None) -> dict:
    import jax

    from repro.core import flatbuf
    from repro.core.algorithms import _client_grad, _make_opt, _worker_group
    from repro.core.elastic import (elastic_client_packed,
                                    elastic_client_update)

    params0 = prob.init_fn(jax.random.key(cfg.seed))
    _init_key(cfg, prob, rkv, conn, rank, "centers", params0)
    pipeline = prob.make_pipeline(rank)
    group = _worker_group(cfg)
    opt = _make_opt(cfg, params0)
    params = params0
    opt_state = opt.init(params0)
    pspec = flatbuf.spec_for(params0)
    ospec = (flatbuf.spec_for(opt_state)
             if jax.tree_util.tree_leaves(opt_state) else None)

    start = 0
    resumed_from = None
    if attempt > 0:
        parked = _unpark_state(rkv, rank, pspec, ospec)
        if parked is not None:
            params, parked_opt, parked_step = parked
            if parked_opt is not None:
                opt_state = parked_opt
            start = parked_step + 1
            resumed_from = parked_step

    losses: list[float] = []
    gsteps: list[int] = []
    metrics: list[float] = []
    metric_epochs: list[int] = []
    exchanges = 0

    def partial() -> dict:
        return {"losses": losses, "gsteps": gsteps, "metrics": metrics,
                "metric_epochs": metric_epochs, "exchanges": exchanges,
                "resumed_from": resumed_from, "partial": True}

    ckpt = int(getattr(cfg, "checkpoint_every", 0) or 0)
    for it in range(start, cfg.epochs * cfg.steps_per_epoch):
        if inj is not None and inj.is_killed(rank, it, attempt):
            kill()
            return dict(partial(), killed_at=it)
        epoch = min(it // cfg.steps_per_epoch, cfg.epochs - 1)
        step = it % cfg.steps_per_epoch
        batches = [pipeline.batch_at(epoch, step)]
        loss, g = _client_grad(prob.grad_fn, params, batches, group)
        if it % cfg.esgd_interval == 0:
            pushed = params
            if inj is not None:
                pushed = inj.corrupt(pushed, rank, it)
            _straggle_sleep(inj, rank, it, cfg.compute_time)
            old_center, _info = _riding(
                rkv, lambda p=pushed, it=it: rkv.elastic_exchange(
                    "centers", p, step=it, unit=rank))
            if old_center is not None:
                exchanges += 1
                if cfg.flat_exchange:
                    params = elastic_client_packed(
                        params, old_center, cfg.esgd_alpha)
                else:
                    params = elastic_client_update(
                        params, old_center, cfg.esgd_alpha)
        params, opt_state = opt.update(g, opt_state, params)
        losses.append(loss)
        gsteps.append(it)
        if step == cfg.steps_per_epoch - 1:
            metrics.append(prob.eval_fn(
                _riding(rkv, lambda: rkv.value("centers"))))
            metric_epochs.append(epoch)
        if ckpt and (it + 1) % ckpt == 0:
            _park_state(cfg, rkv, rank, it, pspec, ospec,
                        params, opt_state)
        _progress(conn, rank, it)
        if flush is not None:
            flush(partial())
    return dict(partial(), partial=False,
                final_center_metric=float(metrics[-1]) if metrics else None)


def main() -> None:  # pragma: no cover - process entry, tested via run_local
    import argparse
    import json

    ap = argparse.ArgumentParser(description="transport worker process")
    ap.add_argument("--rendezvous",
                    default=os.environ.get("REPRO_RDZV_ADDR"))
    ap.add_argument("--rank", type=int,
                    default=int(os.environ.get("REPRO_RANK", "0")))
    ap.add_argument("--transport", default="tcp")
    args = ap.parse_args()
    if not args.rendezvous:
        ap.error("--rendezvous (or REPRO_RDZV_ADDR) is required")
    attempt = int(os.environ.get("REPRO_ATTEMPT", "0"))
    out = run_worker(rank=args.rank, rendezvous_addr=args.rendezvous,
                     transport=args.transport, attempt=attempt)
    from repro.net.transport import connect_with_retry, transport_for

    conn = connect_with_retry(transport_for(args.transport), args.rendezvous)
    config, _ = conn.request("config")
    conn.close()
    outdir = config.get("outdir")
    if outdir:
        path = os.path.join(outdir, f"metrics_worker_{args.rank}.json")
        with open(path, "w") as f:
            json.dump(_jsonable(out), f, indent=2)


def _jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    return obj


if __name__ == "__main__":  # pragma: no cover
    main()
