"""The PS server process: core/kvstore.py's server rules over a Transport.

The KVStore itself is UNTOUCHED — it runs here on single-leaf values (the
FlatBuffer-packed f32 buffer every worker ships), and every server rule
(sync-barrier assign, async optimize, elastic) is linear/pointwise, so
operating in the packed domain is exactly the in-process math.

What this module adds is the *transport half* of the barrier semantics:

  rounds        sync pushes buffer per (key, step) round; when the live
                roster has all arrived they feed the KVStore in ascending
                unit order — the SAME order the in-process simulation
                pushes in, so the f32 barrier sum is bit-identical
  degraded      a blocking pull that reaches ``first_arrival +
                barrier_timeout`` (real seconds here) releases the round
                with the survivor subset via ``kv.pull(now=...)`` — the
                KVStore's own PR-6 degraded release, now driven by the
                wall clock
  membership    units missing from a degraded round are evicted
                (``Membership.fail`` — epoch bump, expected_pushers
                shrinks); a push from an evicted unit re-joins it at the
                next epoch (a recovered straggler announces itself by
                pushing)
  consistency   every pull of a round returns the same summed value and
                the same ``count``, so every worker — including one whose
                own push was discarded — applies the same update and the
                replicas stay bit-identical

Crash durability (PR 10) adds three independent pieces:

  round values   every released round stores its summed value, so a pull
                 of an OLD round returns that round's sum (not the
                 current kv value) — the respawned worker's replay reads
                 history, and late re-pushes after a server restore are
                 discarded against the recorded round
  unit state     ``put_state``/``get_state`` park each worker's packed
                 params + optimizer state (+ step) server-side in exact
                 f32 — the respawned worker resumes from its own
                 uploaded state instead of re-initializing
  snapshots      with ``cfg.checkpoint_every`` set, every N-th sync
                 release atomically snapshots kv values, round history,
                 unit state, membership, and counters via
                 checkpoint.save_packed; a respawned server
                 ``restore_latest``s before serving. The snapshot runs
                 *before* any pull of the round is answered, so a worker
                 whose pull died mid-round safely re-issues its
                 push+pull pair: either the round is in the snapshot
                 (re-push discarded as late, pull returns the stored
                 sum) or it isn't (the round re-forms from everyone's
                 re-push) — both bit-identical, zero lost rounds.

A ``server_faults`` schedule kills the server itself: at the release of
a scheduled kill step (generation-indexed by REPRO_ATTEMPT) the process
self-SIGKILLs after the snapshot and before replying — the hardest
ordering for the workers, exercised by bench_recovery.

Ops: init, push, pull, pushpull, elastic_exchange, value, barrier,
register_group, set_elastic, set_optimizer, put_state, get_state,
snapshot, restore, stats, shutdown.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import checkpoint
from repro.core.faults import injector
from repro.core.kvstore import KVStore
from repro.core.membership import Membership
from repro.net import wire


class _Round:
    """One sync-barrier round of one key: who arrived, when it opened,
    and — once released — the summed value it produced."""

    __slots__ = ("arrived", "first_mono", "done", "count", "degraded",
                 "released_mono", "value")

    def __init__(self, first_mono: float):
        self.arrived: dict[int, np.ndarray] = {}
        self.first_mono = first_mono
        self.done = False
        self.count = 0
        self.degraded = False
        self.released_mono: Optional[float] = None
        self.value: Optional[np.ndarray] = None


class KVServer:
    """One PS server shard: transport handler around one KVStore."""

    def __init__(self, cfg, *, rank: int = 0, clock=time.monotonic,
                 ckpt_dir: Optional[str] = None, attempt: int = 0,
                 on_kill: Optional[Callable[[], None]] = None):
        import jax.numpy as jnp  # noqa: F401 - fail early if jax missing

        self.cfg = cfg
        self.rank = rank
        self.clock = clock
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(getattr(cfg, "checkpoint_every", 0) or 0)
        self.attempt = attempt
        self.on_kill = on_kill
        self._inj = injector(getattr(cfg, "server_faults", None),
                             seed=getattr(cfg, "seed", 0))
        self.wire_dtype = cfg.effective_wire_dtype
        C = cfg.effective_clients
        kv_type = {
            "dist_sgd": "dist_sync", "mpi_sgd": "sync_mpi",
            "dist_asgd": "dist_async", "mpi_asgd": "async_mpi",
            "dist_esgd": "dist_async", "mpi_esgd": "async_mpi",
        }[cfg.mode]
        self.kv = KVStore.create(
            kv_type, num_workers=cfg.num_workers,
            num_servers=cfg.num_servers, num_clients=C,
            flat_exchange=cfg.flat_exchange,
            barrier_timeout=cfg.barrier_timeout)
        if cfg.mode.endswith("esgd"):
            self.kv.set_elastic(cfg.esgd_alpha)
        self.membership = Membership(C)
        self.kv.attach_membership(self.membership)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._rounds: dict[tuple[Any, int], _Round] = {}
        self._barriers: dict[str, _Round] = {}
        # unit -> {"step", "names", "sections": {name: f32 array}}
        self._state: dict[int, dict] = {}
        self.bytes = {"push_in": 0, "pull_out": 0,
                      "exchange_in": 0, "exchange_out": 0,
                      "state_in": 0, "state_out": 0}
        self.degraded_latencies: list[float] = []
        self.snapshots = 0
        self.restored_from: Optional[str] = None
        self.restored_step: Optional[int] = None
        self._async_ops = 0     # snapshot cadence for the async/esgd path
        self.shutdown = threading.Event()

    # -- helpers -------------------------------------------------------------
    def _round(self, key: Any, step: int) -> _Round:
        r = self._rounds.get((key, step))
        if r is None:
            r = self._rounds[(key, step)] = _Round(self.clock())
        return r

    def _rejoin(self, unit: int) -> None:
        """A push from an evicted unit is its re-entry announcement."""
        if not self.membership.is_live(unit):
            self.membership.join(unit)

    def _release(self, key: Any, step: int, *, degraded: bool) -> None:
        """Feed the round's pushes to the KVStore in ascending unit order
        (the in-process simulation's ``for c in range(C)`` order — the
        f32 sum is bit-identical) and let its barrier/degraded logic run.
        Units missing from a degraded round are evicted."""
        import jax.numpy as jnp

        r = self._rounds[(key, step)]
        for u in sorted(r.arrived):
            self.kv.push(key, jnp.asarray(r.arrived[u]), at=0.0, unit=u)
        if degraded:
            # forces the store's own short release (degraded_syncs++)
            self.kv.pull(key, now=(self.kv.barrier_timeout or 0.0) + 1.0)
        r.done = True
        r.degraded = degraded
        r.count = self.kv.last_barrier_count or len(r.arrived)
        r.released_mono = self.clock()
        r.value = np.asarray(self.kv.value(key), dtype=np.float32).copy()
        if degraded:
            self.degraded_latencies.append(r.released_mono - r.first_mono)
            for u in list(self.membership.live):
                if u not in r.arrived and self.membership.live_count > 1:
                    self.membership.fail(u)
        r.arrived.clear()   # the stored value is the record now
        # durability point: the snapshot lands BEFORE any pull of this
        # round is answered, so a worker whose pull dies with us can
        # always re-issue its push+pull pair against the restore
        if self.ckpt_every and self.ckpt_dir and step % self.ckpt_every == 0:
            self._snapshot_locked(step)
        if (self.on_kill is not None and self._inj is not None
                and self._inj.is_killed(self.rank, step, self.attempt)):
            self.on_kill()
        self._cond.notify_all()

    def _deadline(self, r: _Round) -> Optional[float]:
        if self.kv.barrier_timeout is None:
            return None
        return r.first_mono + self.kv.barrier_timeout

    def _decode(self, meta: dict, payload: bytes) -> np.ndarray:
        return np.ascontiguousarray(wire.decode_buffer(meta, payload))

    def _encode_value(self, key: Any) -> tuple[dict, bytes]:
        return wire.encode_buffer(np.asarray(self.kv.value(key)),
                                  self.wire_dtype)

    def _pull_info(self, r: Optional[_Round], key: Any = None) -> dict:
        return {
            "count": (r.count if r is not None
                      else self.kv.push_count.get(key, 0)),
            "degraded": bool(r.degraded) if r is not None else False,
            "epoch": self.membership.epoch,
            "live": list(self.membership.live),
        }

    # -- the handler ---------------------------------------------------------
    def handle(self, op: str, meta: dict, payload: bytes):
        if op == "init":
            return self._op_init(meta, payload)
        if op == "push":
            return self._op_push(meta, payload)
        if op == "pull":
            return self._op_pull(meta)
        if op == "pushpull":
            self._op_push(meta, payload)
            return self._op_pull(meta)
        if op == "elastic_exchange":
            return self._op_exchange(meta, payload)
        if op == "value":
            with self._lock:
                vmeta, vpayload = wire.encode_buffer(
                    np.asarray(self.kv.value(meta["key"])), None)
            return vmeta, vpayload
        if op == "barrier":
            return self._op_barrier(meta)
        if op == "register_group":
            return self._op_register_group(meta)
        if op == "set_elastic":
            with self._lock:
                self.kv.set_elastic(float(meta["alpha"]))
            return {}, b""
        if op == "set_optimizer":
            return self._op_set_optimizer(meta)
        if op == "put_state":
            return self._op_put_state(meta, payload)
        if op == "get_state":
            return self._op_get_state(meta)
        if op == "snapshot":
            with self._cond:
                step = int(meta.get("step", self._max_released_step()))
                path = self._snapshot_locked(step)
            return {"path": path, "step": step}, b""
        if op == "restore":
            info = self.restore_latest()
            return info or {"restored": False}, b""
        if op == "stats":
            return self._op_stats()
        if op == "shutdown":
            self.shutdown.set()
            return {}, b""
        raise ValueError(f"unknown kvserver op {op!r}")

    # -- ops -----------------------------------------------------------------
    def _op_init(self, meta: dict, payload: bytes):
        import jax.numpy as jnp

        key = meta["key"]
        buf = jnp.asarray(self._decode(meta, payload))
        with self._lock:
            if key in self.kv.keys():
                return {"existing": True}, b""  # idempotent re-init
            self.kv.init(key, buf)
        return {"existing": False}, b""

    def _op_push(self, meta: dict, payload: bytes):
        import jax.numpy as jnp

        key, unit = meta["key"], int(meta["unit"])
        step = int(meta.get("step", 0))
        buf = self._decode(meta, payload)
        with self._cond:
            self.bytes["push_in"] += len(payload)
            self._rejoin(unit)
            if not self.kv.is_sync:
                self.kv.push(key, jnp.asarray(buf), unit=unit)
                return {"applied": True, "late": False}, b""
            r = self._round(key, step)
            if r.done:
                self.kv.late_pushes += 1
                return {"applied": False, "late": True}, b""
            r.arrived[unit] = buf
            if len(r.arrived) >= self.kv.expected_pushers:
                self._release(key, step, degraded=False)
            return {"applied": True, "late": False}, b""

    def _op_pull(self, meta: dict):
        key = meta["key"]
        step = int(meta.get("step", 0))
        with self._cond:
            if not self.kv.is_sync:
                vmeta, vpayload = self._encode_value(key)
                self.bytes["pull_out"] += len(vpayload)
                info = self._pull_info(None, key)
                return dict(vmeta, **info), vpayload
            r = self._round(key, step)
            while not r.done and not self.shutdown.is_set():
                deadline = self._deadline(r)
                if deadline is None:
                    self._cond.wait(0.1)
                    continue
                nowm = self.clock()
                if nowm >= deadline:
                    if r.arrived:
                        self._release(key, step, degraded=True)
                    else:
                        # every push of the round was lost: no update,
                        # the round just burned the timeout
                        r.done = True
                        r.degraded = True
                        r.count = 0
                        r.released_mono = nowm
                        self._cond.notify_all()
                    break
                self._cond.wait(min(0.05, deadline - nowm))
            info = self._pull_info(r)
            if r.count == 0:
                return dict(info, shape=[], wire="f32"), b""
            # the ROUND's stored sum, not the current kv value: a replayed
            # pull of an old round must read history (resume-by-replay)
            if r.value is not None:
                vmeta, vpayload = wire.encode_buffer(r.value, self.wire_dtype)
            else:
                vmeta, vpayload = self._encode_value(key)
            self.bytes["pull_out"] += len(vpayload)
            return dict(vmeta, **info), vpayload

    def _op_exchange(self, meta: dict, payload: bytes):
        """Atomic elastic exchange: return the pre-push center and apply
        Elastic1 under one lock — the in-process ``old = kv.value();
        kv.push()`` pair without a pull/push race between workers."""
        import jax.numpy as jnp

        key, unit = meta["key"], int(meta.get("unit", 0))
        buf = self._decode(meta, payload)
        with self._lock:
            self.bytes["exchange_in"] += len(payload)
            old = np.asarray(self.kv.value(key))
            self.kv.push(key, jnp.asarray(buf), unit=unit)
        vmeta, vpayload = wire.encode_buffer(old, self.wire_dtype)
        self.bytes["exchange_out"] += len(vpayload)
        return dict(vmeta, epoch=self.membership.epoch,
                    live=list(self.membership.live)), vpayload

    def _op_barrier(self, meta: dict):
        """A named one-shot barrier over the live roster, honoring the
        same timeout/degraded policy as the data barrier."""
        name, unit = meta["name"], int(meta["unit"])
        with self._cond:
            b = self._barriers.get(name)
            if b is None:
                b = self._barriers[name] = _Round(self.clock())
            if not b.done:
                b.arrived[unit] = np.zeros(0)
                if len(b.arrived) >= self.kv.expected_pushers:
                    b.done = True
                    b.count = len(b.arrived)
                    self._cond.notify_all()
            while not b.done and not self.shutdown.is_set():
                deadline = self._deadline(b)
                if deadline is not None and self.clock() >= deadline:
                    b.done = True
                    b.degraded = True
                    b.count = len(b.arrived)
                    self._cond.notify_all()
                    break
                self._cond.wait(0.05 if deadline is None
                                else min(0.05, deadline - self.clock()))
            return {"count": b.count, "degraded": b.degraded}, b""

    def _op_register_group(self, meta: dict):
        from repro.core.comm import Communicator

        axes = tuple(meta.get("axes", ("worker",)))
        sizes = tuple(int(s) for s in meta.get("sizes", (1,)))
        with self._lock:
            self.kv.register_group(
                meta["gid"], Communicator.world(axes, sizes))
        return {"size": int(np.prod(sizes))}, b""

    def _op_set_optimizer(self, meta: dict):
        from repro.optim.sgd import adagrad, adamw, sgd

        name = meta.get("name", "sgd")
        lr = float(meta.get("lr", 0.1))
        make = {"sgd": lambda: sgd(lr, float(meta.get("momentum", 0.0))),
                "adagrad": lambda: adagrad(lr),
                "adamw": lambda: adamw(lr)}.get(name)
        if make is None:
            raise ValueError(f"optimizer must be sgd/adagrad/adamw, "
                             f"got {name!r}")
        with self._lock:
            self.kv.set_optimizer(make(),
                                  rescale=float(meta.get("rescale", 1.0)))
        return {}, b""

    # -- durable state: per-unit parking + whole-server snapshots ------------
    def _op_put_state(self, meta: dict, payload: bytes):
        """Park one unit's packed params/opt sections (exact f32 — resume
        must be bit-exact, so the wire codec is bypassed)."""
        unit, step = int(meta["unit"]), int(meta["step"])
        names = [str(n) for n in meta["sections"]]
        sizes = [int(s) for s in meta["sizes"]]
        arr = np.frombuffer(payload, np.float32)
        if arr.size != sum(sizes):
            raise ValueError(
                f"put_state payload has {arr.size} f32 values but the "
                f"section table sums to {sum(sizes)}")
        sections, off = {}, 0
        for name, size in zip(names, sizes):
            sections[name] = arr[off:off + size].copy()
            off += size
        with self._cond:
            self.bytes["state_in"] += len(payload)
            self._state[unit] = {"step": step, "names": names,
                                 "sections": sections}
        return {"stored": True, "step": step}, b""

    def _op_get_state(self, meta: dict):
        unit = int(meta["unit"])
        with self._cond:
            st = self._state.get(unit)
            if st is None:
                return {"found": False}, b""
            payload = b"".join(np.asarray(st["sections"][n], np.float32)
                               .tobytes() for n in st["names"])
            self.bytes["state_out"] += len(payload)
            return {"found": True, "step": st["step"],
                    "sections": list(st["names"]),
                    "sizes": [int(st["sections"][n].size)
                              for n in st["names"]]}, payload

    def _max_released_step(self) -> int:
        done = [s for (_, s), r in self._rounds.items() if r.done]
        return max(done) if done else 0

    def _snapshot_locked(self, step: int) -> Optional[str]:
        """Atomic durable snapshot (caller holds the lock): kv values,
        released-round sums, parked unit state, membership history, and
        counters. Returns the written path (None without a ckpt_dir)."""
        if not self.ckpt_dir:
            return None
        arrays: dict[str, np.ndarray] = {}
        keys = list(self.kv.keys())
        for i, key in enumerate(keys):
            arrays[f"kv:{i}"] = np.asarray(self.kv.value(key))
        rounds = []
        for (key, rstep), r in sorted(self._rounds.items(),
                                      key=lambda kv: (str(kv[0][0]),
                                                      kv[0][1])):
            if not r.done:
                continue    # partial arrivals re-form from re-pushes
            if r.value is not None:
                arrays[f"round:{len(rounds)}"] = r.value
            rounds.append([key, rstep, r.count, bool(r.degraded),
                           r.value is not None])
        state_meta = {}
        for unit, st in self._state.items():
            for i, name in enumerate(st["names"]):
                arrays[f"state:{unit}:{i}"] = st["sections"][name]
            state_meta[str(unit)] = {"step": st["step"],
                                     "names": list(st["names"])}
        meta = {
            "keys": keys,
            "rounds": rounds,
            "state": state_meta,
            "membership": [[e.kind, e.member]
                           for e in self.membership.history
                           if e.kind != "init"],
            "counters": {
                "degraded_syncs": self.kv.degraded_syncs,
                "late_pushes": self.kv.late_pushes,
                "last_barrier_count": self.kv.last_barrier_count,
                "push_count": {str(k): v
                               for k, v in self.kv.push_count.items()},
            },
        }
        path = checkpoint.checkpoint_path(self.ckpt_dir, step)
        checkpoint.save_packed(path, arrays, step=step, metadata=meta)
        self.snapshots += 1
        return path

    def restore_latest(self) -> Optional[dict]:
        """Load the newest complete snapshot (torn files skipped) and
        rebuild kv values, round history, unit state, and membership.
        No-op (returns None) without a ckpt_dir or prior snapshot."""
        import jax.numpy as jnp

        if not self.ckpt_dir:
            return None
        path = checkpoint.latest_checkpoint(self.ckpt_dir)
        if path is None:
            return None
        arrays, meta = checkpoint.restore_packed(path)
        with self._cond:
            for i, key in enumerate(meta["keys"]):
                if key not in self.kv.keys():
                    self.kv.init(key, jnp.asarray(arrays[f"kv:{i}"]))
            n_val = 0
            for key, rstep, count, degraded, has_value in meta["rounds"]:
                r = _Round(self.clock())
                r.done = True
                r.count = int(count)
                r.degraded = bool(degraded)
                r.released_mono = self.clock()
                if has_value:
                    r.value = np.asarray(arrays[f"round:{n_val}"],
                                         np.float32)
                    n_val += 1
                self._rounds[(key, int(rstep))] = r
            for unit_s, st in meta["state"].items():
                unit = int(unit_s)
                sections = {
                    name: np.asarray(arrays[f"state:{unit}:{i}"],
                                     np.float32)
                    for i, name in enumerate(st["names"])}
                self._state[unit] = {"step": int(st["step"]),
                                     "names": list(st["names"]),
                                     "sections": sections}
            for kind, member in meta["membership"]:
                if kind == "join":
                    if not self.membership.is_live(member):
                        self.membership.join(member)
                elif self.membership.is_live(member):
                    getattr(self.membership, kind)(member)
            c = meta["counters"]
            self.kv.degraded_syncs = c["degraded_syncs"]
            self.kv.late_pushes = c["late_pushes"]
            self.kv.last_barrier_count = c["last_barrier_count"]
            for k, v in c["push_count"].items():
                self.kv.push_count[k] = v
            self.restored_from = path
            self.restored_step = int(meta.get("step", 0))
            self._cond.notify_all()
        return {"restored": True, "path": path, "step": self.restored_step}

    def _op_stats(self):
        with self._lock:
            return {
                "rank": self.rank,
                "degraded_syncs": self.kv.degraded_syncs,
                "late_pushes": self.kv.late_pushes,
                "last_barrier_count": self.kv.last_barrier_count,
                "push_count": dict(self.kv.push_count),
                "membership_epoch": self.membership.epoch,
                "live": list(self.membership.live),
                "membership_history": [
                    {"epoch": e.epoch, "kind": e.kind, "member": e.member,
                     "live": list(e.live)}
                    for e in self.membership.history],
                "bytes": dict(self.bytes),
                "degraded_latencies": list(self.degraded_latencies),
                "keys": [str(k) for k in self.kv.keys()],
                "snapshots": self.snapshots,
                "restored_from": self.restored_from,
                "restored_step": self.restored_step,
                "attempt": self.attempt,
                "state_units": sorted(self._state),
            }, b""


def _sigkill() -> None:  # pragma: no cover - kills the calling process
    import os
    import signal

    os.kill(os.getpid(), signal.SIGKILL)


def main() -> None:  # pragma: no cover - process entry, tested via run_local
    import argparse
    import json
    import os

    from repro.net.rendezvous import algo_from_dict, join_rendezvous
    from repro.net.transport import connect_with_retry, transport_for

    ap = argparse.ArgumentParser(description="PS server process")
    ap.add_argument("--rendezvous",
                    default=os.environ.get("REPRO_RDZV_ADDR"),
                    help="host:port of the rendezvous (or REPRO_RDZV_ADDR)")
    ap.add_argument("--rank", type=int,
                    default=int(os.environ.get("REPRO_RANK", "0")))
    ap.add_argument("--transport", default="tcp")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--max-seconds", type=float, default=600.0)
    args = ap.parse_args()
    if not args.rendezvous:
        ap.error("--rendezvous (or REPRO_RDZV_ADDR) is required")
    attempt = int(os.environ.get("REPRO_ATTEMPT", "0"))
    transport = transport_for(args.transport)
    conn = connect_with_retry(transport, args.rendezvous)
    config, _ = conn.request("config")
    cfg = algo_from_dict(config["algo"])
    outdir = config.get("outdir")
    ckpt_dir = None
    if outdir and getattr(cfg, "checkpoint_every", 0):
        ckpt_dir = os.path.join(outdir, f"ckpt_server_{args.rank}")
    srv = KVServer(cfg, rank=args.rank, ckpt_dir=ckpt_dir, attempt=attempt,
                   on_kill=(_sigkill if getattr(cfg, "server_faults", None)
                            else None))
    srv.restore_latest()
    server = transport.serve(srv.handle, host=args.host, port=0)
    join_rendezvous(conn, "server", args.rank, addr=server.addr)
    deadline = time.monotonic() + args.max_seconds
    while not srv.shutdown.is_set() and time.monotonic() < deadline:
        srv.shutdown.wait(0.2)
    stats, _ = srv.handle("stats", {}, b"")
    outdir = config.get("outdir")
    if outdir:
        path = os.path.join(outdir, f"metrics_server_{args.rank}.json")
        with open(path, "w") as f:
            json.dump(stats, f, indent=2)
    server.close()
    conn.close()


if __name__ == "__main__":  # pragma: no cover
    main()
