"""The worker's KVStore endpoint: core/kvstore.py's client API over a
Transport connection per server shard.

Key routing uses ``stable_server_of`` (crc32 — `hash()` is salted per
process, so the in-process ``KVStore.server_of`` rule is mirrored with a
seed-free hash both sides agree on).

Values cross the wire as FlatBuffer-packed f32 buffers encoded per wire
dtype (net/wire.py), so each push/pull payload is exactly
``cost_model.ps_wire_nbytes(spec.size, wire_dtype)`` bytes.

Fault semantics mirror ``core/faults.delivery_time``: a push attempt the
schedule drops is retried after ``backoff * 2**attempt`` REAL seconds (the
in-process simulation adds the same amount of virtual time); a push whose
every attempt drops is LOST — the worker proceeds to pull and the
server's barrier_timeout covers the hole.

Crash recovery (PR 10): an optional ``reconnect`` factory (rank -> fresh
Connection, typically rendezvous ``wait_servers`` + ``connect_with_retry``
so a respawned server's NEW address is picked up) lets the client ride a
server death — ``refresh()`` rebuilds every connection, and the
state/snapshot RPCs retry through it once. The worker loop retries its
push+pull *pair* the same way (both must re-issue together for the
restored round to re-form — see net/kvserver.py's durability notes).
``put_state``/``get_state`` park exact-f32 packed state server-side; the
bytes a resume pulls are tracked in ``state_bytes_in`` and equal
``cost_model.restore_leg_bytes`` exactly.
"""
from __future__ import annotations

import time
import zlib
from typing import Any, Callable, Optional

import numpy as np

from repro.core import flatbuf
from repro.net import wire
from repro.net.transport import Connection


def stable_server_of(key: Any, num_servers: int) -> int:
    """Process-stable key -> server shard (crc32, not salted hash())."""
    return zlib.crc32(str(key).encode()) % max(num_servers, 1)


class RemoteKVStore:
    """Client endpoint over one Connection per server shard."""

    def __init__(self, conns: dict[int, Connection], *,
                 wire_dtype: Optional[str] = None, injector=None,
                 push_retries: int = 2, push_backoff: float = 0.05,
                 sleep=time.sleep,
                 reconnect: Optional[Callable[[int], Connection]] = None):
        if not conns:
            raise ValueError("RemoteKVStore needs at least one connection")
        self.conns = dict(conns)
        self.num_servers = len(self.conns)
        self.wire_dtype = wire_dtype
        self.injector = injector
        self.push_retries = push_retries
        self.push_backoff = push_backoff
        self.sleep = sleep
        self.reconnect = reconnect
        self._specs: dict[Any, flatbuf.FlatBuffer] = {}
        self.pushed_bytes = 0
        self.pulled_bytes = 0
        self.push_count = 0
        self.pushes_lost = 0
        self.push_delay_s = 0.0
        self.state_bytes_out = 0
        self.state_bytes_in = 0
        self.reconnects = 0

    # -- plumbing ------------------------------------------------------------
    def _conn(self, key: Any) -> Connection:
        rank = stable_server_of(key, self.num_servers)
        return self.conns[sorted(self.conns)[rank]]

    def refresh(self) -> None:
        """Rebuild every server connection via the ``reconnect`` factory
        (rank -> Connection). The factory re-resolves addresses, so a
        respawned server's new port is found."""
        if self.reconnect is None:
            raise RuntimeError(
                "RemoteKVStore has no reconnect factory — pass reconnect= "
                "to ride a server respawn")
        for rank in sorted(self.conns):
            try:
                self.conns[rank].close()
            except Exception:
                pass
            self.conns[rank] = self.reconnect(rank)
        self.reconnects += 1

    def _request_riding(self, key: Any, op: str, meta: dict,
                        payload: bytes = b""):
        """One RPC that survives a single server death mid-flight: on a
        connection error, refresh and re-issue once (the ops routed here
        are idempotent server-side)."""
        try:
            return self._conn(key).request(op, meta, payload)
        except (OSError, wire.WireError):
            if self.reconnect is None:
                raise
            self.refresh()
            return self._conn(key).request(op, meta, payload)

    def _spec(self, key: Any, tree: Any = None) -> flatbuf.FlatBuffer:
        spec = self._specs.get(key)
        if spec is None:
            if tree is None:
                raise KeyError(f"key {key!r} has no registered spec")
            spec = self._specs[key] = flatbuf.spec_for(tree)
        return spec

    def _pack(self, key: Any, tree: Any) -> np.ndarray:
        import jax

        spec = self._spec(key, tree)
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) == 1 and getattr(leaves[0], "ndim", None) == 1 \
                and leaves[0].shape[0] == spec.size:
            return np.asarray(leaves[0], dtype=np.float32)
        return np.asarray(spec.pack(tree), dtype=np.float32)

    def _unpack(self, key: Any, buf: np.ndarray) -> Any:
        import jax.numpy as jnp

        spec = self._specs[key]
        return spec.unpack(jnp.asarray(buf, dtype=jnp.float32))

    def register(self, key: Any, tree: Any) -> flatbuf.FlatBuffer:
        """Pin the key's FlatBuffer spec (pack/unpack layout)."""
        return self._spec(key, tree)

    # -- RPCs ----------------------------------------------------------------
    def init(self, key: Any, tree: Any) -> bool:
        """Init the key server-side (exact f32; idempotent across
        workers — the first init wins, as with in-process worker 0)."""
        buf = self._pack(key, tree)
        meta, payload = wire.encode_buffer(buf, None)
        reply, _ = self._conn(key).request(
            "init", dict(meta, key=key), payload)
        return not reply.get("existing", False)

    def _should_drop(self, unit: int, step: int, attempt: int) -> bool:
        inj = self.injector
        return bool(inj is not None
                    and inj.should_drop(unit, step, attempt=attempt))

    def push(self, key: Any, tree: Any, *, step: int = 0,
             unit: int = 0) -> bool:
        """Push with the faults.delivery_time retry policy over real
        time. Returns False if every attempt dropped (push LOST)."""
        buf = self._pack(key, tree)
        meta, payload = wire.encode_buffer(buf, self.wire_dtype)
        meta = dict(meta, key=key, unit=unit, step=step)
        for attempt in range(1 + self.push_retries):
            if self._should_drop(unit, step, attempt):
                delay = self.push_backoff * (2 ** attempt)
                self.push_delay_s += delay
                self.sleep(delay)
                continue
            reply, _ = self._conn(key).request("push", meta, payload)
            self.push_count += 1
            self.pushed_bytes += len(payload)
            return not reply.get("late", False)
        self.pushes_lost += 1
        return False

    def pull(self, key: Any, *, step: int = 0,
             unit: int = 0) -> tuple[Any, dict]:
        """Blocking pull of the round's value. Returns ``(tree, info)``;
        ``tree`` is None when the round released empty (count == 0 —
        every push was lost; the worker skips the update, as the
        in-process all-lost round does)."""
        reply, payload = self._conn(key).request(
            "pull", {"key": key, "step": step, "unit": unit})
        info = {k: reply.get(k) for k in
                ("count", "degraded", "epoch", "live")}
        if not payload or info["count"] == 0:
            return None, info
        self.pulled_bytes += len(payload)
        buf = wire.decode_buffer(reply, payload)
        return self._unpack(key, buf), info

    def pushpull(self, key: Any, tree: Any, *, step: int = 0,
                 unit: int = 0) -> tuple[Any, dict]:
        buf = self._pack(key, tree)
        meta, payload = wire.encode_buffer(buf, self.wire_dtype)
        meta = dict(meta, key=key, unit=unit, step=step)
        reply, rpayload = self._conn(key).request("pushpull", meta, payload)
        self.push_count += 1
        self.pushed_bytes += len(payload)
        info = {k: reply.get(k) for k in
                ("count", "degraded", "epoch", "live")}
        if not rpayload or info["count"] == 0:
            return None, info
        self.pulled_bytes += len(rpayload)
        return self._unpack(key, wire.decode_buffer(reply, rpayload)), info

    def elastic_exchange(self, key: Any, tree: Any, *, step: int = 0,
                         unit: int = 0) -> tuple[Any, dict]:
        """Atomic old-center-out / Elastic1-in (the esgd interval's
        ``old = kv.value(); kv.push()`` pair). Same loss/retry policy as
        push; a lost exchange returns (None, info) and the worker skips
        the elastic step (its next interval catches up)."""
        buf = self._pack(key, tree)
        meta, payload = wire.encode_buffer(buf, self.wire_dtype)
        meta = dict(meta, key=key, unit=unit, step=step)
        for attempt in range(1 + self.push_retries):
            if self._should_drop(unit, step, attempt):
                delay = self.push_backoff * (2 ** attempt)
                self.push_delay_s += delay
                self.sleep(delay)
                continue
            reply, rpayload = self._conn(key).request(
                "elastic_exchange", meta, payload)
            self.push_count += 1
            self.pushed_bytes += len(payload)
            self.pulled_bytes += len(rpayload)
            info = {k: reply.get(k) for k in ("epoch", "live")}
            return self._unpack(key, wire.decode_buffer(reply, rpayload)), \
                info
        self.pushes_lost += 1
        return None, {"epoch": None, "live": None}

    def value(self, key: Any) -> Any:
        """Exact f32 server value (no wire quantization) — used for
        eval-time center reads and debugging."""
        reply, payload = self._conn(key).request("value", {"key": key})
        return self._unpack(key, wire.decode_buffer(reply, payload))

    def barrier(self, name: str, *, unit: int = 0) -> dict:
        """Named barrier on server 0 over the live roster."""
        reply, _ = self.conns[sorted(self.conns)[0]].request(
            "barrier", {"name": name, "unit": unit})
        return reply

    def register_group(self, gid: Any, axes, sizes) -> None:
        for rank in sorted(self.conns):
            self.conns[rank].request(
                "register_group",
                {"gid": gid, "axes": list(axes), "sizes": list(sizes)})

    def set_elastic(self, alpha: float) -> None:
        for rank in sorted(self.conns):
            self.conns[rank].request("set_elastic", {"alpha": alpha})

    # -- durable-state RPCs (crash recovery) ---------------------------------
    def _state_key(self, unit: int) -> str:
        """Routing key for a unit's parked state (stable across respawns
        and independent of the data keys)."""
        return f"state:{unit}"

    def put_state(self, unit: int, step: int,
                  sections: dict[str, np.ndarray]) -> dict:
        """Park this unit's packed state sections server-side in exact
        f32 (resume must be bit-exact — the wire codec is bypassed)."""
        names = list(sections)
        arrays = [np.asarray(sections[n], np.float32).reshape(-1)
                  for n in names]
        payload = b"".join(a.tobytes() for a in arrays)
        meta = {"unit": unit, "step": step, "sections": names,
                "sizes": [int(a.size) for a in arrays]}
        reply, _ = self._request_riding(
            self._state_key(unit), "put_state", meta, payload)
        self.state_bytes_out += len(payload)
        return reply

    def get_state(self, unit: int) -> Optional[dict]:
        """The unit's parked state, or None. Returns ``{"step": int,
        "sections": {name: f32 array}}``; the payload bytes pulled equal
        ``cost_model.restore_leg_bytes(sum of section sizes)``."""
        reply, payload = self._request_riding(
            self._state_key(unit), "get_state", {"unit": unit})
        if not reply.get("found"):
            return None
        self.state_bytes_in += len(payload)
        arr = np.frombuffer(payload, np.float32)
        sections, off = {}, 0
        for name, size in zip(reply["sections"], reply["sizes"]):
            sections[name] = arr[off:off + int(size)].copy()
            off += int(size)
        return {"step": int(reply["step"]), "sections": sections}

    def snapshot(self, *, step: Optional[int] = None) -> dict[int, dict]:
        """Force a durable snapshot on every server shard."""
        meta = {} if step is None else {"step": step}
        out = {}
        for rank in sorted(self.conns):
            reply, _ = self.conns[rank].request("snapshot", dict(meta))
            out[rank] = reply
        return out

    def restore(self) -> dict[int, dict]:
        """Ask every server shard to restore its latest snapshot."""
        out = {}
        for rank in sorted(self.conns):
            reply, _ = self.conns[rank].request("restore")
            out[rank] = reply
        return out

    def server_stats(self) -> dict[int, dict]:
        out = {}
        for rank in sorted(self.conns):
            reply, _ = self.conns[rank].request("stats")
            out[rank] = reply
        return out

    def stats(self) -> dict:
        return {
            "pushed_bytes": self.pushed_bytes,
            "pulled_bytes": self.pulled_bytes,
            "push_count": self.push_count,
            "pushes_lost": self.pushes_lost,
            "push_delay_s": self.push_delay_s,
            "state_bytes_out": self.state_bytes_out,
            "state_bytes_in": self.state_bytes_in,
            "reconnects": self.reconnects,
        }

    def close(self) -> None:
        for conn in self.conns.values():
            conn.close()
