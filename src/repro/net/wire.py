"""Frame + payload codec for the socket-backed PS tier.

A frame is::

    MAGIC(4) | header_len u32 | payload_len u32 | header JSON | payload

both length fields big-endian. The header is a small JSON dict carrying
the op name and metadata; the payload is the tensor bytes.

Payloads are FlatBuffer-packed f32 buffers (core/flatbuf.py) encoded per
wire dtype with the SAME codec the in-process collectives use
(kernels/quant_bucket):

  f32   raw little-endian f32             4n bytes
  bf16  bfloat16 cast (ml_dtypes)          2n bytes
  int8  wire_encode codes + per-128 f32    n + ceil(n/128)*4 bytes
        scales (WIRE_BLOCK buckets)

so the bytes on the socket equal ``cost_model.ps_wire_nbytes(n, wd)``
exactly — and, since every spec.size is a multiple of WIRE_BLOCK, equal
``cost_model.ps_push_bytes(4n, wd)`` too. The bench gates on the match.
"""
from __future__ import annotations

import json
import struct
from typing import Callable, Optional

import numpy as np

MAGIC = b"RKV1"
_HEAD = struct.Struct("!4sII")

#: wire bytes of one int8 scale bucket (kernels/quant_bucket.WIRE_BLOCK)
WIRE_BLOCK = 128


class WireError(RuntimeError):
    """Malformed frame (bad magic, truncated stream, bad header)."""


def encode_frame(op: str, meta: Optional[dict] = None,
                 payload: bytes = b"") -> bytes:
    header = dict(meta or {})
    header["op"] = op
    hbytes = json.dumps(header, separators=(",", ":")).encode()
    return _HEAD.pack(MAGIC, len(hbytes), len(payload)) + hbytes + payload


def decode_frame(data: bytes) -> tuple[str, dict, bytes]:
    """Inverse of ``encode_frame`` for an in-memory frame."""
    if len(data) < _HEAD.size:
        raise WireError(f"frame truncated: {len(data)} bytes")
    magic, hlen, plen = _HEAD.unpack_from(data)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    if len(data) != _HEAD.size + hlen + plen:
        raise WireError(
            f"frame length mismatch: header says {_HEAD.size + hlen + plen},"
            f" got {len(data)}")
    header = json.loads(data[_HEAD.size:_HEAD.size + hlen])
    op = header.pop("op")
    return op, header, data[_HEAD.size + hlen:]


def read_frame(read_exact: Callable[[int], bytes]) -> tuple[str, dict, bytes]:
    """Read one frame from a stream via ``read_exact(n) -> n bytes``."""
    head = read_exact(_HEAD.size)
    magic, hlen, plen = _HEAD.unpack(head)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    header = json.loads(read_exact(hlen))
    op = header.pop("op")
    return op, header, read_exact(plen)


# ---------------------------------------------------------------------------
# Payload codec: packed f32 buffer <-> wire bytes per wire dtype
# ---------------------------------------------------------------------------

def _bf16():
    import ml_dtypes

    return ml_dtypes.bfloat16


def encode_buffer(buf, wire_dtype: Optional[str] = None) -> tuple[dict, bytes]:
    """Encode a packed f32 buffer (any shape) into (meta, payload).

    The int8 form flattens, quantizes with the in-process wire codec
    (one f32 scale per WIRE_BLOCK bucket — the same bucket-for-bucket
    math as the quantized ring hops), and ships codes then scales.
    """
    arr = np.asarray(buf, dtype=np.float32)
    meta = {"shape": list(arr.shape), "wire": wire_dtype or "f32"}
    if wire_dtype in (None, "f32"):
        return meta, arr.tobytes()
    if wire_dtype == "bf16":
        return meta, np.ascontiguousarray(arr.astype(_bf16())).tobytes()
    if wire_dtype == "int8":
        import jax.numpy as jnp

        from repro.kernels.quant_bucket.quant_bucket import wire_encode

        codes, scales = wire_encode(jnp.asarray(arr.reshape(-1)))
        return meta, (np.asarray(codes).tobytes()
                      + np.asarray(scales, dtype=np.float32).tobytes())
    raise ValueError(f"wire_dtype must be None/f32/bf16/int8, "
                     f"got {wire_dtype!r}")


def decode_buffer(meta: dict, payload: bytes) -> np.ndarray:
    """Inverse of ``encode_buffer``: the receiver's f32 view."""
    shape = tuple(meta["shape"])
    n = int(np.prod(shape)) if shape else 1
    wire = meta.get("wire", "f32")
    if wire == "f32":
        return np.frombuffer(payload, np.float32, n).reshape(shape)
    if wire == "bf16":
        return np.frombuffer(payload, _bf16(), n).astype(
            np.float32).reshape(shape)
    if wire == "int8":
        import jax.numpy as jnp

        from repro.kernels.quant_bucket.quant_bucket import wire_decode

        n_pad = -(-n // WIRE_BLOCK) * WIRE_BLOCK
        codes = np.frombuffer(payload, np.int8, n_pad)
        scales = np.frombuffer(payload[n_pad:], np.float32,
                               n_pad // WIRE_BLOCK)
        out = wire_decode(jnp.asarray(codes), jnp.asarray(scales), n)
        return np.asarray(out, dtype=np.float32).reshape(shape)
    raise ValueError(f"unknown wire form {wire!r} in frame header")


def payload_nbytes(n_values: int, wire_dtype: Optional[str] = None) -> int:
    """Exact payload bytes ``encode_buffer`` emits for ``n_values`` f32
    values — the quantity ``cost_model.ps_wire_nbytes`` predicts."""
    if wire_dtype in (None, "f32"):
        return 4 * n_values
    if wire_dtype == "bf16":
        return 2 * n_values
    if wire_dtype == "int8":
        n_pad = -(-n_values // WIRE_BLOCK) * WIRE_BLOCK
        return n_pad + (n_pad // WIRE_BLOCK) * 4
    raise ValueError(f"wire_dtype must be None/f32/bf16/int8, "
                     f"got {wire_dtype!r}")
