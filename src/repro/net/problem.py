"""The shared training problem for multi-process runs.

Every process (and the in-process reference run) must build the SAME
init/grad/eval/pipeline functions for the bit-exactness gates to mean
anything, so they live here — logistic regression on the synthetic
image pipeline, the same problem tests/test_algorithms.py trains.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple


class Problem(NamedTuple):
    name: str
    init_fn: Callable[[Any], Any]
    grad_fn: Callable[[Any, Any], Any]
    eval_fn: Callable[[Any], float]
    make_pipeline: Callable[[int], Any]


@functools.lru_cache(maxsize=None)
def build_problem(name: str = "logreg8") -> Problem:
    if name != "logreg8":
        raise ValueError(f"unknown problem {name!r} (have: logreg8)")

    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import DataConfig, ImagePipeline

    D, NCLS = 8 * 8 * 3, 10

    def init_fn(key):
        return {"w": jax.random.normal(key, (D, NCLS)) * 0.01,
                "b": jnp.zeros((NCLS,))}

    def _loss(params, batch):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        logits = x @ params["w"] + params["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
        return jnp.mean(lse - gold)

    grad_fn = jax.jit(jax.value_and_grad(_loss))

    test_pipe = ImagePipeline(
        DataConfig(seed=0, batch_size=256, steps_per_epoch=1, shard=12345),
        image_size=8)
    test_batch = test_pipe.batch_at(999, 0)

    def eval_fn(params):
        x = test_batch["images"].reshape(256, -1)
        logits = x @ params["w"] + params["b"]
        return float(jnp.mean(
            (jnp.argmax(logits, -1)
             == test_batch["labels"]).astype(jnp.float32)))

    def make_pipeline(w):
        return ImagePipeline(
            DataConfig(seed=0, batch_size=16, steps_per_epoch=10, shard=w),
            image_size=8)

    return Problem(name, init_fn, grad_fn, eval_fn, make_pipeline)
