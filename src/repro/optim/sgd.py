"""Optimizers, pure-pytree (init/update), mirroring what the paper ships to
the PS via ``KVStore.set_optimizer``: SGD (+momentum), AdaGrad, AdamW, and
the Elastic server/client updates (eqs. 2/3) live in core/elastic.py.

Beyond the per-leaf tree.map optimizers, this module owns the **sharded
fused step** (``scatter_update_gather``): ring reduce-scatter the packed
flat gradient, run the fused optimizer Pallas kernel — momentum SGD,
AdaGrad or AdamW (``FLAT_STATE_STREAMS``) — on the local 1/p shard
(every full-length state stream lives sharded — a p× optimizer-memory
reduction, 2 streams' worth for AdamW), then ring-allgather the updated
params. The gradient leg waits on (p-1)/p·n bytes instead of the full
allreduce's 2·(p-1)/p·n, and the whole update is ONE Pallas grid instead
of O(num_leaves) kernels.
"""
from __future__ import annotations

import types
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flatbuf


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (g, state, p) -> (new_p, state)
    # static metadata (name + hyperparams) so drivers can lower an
    # optimizer onto its fused-kernel equivalent; empty for custom rules
    # (read-only default so default-constructed Optimizers can't alias a
    # shared mutable dict)
    hyper: Mapping = types.MappingProxyType({})


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0,
        state_dtype=None) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype or p.dtype), params
        )

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new_p = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_p, ()
        # hp momentum arithmetic, rounded back to the declared state
        # dtype only at the store — otherwise a bf16 stream would
        # silently promote to f32 on the first update (and retrace any
        # jitted step when the state aval changed)
        hp_v = jax.tree.map(
            lambda v, g: momentum * v.astype(jnp.float32)
            + g.astype(jnp.float32),
            state, grads,
        )
        new_p = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
            params, hp_v,
        )
        new_v = jax.tree.map(lambda v, s: v.astype(s.dtype), hp_v, state)
        return new_p, new_v

    return Optimizer(init, update,
                     {"name": "sgd", "lr": lr, "momentum": momentum,
                      "weight_decay": weight_decay,
                      "state_dtype": state_dtype})


def adagrad(lr: float, eps: float = 1e-10, state_dtype=None) -> Optimizer:
    sd = state_dtype or jnp.float32

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, sd), params)

    def update(grads, state, params):
        # hp accumulator arithmetic, rounded to the (possibly bf16) state
        # stream only at the store — mirroring the fused kernel, which
        # computes f32 per tile and casts on write
        hp_s = jax.tree.map(
            lambda s, g: s.astype(jnp.float32)
            + jnp.square(g.astype(jnp.float32)),
            state, grads,
        )
        new_p = jax.tree.map(
            lambda p, g, s: (
                p.astype(jnp.float32)
                - lr * g.astype(jnp.float32) / (jnp.sqrt(s) + eps)
            ).astype(p.dtype),
            params, grads, hp_s,
        )
        return new_p, jax.tree.map(lambda s: s.astype(sd), hp_s)

    return Optimizer(init, update,
                     {"name": "adagrad", "lr": lr, "eps": eps,
                      "state_dtype": state_dtype})


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    sd = state_dtype or jnp.float32

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, sd)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        # hp moment arithmetic; the (possibly bf16) streams are rounded
        # only at the store, like the fused kernel's per-tile f32 compute
        m = jax.tree.map(
            lambda m_, g: b1 * m_.astype(jnp.float32)
            + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_.astype(jnp.float32)
            + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def step(p, m_, v_):
            upd = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_p = jax.tree.map(step, params, m, v)
        cast = lambda tree: jax.tree.map(lambda l: l.astype(sd), tree)
        return new_p, {"m": cast(m), "v": cast(v), "t": t}

    return Optimizer(init, update,
                     {"name": "adamw", "lr": lr, "b1": b1, "b2": b2,
                      "eps": eps, "weight_decay": weight_decay,
                      "state_dtype": state_dtype})


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "adagrad": adagrad, "adamw": adamw}[name](lr, **kw)


# ---------------------------------------------------------------------------
# Sharded fused step: reduce-scatter -> Pallas fused update on 1/p -> allgather
# ---------------------------------------------------------------------------

#: optimizers the flat fused path can lower, with their full-length f32
#: state-stream counts (sharded 1/p alongside the momentum buffer):
#: sgd carries 1 (momentum), adagrad 1 (accumulator), adamw 2 (m, v) plus
#: the scalar step count t.
FLAT_STATE_STREAMS: Mapping[str, int] = types.MappingProxyType(
    {"sgd": 1, "adagrad": 1, "adamw": 2})


def _flat_name(hyper) -> str:
    """Canonical optimizer family of a hyper dict (``flat_*`` aliases of
    the local Optimizer wrappers map onto their per-leaf family)."""
    name = hyper if isinstance(hyper, str) else hyper["name"]
    return name[5:] if name.startswith("flat_") else name


def momentum_shard_init(spec: flatbuf.FlatBuffer, p: int = 1,
                        num_rings: int = 1,
                        bucket_bytes: int | None = None,
                        dtype=jnp.float32) -> jax.Array:
    """Zero momentum for one device's shard of the flat buffer (call under
    vmap/shard_map per device, or with p=1 for the local path)."""
    return jnp.zeros((flatbuf.shard_size(spec, p, num_rings, bucket_bytes)),
                     dtype)


def state_stream_dtype(hyper, state_dtypes=None) -> Any:
    """The dtype the flat state streams are stored in: an explicit
    ``state_dtypes`` wins, else the optimizer's ``hyper["state_dtype"]``,
    else f32. The fused kernels always COMPUTE in f32 per tile and cast
    on store, so a bf16 stream halves the state bytes per device without
    touching the update math's precision."""
    sd = state_dtypes
    if sd is None and not isinstance(hyper, str):
        sd = hyper.get("state_dtype")
    return jnp.dtype(sd) if sd is not None else jnp.float32


def optstate_shard_init(hyper, spec: flatbuf.FlatBuffer, p: int = 1,
                        num_rings: int = 1,
                        bucket_bytes: int | None = None,
                        state_dtypes=None) -> Any:
    """Zero flat optimizer state for one device's 1/p shard of the buffer
    (``momentum_shard_init`` generalized to K state streams).

    Layout per family — every full-length stream is sharded 1/p, stored
    in the declared stream dtype (``state_stream_dtype``: f32 default,
    bf16 for the low-precision streams — another 2x state-bytes cut on
    top of the 1/p sharding):

      sgd      (n,) momentum
      adagrad  (n,) accumulator
      adamw    {"mv": (2, n) first/second moments,
                "t":  ()     i32 shared step count (bias correction)}
    """
    name = _flat_name(hyper)
    sd = state_stream_dtype(hyper, state_dtypes)
    n = flatbuf.shard_size(spec, p, num_rings, bucket_bytes)
    k = FLAT_STATE_STREAMS[name]
    if name == "adamw":
        return {"mv": jnp.zeros((k, n), sd),
                "t": jnp.zeros((), jnp.int32)}
    return jnp.zeros((n,), sd)


def _fused_shard_update(name: str, hyper, p_shard: jax.Array,
                        opt_state: Any, g_shard: jax.Array,
                        interpret: bool) -> tuple[jax.Array, Any]:
    """Dispatch the ONE-grid Pallas update on this device's shard: the K
    state streams ride the same tiles as (param, grad)."""
    from repro.kernels.fused_optim.fused_optim import adagrad_flat, adamw_flat
    from repro.kernels.fused_sgd.fused_sgd import sgd_momentum_flat

    lr = jnp.float32(hyper["lr"])
    if name == "sgd":
        return sgd_momentum_flat(p_shard, opt_state, g_shard, lr,
                                 jnp.float32(hyper["momentum"]),
                                 interpret=interpret)
    if name == "adagrad":
        return adagrad_flat(p_shard, opt_state, g_shard, lr,
                            jnp.float32(hyper.get("eps", 1e-10)),
                            interpret=interpret)
    if name == "adamw":
        t = opt_state["t"] + 1
        tf = t.astype(jnp.float32)
        b1 = jnp.float32(hyper.get("b1", 0.9))
        b2 = jnp.float32(hyper.get("b2", 0.95))
        # the (2, n) m/v buffer rides the kernel whole — no per-step
        # slice/re-stack copies of the moment streams
        new_p, new_mv = adamw_flat(
            p_shard, opt_state["mv"], g_shard,
            lr, b1, b2, jnp.float32(hyper.get("eps", 1e-8)),
            jnp.float32(hyper.get("weight_decay", 0.0) or 0.0),
            1.0 - b1 ** tf, 1.0 - b2 ** tf, interpret=interpret)
        return new_p, {"mv": new_mv, "t": t}
    raise ValueError(
        f"flat fused update knows {sorted(FLAT_STATE_STREAMS)}, got {name!r}")


def scatter_update_gather(spec: flatbuf.FlatBuffer, grads: Any, params: Any,
                          opt_state: Any, lr=None, momentum=None, *,
                          hyper: Optional[Mapping] = None,
                          comm=None,
                          axis_name: Optional[str] = None,
                          num_rings: int = 1,
                          bucket_bytes: int | None = None,
                          wire_dtype: Optional[str] = None,
                          weight_decay: float = 0.0,
                          mean: bool = True,
                          interpret: bool | None = None) -> tuple[Any, Any]:
    """One fused sync+update step on this device (the paper-faithful MPI
    worker program; run under shard_map on a mesh or vmap emulation):

      1. pack grads into the persistent flat buffer (static offsets)
      2. ring reduce-scatter over the gradient communicator -> this
         device owns a fully-reduced 1/p shard ((p-1)/p·n gradient-leg
         bytes — half the full allreduce; multi-axis groups nest the
         reduce-scatter level by level at the same total cost)
      3. fused optimizer Pallas kernel on (param shard, K state-stream
         shards, grad shard): one grid, state stays sharded (p× memory
         saving per full-length stream — 2 streams for AdamW)
      4. ring allgather of the UPDATED param shards -> full new params

    The optimizer is selected by ``hyper`` (an ``Optimizer.hyper`` dict:
    sgd / adagrad / adamw — see ``FLAT_STATE_STREAMS``); the positional
    ``lr``/``momentum`` form is the momentum-SGD shorthand. ``opt_state``
    is this device's shard as laid out by ``optstate_shard_init``.

    ``comm`` is the gradient group (``core.comm.Communicator``); its
    policy supplies the ring count, bucketing, AND the wire protocol:
    with ``wire_dtype`` "bf16"/"int8" the reduce-scatter hops carry the
    compressed gradient chunks (hp accumulation per hop) and the
    allgather hops carry the compressed updated-param shards (every
    device roundtrips its own shard through the codec, so replicas stay
    bit-identical). A trivial communicator
    (or one whose axes have size 1) degenerates to the local fused
    update: no collective, one Pallas grid over the whole buffer — still
    a win over O(num_leaves) per-leaf updates. The old ``axis_name=``
    string spelling was removed — build the group with
    ``Communicator.from_axis_name`` and pass ``comm=``.

    Returns ``(new_params_tree, new_opt_state_shard)``.
    """
    from repro.core import comm as _comm
    from repro.kernels.common import use_interpret

    if hyper is None:
        hyper = {"name": "sgd", "lr": lr, "momentum": momentum,
                 "weight_decay": weight_decay}
    elif lr is not None or momentum is not None or weight_decay:
        raise ValueError(
            "pass hyperparameters either positionally (the momentum-SGD "
            "shorthand) or via hyper=, not both — with hyper= the "
            "optimizer reads lr/momentum/weight_decay from the dict, so "
            "move them there")
    name = _flat_name(hyper)

    if axis_name is not None:
        _comm._axis_name_removed("scatter_update_gather")
    if comm is None:
        comm = _comm.LOCAL.with_policy(
            num_rings=num_rings,
            bucket_bytes=bucket_bytes, wire_dtype=wire_dtype)
    elif num_rings != 1 or bucket_bytes is not None or wire_dtype is not None:
        raise ValueError(
            "with comm= the ring/wire policy lives on the communicator — "
            "set num_rings/bucket_bytes/wire_dtype there "
            "(Communicator.with_policy), not as arguments; mixing the two "
            "would desync the gradient sharding (or the wire form) from "
            "the optimizer-state layout")

    p = comm.resolve_size()
    nr = comm.rings_for(spec.nbytes)
    _, total = flatbuf.shard_geometry(spec.size, p, nr)

    gbuf = flatbuf.pack_padded(spec, grads, total)
    pbuf = flatbuf.pack_padded(spec, params, total)

    if p == 1:
        g_shard, p_shard = gbuf, pbuf
    else:
        g_shard = comm.reduce_scatter(gbuf, num_rings=nr)
        p_shard = comm.shard_select(pbuf, num_rings=nr)
    if mean:
        g_shard = g_shard / p
    wd = hyper.get("weight_decay", 0.0) or 0.0
    if name == "sgd" and wd:
        # coupled L2, matching per-leaf optim.sgd; adamw decays decoupled
        # inside its kernel
        g_shard = g_shard + wd * p_shard

    if interpret is None:
        interpret = use_interpret()
    new_p_shard, new_state = _fused_shard_update(
        name, hyper, p_shard, opt_state, g_shard, interpret)

    if p == 1:
        new_pbuf = new_p_shard
    else:
        new_pbuf = comm.allgather(new_p_shard, num_rings=nr)
    return spec.unpack(new_pbuf[:spec.size]), new_state


def optstate_sched_init(hyper, schedule, state_dtypes=None) -> Any:
    """``optstate_shard_init`` for the overlapped (schedule-bucketed)
    layout: the per-device state length is ``schedule.shard_size`` — the
    bucket-major concat of single-ring per-bucket chunks — instead of
    the monolithic ``flatbuf.shard_size`` geometry."""
    name = _flat_name(hyper)
    sd = state_stream_dtype(hyper, state_dtypes)
    n = schedule.shard_size
    k = FLAT_STATE_STREAMS[name]
    if name == "adamw":
        return {"mv": jnp.zeros((k, n), sd),
                "t": jnp.zeros((), jnp.int32)}
    return jnp.zeros((n,), sd)


def overlap_update(schedule, g_shard: jax.Array, staged_params: Any,
                   opt_state: Any, *,
                   hyper: Mapping,
                   comm=None,
                   num_rings: Optional[int] = None,
                   bucket_bytes: int | None = None,
                   wire_dtype: Optional[str] = None,
                   mean: bool = True,
                   interpret: bool | None = None) -> tuple[Any, Any]:
    """The update half of the backward-overlapped step.

    The grad fn already issued each schedule bucket's reduce-scatter leg
    mid-backward (``Communicator.reduce_scatter_bucket``) and handed us
    ``g_shard``: the bucket-major ``(schedule.shard_size,)`` concat of
    this device's fully-reduced per-bucket chunks. This function runs
    what is left after backward finishes:

      1. select this device's matching param shard from the packed
         staged params (``shard_select_sched`` — static, no comm)
      2. ONE fused optimizer Pallas grid over the whole shard (the
         buckets share the kernel launch; only the WIRE was bucketed)
      3. the ONE trailing allgather of the updated shard
         (``allgather_sched``), re-stitched to the packed layout

    ``staged_params`` is the stage-subtree tuple ``Model.overlap_stages``
    produced — the SAME staging the schedule was built from; the return
    is ``(new_staged_params, new_opt_state)`` (caller ``unstage``s).
    ``comm`` carries the whole policy: explicit ``num_rings`` /
    ``bucket_bytes`` / ``wire_dtype`` arguments are rejected here just
    like in ``scatter_update_gather`` — the schedule already fixed the
    bucket geometry and mixing knobs would desync it from the state
    layout.
    """
    from repro.core import comm as _comm
    from repro.kernels.common import use_interpret

    if num_rings is not None or bucket_bytes is not None \
            or wire_dtype is not None:
        raise ValueError(
            "overlap_update: the bucket/ring/wire policy lives on the "
            "communicator and the BucketSchedule — set wire_dtype on the "
            "comm (Communicator.with_policy) and the bucket split via "
            "overlap_buckets, not as arguments; explicit knobs here "
            "would desync the wire legs from the schedule layout")
    comm = _comm.LOCAL if comm is None else comm
    name = _flat_name(hyper)
    p = comm.resolve_size()
    if p != schedule.p:
        raise ValueError(
            f"schedule was built for p={schedule.p} shards but the "
            f"communicator spans {p} — rebuild the BucketSchedule with "
            f"the gradient group's size (bucket_schedule(spec, counts, "
            f"p={p}))")

    pbuf = schedule.spec.pack(staged_params)
    p_shard = comm.shard_select_sched(pbuf, schedule)
    if mean:
        g_shard = g_shard / p
    wd = hyper.get("weight_decay", 0.0) or 0.0
    if name == "sgd" and wd:
        g_shard = g_shard + wd * p_shard

    if interpret is None:
        interpret = use_interpret()
    new_p_shard, new_state = _fused_shard_update(
        name, hyper, p_shard, opt_state, g_shard, interpret)

    new_pbuf = comm.allgather_sched(new_p_shard, schedule)
    return schedule.spec.unpack(new_pbuf), new_state


def _flat_optimizer(hyper: dict, spec: flatbuf.FlatBuffer,
                    num_rings: int, bucket_bytes: int | None) -> Optimizer:
    """Drop-in ``Optimizer`` whose update is the fused flat-buffer kernel
    (local p=1 geometry — the single-process drivers' default update).
    State is the flat f32 stream shard(s) instead of a pytree."""
    from repro.core import comm as _comm

    nr = flatbuf.effective_rings(spec.nbytes, num_rings, bucket_bytes)
    local = _comm.Communicator(
        axes=(), sizes=(), policy=_comm.CollectivePolicy(num_rings=nr))

    def init(params):
        return optstate_shard_init(hyper, spec, 1, nr)

    @jax.jit
    def update(grads, state, params):
        return scatter_update_gather(
            spec, grads, params, state, hyper=hyper, comm=local, mean=False)

    return Optimizer(init, update, hyper)


def flat_sgd(lr: float, momentum: float, spec: flatbuf.FlatBuffer, *,
             weight_decay: float = 0.0, num_rings: int = 1,
             bucket_bytes: int | None = None) -> Optimizer:
    """Fused flat momentum SGD: state is ONE flat momentum buffer."""
    return _flat_optimizer(
        {"name": "flat_sgd", "lr": lr, "momentum": momentum,
         "weight_decay": weight_decay}, spec, num_rings, bucket_bytes)


def flat_adagrad(lr: float, spec: flatbuf.FlatBuffer, *,
                 eps: float = 1e-10, num_rings: int = 1,
                 bucket_bytes: int | None = None,
                 state_dtype=None) -> Optimizer:
    """Fused flat AdaGrad: state is ONE flat accumulator buffer
    (optionally bf16 — half the state bytes, f32 compute per tile)."""
    return _flat_optimizer(
        {"name": "flat_adagrad", "lr": lr, "eps": eps,
         "state_dtype": state_dtype},
        spec, num_rings, bucket_bytes)


def flat_adamw(lr: float, spec: flatbuf.FlatBuffer, *,
               b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
               weight_decay: float = 0.0, num_rings: int = 1,
               bucket_bytes: int | None = None,
               state_dtype=None) -> Optimizer:
    """Fused flat AdamW: state is the (2, n) m/v buffer + scalar step
    count — the two full-size adaptive streams ride one flat object
    (optionally bf16: another 2x off the dominant state cost)."""
    return _flat_optimizer(
        {"name": "flat_adamw", "lr": lr, "b1": b1, "b2": b2, "eps": eps,
         "weight_decay": weight_decay, "state_dtype": state_dtype},
        spec, num_rings, bucket_bytes)
