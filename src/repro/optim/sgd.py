"""Optimizers, pure-pytree (init/update), mirroring what the paper ships to
the PS via ``KVStore.set_optimizer``: SGD (+momentum), AdaGrad, AdamW, and
the Elastic server/client updates (eqs. 2/3) live in core/elastic.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (g, state, p) -> (new_p, state)


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0,
        state_dtype=None) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype or p.dtype), params
        )

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new_p = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_p, ()
        new_v = jax.tree.map(lambda v, g: momentum * v + g, state, grads)
        new_p = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32) - lr * v.astype(jnp.float32)).astype(p.dtype),
            params, new_v,
        )
        return new_p, new_v

    return Optimizer(init, update)


def adagrad(lr: float, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        new_s = jax.tree.map(
            lambda s, g: s + jnp.square(g.astype(jnp.float32)), state, grads
        )
        new_p = jax.tree.map(
            lambda p, g, s: (
                p.astype(jnp.float32)
                - lr * g.astype(jnp.float32) / (jnp.sqrt(s) + eps)
            ).astype(p.dtype),
            params, grads, new_s,
        )
        return new_p, new_s

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def step(p, m_, v_):
            upd = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_p = jax.tree.map(step, params, m, v)
        return new_p, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "adagrad": adagrad, "adamw": adamw}[name](lr, **kw)
