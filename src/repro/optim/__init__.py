from repro.optim.sgd import Optimizer, adagrad, adamw, get_optimizer, sgd
