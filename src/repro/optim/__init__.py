from repro.optim.sgd import (
    FLAT_STATE_STREAMS,
    Optimizer,
    adagrad,
    adamw,
    flat_adagrad,
    flat_adamw,
    flat_sgd,
    get_optimizer,
    momentum_shard_init,
    optstate_shard_init,
    scatter_update_gather,
    sgd,
)
