"""repro: MXNET-MPI (hierarchical PS+MPI data parallelism) on TPU, in JAX.

Public API surface:

    from repro import build_model, get_config, reduced        # models
    from repro.core import KVStore, SyncConfig                # the paper
    from repro.core.algorithms import AlgoConfig, run, MODES  # six SGD modes
    from repro.launch.train import make_train_step, train_loop
    from repro.launch.serve import BatchedServer
    from repro.launch.mesh import make_production_mesh
"""
from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ModelConfig,
    get_config,
    list_configs,
    reduced,
)
from repro.models.model import Model, build_model

__version__ = "0.1.0"
__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "Model",
    "ModelConfig",
    "build_model",
    "get_config",
    "list_configs",
    "reduced",
    "__version__",
]
