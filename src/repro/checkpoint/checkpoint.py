"""Pytree checkpointing to .npz: flat path->array encoding, restores exact
tree structure and dtypes. Atomic write (tmp + rename) so a killed job
never leaves a torn checkpoint — the PS task model assumes restartability
(the paper leans on LSF auto-restart for fault recovery, §8).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",):
            # npz can't round-trip ml_dtypes; store widened (lossless for
            # bf16 -> f32), restore casts back to the target dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return f"k:{entry.key}"
    if hasattr(entry, "idx"):
        return f"i:{entry.idx}"
    return f"n:{entry}"


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: dict | None = None) -> None:
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"step": step, "treedef": str(treedef), **(metadata or {})}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __meta__=json.dumps(meta), **flat)
        # np.savez appends .npz to the filename it's given
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)


def restore_checkpoint(path: str, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        flat_like = _flatten(like)
        restored = {}
        for key, ref in flat_like.items():
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if arr.shape != ref.shape:
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != {ref.shape}"
                )
            restored[key] = arr
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path, leaf in leaves_like:
        key = _SEP.join(_path_str(p) for p in path)
        new_leaves.append(restored[key].astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves
    )
    return tree, meta
