"""Pytree + packed-buffer checkpointing to .npz.

Two families share one atomic-write core (tmp + os.replace, so a killed
job never leaves a torn checkpoint — the PS task model assumes
restartability; the paper leans on LSF auto-restart for fault
recovery, §8):

  save_checkpoint / restore_checkpoint
      pytrees as flat path->array npz, exact structure and dtypes back
      (bf16 widened losslessly to f32 on disk).

  save_packed / restore_packed
      named packed buffers (the FlatBuffer f32 params / optimizer-state
      / per-round sums a KV server snapshots — net/kvserver.py) plus a
      JSON meta dict, no pytree structure required.

``latest_checkpoint`` scans a directory for the newest *complete*
``ckpt_<step>.npz``: leftover ``*.tmp*`` files from a crash mid-write
are never considered, and a torn/corrupt newest file is skipped in
favor of the last one that still loads — the restore path of the
crash-recovery story (launch/supervisor.py).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"

#: server snapshot filename stem: ckpt_<step>.npz
CKPT_PREFIX = "ckpt_"
_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",):
            # npz can't round-trip ml_dtypes; store widened (lossless for
            # bf16 -> f32), restore casts back to the target dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return f"k:{entry.key}"
    if hasattr(entry, "idx"):
        return f"i:{entry.idx}"
    return f"n:{entry}"


def _atomic_savez(path: str, arrays: dict[str, np.ndarray],
                  meta: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __meta__=json.dumps(meta), **arrays)
        # np.savez appends .npz to the filename it's given
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: dict | None = None) -> None:
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"step": step, "treedef": str(treedef), **(metadata or {})}
    _atomic_savez(path, _flatten(tree), meta)


def restore_checkpoint(path: str, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        flat_like = _flatten(like)
        restored = {}
        for key, ref in flat_like.items():
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if arr.shape != ref.shape:
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != {ref.shape}"
                )
            restored[key] = arr
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path, leaf in leaves_like:
        key = _SEP.join(_path_str(p) for p in path)
        new_leaves.append(restored[key].astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves
    )
    return tree, meta


# ---------------------------------------------------------------------------
# Packed-buffer snapshots (KV server durability) + discovery
# ---------------------------------------------------------------------------

def checkpoint_path(dirname: str, step: int) -> str:
    return os.path.join(dirname, f"{CKPT_PREFIX}{step}.npz")


def save_packed(path: str, arrays: dict[str, np.ndarray], *, step: int = 0,
                metadata: dict | None = None) -> None:
    """Atomically write named packed buffers + JSON metadata. Array names
    are free-form strings (the server uses ``kv:<key>``,
    ``state:<unit>:<section>``, ``round:<key>:<step>`` namespaces)."""
    meta = {"step": step, "packed": True, **(metadata or {})}
    _atomic_savez(path, {k: np.asarray(v) for k, v in arrays.items()}, meta)


def restore_packed(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Inverse of ``save_packed``. Raises on a torn/corrupt file (zipfile
    or JSON errors) — ``latest_checkpoint`` turns that into a skip."""
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        arrays = {k: data[k] for k in data.files if k != "__meta__"}
    return arrays, meta


def latest_checkpoint(dirname: str) -> Optional[str]:
    """Newest complete ``ckpt_<step>.npz`` under ``dirname``, or None.

    Crash-mid-write safe: ``*.tmp*`` leftovers never match the name
    pattern, and a file that fails to load (torn zip, bad meta) is
    skipped in favor of the next-newest complete snapshot.
    """
    if not os.path.isdir(dirname):
        return None
    found = []
    for name in os.listdir(dirname):
        m = _CKPT_RE.match(name)
        if m:
            found.append((int(m.group(1)), os.path.join(dirname, name)))
    for _, path in sorted(found, reverse=True):
        try:
            restore_packed(path)
        except Exception:
            continue
        return path
    return None
