"""The six parallel-SGD modes of the paper's evaluation (§7):

  dist-SGD   pure PS, synchronous           (paper fig. 6, #clients=#workers)
  mpi-SGD    MPI clients + PS, synchronous  (fig. 6)
  dist-ASGD  pure PS, asynchronous          (fig. 7, #clients=#workers)
  mpi-ASGD   sync inside client, async push (fig. 7)
  dist-ESGD  elastic averaging per worker   (fig. 8, #clients=#workers)
  mpi-ESGD   local sync-SGD inside client, elastic averaging at PS (fig. 8)

Each mode drives the same KVStore API the paper's pseudo-code uses, with
per-key push/pull, server-side optimizer (``set_optimizer``), and
intra-client tensor allreduce. Wall time is *simulated* with the α-β-γ
cost model (there is no congested network in this container); gradient
math is real JAX on real synthetic data, so convergence curves are
genuine.
"""
from __future__ import annotations

import dataclasses
from dataclasses import InitVar, dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, flatbuf
from repro.core.client import group_workers
from repro.core.comm import (CollectivePolicy, Communicator,
                             filter_mirrors, resolve_policy)
from repro.core.elastic import elastic_client_packed, elastic_client_update
from repro.core.faults import FaultInjector, delivery_time, injector
from repro.core.kvstore import KVStore
from repro.core.membership import Membership
from repro.core.scheduler import AsyncEngine, StalenessTracker, UnitTiming
from repro.optim.sgd import (
    Optimizer,
    adagrad,
    adamw,
    flat_adagrad,
    flat_adamw,
    flat_sgd,
    sgd,
)

MODES = ("dist_sgd", "mpi_sgd", "dist_asgd", "mpi_asgd", "dist_esgd", "mpi_esgd")

#: the flat-field defaults AlgoConfig historically shipped (the simulated
#: worker group always ran 2 rings) — the base point the deprecation shim
#: resolves non-default flat kwargs against
_ALGO_BASE = CollectivePolicy(method="multi_ring", num_rings=2)


@dataclass(frozen=True)
class AlgoConfig:
    mode: str
    num_workers: int = 12
    num_clients: int = 2          # ignored for dist_* (== num_workers)
    num_servers: int = 2
    lr: float = 0.1
    momentum: float = 0.9
    esgd_alpha: float = 0.5
    esgd_interval: int = 64       # the paper's INTERVAL
    epochs: int = 4
    steps_per_epoch: int = 40
    compute_time: float = 0.5     # nominal s/batch (paper: resnet50 on K80s)
    jitter: float = 0.15
    model_bytes: float = 100e6    # resnet-50 ~ 25M params fp32
    seed: int = 0
    net: cost_model.NetParams = field(default_factory=cost_model.testbed)
    # deprecated flat mirror of ``policy.method``
    allreduce_method: str = "multi_ring"
    # removed: was int8 on the PS-push leg only; wire_dtype="int8" is the
    # one compression knob now (hard error below)
    compress_push: bool = False
    # beyond-paper low-precision wire protocol: applied to the intra-client
    # collective hops (via the worker group's Communicator policy) AND the
    # PS push leg (KVStore wire) — None/"f32", "bf16", "int8"
    wire_dtype: Optional[str] = None
    # worker/server update rule: sgd / adagrad / adamw — all three lower
    # onto the fused flat-buffer step below
    optimizer: str = "sgd"
    # fused flat-buffer optimizer step (optim.sgd.flat_sgd /
    # flat_adagrad / flat_adamw): one Pallas grid over the packed
    # gradient instead of per-leaf tree.map updates
    fused_update: bool = True
    # flat elastic leg: eqs. (2)/(3) on the packed FlatBuffer through the
    # fused exchange kernel (both the KVStore server rule and the local
    # client update) instead of per-leaf tree.maps
    flat_exchange: bool = True
    bucket_bytes: Optional[int] = None
    # backward-overlapped bucketed reduce-scatter (launch/train.py's
    # staged grad fn): the intra-client gradient leg's reduce-scatter
    # half hides behind backward compute; the simulated step time pays
    # only the exposed remainder (cost_model.overlapped_step_time)
    overlap: bool = False
    overlap_buckets: int = 4
    # fault injection (core/faults.py): a FaultSchedule or its compact
    # string form ("kill@12:unit=1;straggle@0:unit=3:factor=4"); None
    # runs the clean path BIT-IDENTICALLY to pre-fault configs
    faults: Any = None
    # sync-barrier graceful degradation (KVStore): seconds past a
    # round's first arrival before the barrier releases with the
    # survivor subset; required for kill/drop schedules in sync modes
    barrier_timeout: Optional[float] = None
    # async server rule: damp an s-stale push by 1/(1+s) on the packed
    # FlatBuffer (off by default — the paper's plain ASGD)
    staleness_scaling: bool = False
    # dropped-push retry policy: 1 + push_retries delivery attempts,
    # doubling backoff starting at push_backoff seconds
    push_retries: int = 2
    push_backoff: float = 0.05
    # crash recovery (transport tier, launch/supervisor.py): durable KV
    # checkpoint cadence in releasing steps (0 = no snapshots; also the
    # worker's state-parking cadence), the per-unit supervised-respawn
    # budget with its first backoff, and a SEPARATE fault schedule the
    # server tier evaluates (kill@step:unit=R self-kills server R after
    # it releases that step — after the snapshot, before any reply).
    # The in-process simulation ignores all four (restart@ events are
    # likewise launcher-only; see core/faults.py)
    checkpoint_every: int = 0
    restarts: int = 0
    restart_backoff: float = 0.05
    server_faults: Any = None
    # internal bookkeeping: the policy the mirror knobs were backfilled
    # from (dataclasses.replace passes it back so __post_init__ can tell
    # an explicitly changed mirror from one restating the previous
    # policy). Never pass it yourself.
    policy_src: Optional[CollectivePolicy] = field(
        default=None, repr=False, compare=False)
    # -- the ONE policy field (canonical; the flat knobs mirror it) --------
    policy: InitVar[Optional[CollectivePolicy]] = None

    def __post_init__(self, policy: Optional[CollectivePolicy] = None):
        if self.compress_push:
            raise ValueError(
                "AlgoConfig(compress_push=True) was removed — it is the "
                "int8 wire: pass wire_dtype='int8' instead (one "
                "compression knob, shared between the PS push leg and "
                "the collective hops)")
        defaults = {"method": "multi_ring", "bucket_bytes": None,
                    "wire_dtype": None, "overlap": False,
                    "overlap_buckets": 4}
        flat = {
            "method": self.allreduce_method,
            "bucket_bytes": self.bucket_bytes, "wire_dtype": self.wire_dtype,
            "overlap": self.overlap, "overlap_buckets": self.overlap_buckets,
        }
        # only knobs the caller moved off the field defaults (or, on a
        # replace() round-trip, off the previous policy) count as "passed"
        flat = filter_mirrors(flat, defaults=defaults,
                              prior=self.policy_src)
        if policy is None and flat.get("overlap"):
            # overlap runs a single ring schedule (policy.validate)
            flat["num_rings"] = 1
        pol = resolve_policy(policy, flat, base=_ALGO_BASE,
                             where="AlgoConfig")
        pol.validate(where="AlgoConfig")
        object.__setattr__(self, "policy", pol)
        object.__setattr__(self, "policy_src", pol)
        object.__setattr__(self, "allreduce_method", pol.method)
        object.__setattr__(self, "bucket_bytes", pol.bucket_bytes)
        object.__setattr__(self, "wire_dtype", pol.wire_dtype)
        object.__setattr__(self, "overlap", pol.overlap)
        object.__setattr__(self, "overlap_buckets", pol.overlap_buckets)

    @property
    def collective_wire_dtype(self) -> Optional[str]:
        """Wire dtype of the intra-client collective hops (None =
        full-precision) — ``policy.wire``."""
        return self.policy.wire

    @property
    def effective_wire_dtype(self) -> Optional[str]:
        """Wire dtype of the PS push leg (KVStore wire) — the same one
        knob as the collective hops since ``compress_push`` was removed."""
        return self.policy.wire

    @property
    def effective_clients(self) -> int:
        return self.num_workers if self.mode.startswith("dist") else self.num_clients

    @property
    def workers_per_client(self) -> int:
        return self.num_workers // self.effective_clients


@dataclass
class History:
    times: list[float] = field(default_factory=list)
    epochs: list[int] = field(default_factory=list)
    metrics: list[float] = field(default_factory=list)
    losses: list[float] = field(default_factory=list)
    mean_staleness: float = 0.0
    epoch_time: float = 0.0
    # robustness accounting (0/full on clean runs)
    degraded_syncs: int = 0
    late_pushes: int = 0
    live_clients: int = 0
    membership_epochs: int = 0


GradFn = Callable[[Any, dict], tuple[jax.Array, Any]]
EvalFn = Callable[[Any], float]


def _worker_group(cfg: AlgoConfig) -> Communicator:
    """The intra-client MPI communicator (one group per client — every
    client has the same geometry, so one object serves them all):
    ``workers_per_client`` ranks over an emulated 'worker' axis, with
    the config's collective policy. This is the paper's
    MPI-communicator-in-KVStore group; the runners register it on the
    store and all intra-client sync dispatches through it."""
    return Communicator.world(
        ("worker",), (cfg.workers_per_client,), policy=cfg.policy)


def _member_grads(grad_fn: GradFn, params,
                  batches: list[dict]) -> tuple[float, Any]:
    """Per-worker grads of one client, stacked on a leading member dim
    (the group collective's layout)."""
    losses, grads = [], []
    for b in batches:
        l, g = grad_fn(params, b)
        losses.append(float(l))
        grads.append(g)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grads)
    return float(np.mean(losses)), stacked


def _client_grad(grad_fn: GradFn, params, batches: list[dict],
                 group: Communicator) -> tuple[float, Any]:
    """Intra-client step: per-worker grads, group-allreduced (mean)
    through the client's communicator.

    Numerically exercises the real ring/multi-ring collective via vmap
    emulation when the client has >1 worker.
    """
    loss, stacked = _member_grads(grad_fn, params, batches)
    if len(batches) == 1:
        return loss, jax.tree.map(lambda l: l[0], stacked)
    synced = group.emulate_reduce(stacked)
    mean = jax.tree.map(lambda s: s[0] / len(batches), synced)
    return loss, mean


def _make_opt(cfg: AlgoConfig, params) -> Optimizer:
    """The worker/server update rule: the fused flat-buffer optimizer
    (one Pallas grid over the packed gradient, spec built once) when
    enabled, else the per-leaf reference."""
    if cfg.optimizer == "adagrad":
        if cfg.fused_update:
            return flat_adagrad(cfg.lr, flatbuf.spec_for(params),
                                bucket_bytes=cfg.bucket_bytes)
        return adagrad(cfg.lr)
    if cfg.optimizer == "adamw":
        if cfg.fused_update:
            return flat_adamw(cfg.lr, flatbuf.spec_for(params),
                              bucket_bytes=cfg.bucket_bytes)
        return adamw(cfg.lr)
    if cfg.optimizer != "sgd":
        raise ValueError(f"optimizer must be sgd/adagrad/adamw, "
                         f"got {cfg.optimizer!r}")
    if cfg.fused_update and cfg.momentum > 0.0:
        # momentum == 0 would still pay a full-model momentum buffer for
        # v' = 0*v + g; plain sgd carries no state there
        return flat_sgd(cfg.lr, cfg.momentum, flatbuf.spec_for(params),
                        bucket_bytes=cfg.bucket_bytes)
    return sgd(cfg.lr, cfg.momentum)


def _comm_times(cfg: AlgoConfig) -> dict[str, float]:
    per_client = cfg.workers_per_client
    intra = cost_model.allreduce_time(
        cfg.model_bytes, per_client, cfg.net, cfg.allreduce_method,
        wire_dtype=cfg.collective_wire_dtype,
    )
    if cfg.overlap:
        # exposed comm time only: the hidden reduce-scatter fraction
        # already rides behind cfg.compute_time in the step accounting
        bb = [cfg.model_bytes / cfg.overlap_buckets] * cfg.overlap_buckets
        intra = cost_model.overlapped_step_time(
            cfg.compute_time, bb, per_client, cfg.net,
            wire_dtype=cfg.collective_wire_dtype) - cfg.compute_time
    ps = cost_model.ps_pushpull_time(
        cfg.model_bytes, cfg.effective_clients, cfg.num_servers, cfg.net,
        wire_dtype=cfg.effective_wire_dtype,
    )
    return {"intra": intra, "ps": ps}


def _injector(cfg: AlgoConfig) -> Optional[FaultInjector]:
    """The config's fault injector (None when the schedule is empty —
    the clean path runs bit-identically to pre-fault configs)."""
    return injector(cfg.faults, seed=cfg.seed)


def _client_membership(cfg: AlgoConfig, C: int) -> Membership:
    """The PS tier's membership: clients over an emulated 'client' axis,
    so every epoch change re-splits a real Communicator (the group a
    deployment would MPI_Comm_split over the survivors)."""
    return Membership(
        C, Communicator.world(
            ("client",), (C,),
            policy=CollectivePolicy(method=cfg.policy.method)))


def run(cfg: AlgoConfig, init_fn: Callable[[jax.Array], Any], grad_fn: GradFn,
        eval_fn: EvalFn, make_pipeline: Callable[[int], Any]) -> History:
    if cfg.mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    if cfg.num_workers % cfg.effective_clients:
        raise ValueError("workers must divide into clients evenly")
    runner = {
        "dist_sgd": _run_sync, "mpi_sgd": _run_sync,
        "dist_asgd": _run_async, "mpi_asgd": _run_async,
        "dist_esgd": _run_esgd, "mpi_esgd": _run_esgd,
    }[cfg.mode]
    return runner(cfg, init_fn, grad_fn, eval_fn, make_pipeline)


# ---------------------------------------------------------------------------
# synchronous (fig. 6): Push(grads); Pull(grads); SGD.Update locally
# ---------------------------------------------------------------------------

def _run_sync(cfg, init_fn, grad_fn, eval_fn, make_pipeline) -> History:
    inj = _injector(cfg)
    if inj is not None:
        return _run_sync_faulted(cfg, init_fn, grad_fn, eval_fn,
                                 make_pipeline, inj)
    return _run_sync_clean(cfg, init_fn, grad_fn, eval_fn, make_pipeline)


def _run_sync_clean(cfg, init_fn, grad_fn, eval_fn, make_pipeline) -> History:
    C = cfg.effective_clients
    idents = group_workers(cfg.num_workers, C)
    pipelines = [make_pipeline(w) for w in range(cfg.num_workers)]
    params = init_fn(jax.random.key(cfg.seed))
    # fig. 6: Push(grads); Pull(grads) returns the global SUM (server rule
    # "assign" after the sync barrier); SGD.Update runs on the worker with
    # rescale = 1/mini_batch_size (here: 1/num_workers of worker-mean grads)
    kv = KVStore.create("sync_mpi" if cfg.mode == "mpi_sgd" else "dist_sync",
                        num_workers=cfg.num_workers, num_servers=cfg.num_servers,
                        num_clients=C)
    kv.init("grads", jax.tree.map(jnp.zeros_like, params))
    group = _worker_group(cfg)
    for c in range(C):
        kv.register_group(c, group)
    opt = _make_opt(cfg, params)
    opt_state = opt.init(params)

    comm = _comm_times(cfg)
    rng = np.random.default_rng(cfg.seed)
    now = 0.0
    hist = History()
    step_times = []
    for epoch in range(cfg.epochs):
        for step in range(cfg.steps_per_epoch):
            losses = []
            for c in range(C):
                members = [w for w in range(cfg.num_workers)
                           if idents[w].mpi.client == c]
                batches = [pipelines[w].batch_at(epoch, step) for w in members]
                loss, stacked = _member_grads(grad_fn, params, batches)
                # the paper's worker program: the group collective runs
                # INSIDE kv.push (register_group'd communicator), the
                # client-sum crosses to the PS tier as one pusher
                kv.push("grads", stacked, group=c)
                losses.append(loss)
            total = kv.pull("grads")[0]
            mean_g = jax.tree.map(lambda x: x / cfg.num_workers, total)
            params, opt_state = opt.update(mean_g, opt_state, params)
            # simulated wall time: slowest worker's compute + comms
            compute = max(
                cfg.compute_time * rng.lognormal(0, cfg.jitter)
                for _ in range(cfg.num_workers)
            )
            dt = compute + comm["intra"] + comm["ps"]
            now += dt
            step_times.append(dt)
            hist.losses.append(float(np.mean(losses)))
        hist.times.append(now)
        hist.epochs.append(epoch)
        hist.metrics.append(eval_fn(params))
    hist.epoch_time = float(np.mean(step_times)) * cfg.steps_per_epoch
    hist.live_clients = C
    return hist


def _run_sync_faulted(cfg, init_fn, grad_fn, eval_fn, make_pipeline,
                      inj: FaultInjector) -> History:
    """The synchronous modes under a fault schedule: the paper's
    robustness story exercised end to end. Dead clients miss the PS
    barrier; the FIRST missed round degrades via barrier_timeout
    (survivor release + rescale), after which the Membership evicts them
    (epoch bump + Communicator re-split) and later barriers are full
    barriers of the survivor group. Straggle/delay stretch a client's
    arrival; drops ride the retry/backoff policy; pushes past the
    deadline are discarded as late by the store."""
    C = cfg.effective_clients
    if (cfg.barrier_timeout is None
            and inj.schedule.kinds & {"kill", "drop"}):
        raise ValueError(
            f"mode {cfg.mode!r} has a sync PS barrier: a kill/drop fault "
            "schedule would deadlock it — set "
            "AlgoConfig.barrier_timeout so the barrier can release with "
            "the survivor group")
    idents = group_workers(cfg.num_workers, C)
    pipelines = [make_pipeline(w) for w in range(cfg.num_workers)]
    params = init_fn(jax.random.key(cfg.seed))
    kv = KVStore.create("sync_mpi" if cfg.mode == "mpi_sgd" else "dist_sync",
                        num_workers=cfg.num_workers, num_servers=cfg.num_servers,
                        num_clients=C, barrier_timeout=cfg.barrier_timeout)
    kv.init("grads", jax.tree.map(jnp.zeros_like, params))
    group = _worker_group(cfg)
    for c in range(C):
        kv.register_group(c, group)
    live = _client_membership(cfg, C)
    kv.attach_membership(live)
    opt = _make_opt(cfg, params)
    opt_state = opt.init(params)

    comm = _comm_times(cfg)
    wpc = cfg.workers_per_client
    rng = np.random.default_rng(cfg.seed)
    now = 0.0
    hist = History()
    step_times = []
    for epoch in range(cfg.epochs):
        for step in range(cfg.steps_per_epoch):
            gstep = epoch * cfg.steps_per_epoch + step
            newly_dead = [c for c in live.live if inj.is_killed(c, gstep)]
            losses, arrivals, pushes = [], {}, {}
            for c in live.live:
                if c in newly_dead:
                    continue  # died before this round's compute
                members = [w for w in range(cfg.num_workers)
                           if idents[w].mpi.client == c]
                batches = [pipelines[w].batch_at(epoch, step)
                           for w in members]
                loss, stacked = _member_grads(grad_fn, params, batches)
                draws = [rng.lognormal(0, cfg.jitter) for _ in members]
                compute = cfg.compute_time * max(draws)
                leg = (compute * inj.straggle_factor(c, gstep)
                       + inj.delay(c, gstep))
                arrivals[c] = now + leg + comm["intra"]
                pushes[c] = inj.corrupt(stacked, c, gstep)
                losses.append(loss)
            deliver = {}
            for c in sorted(arrivals):
                at = delivery_time(inj, c, gstep, arrivals[c],
                                   retries=cfg.push_retries,
                                   backoff=cfg.push_backoff)
                if at is not None:
                    deliver[c] = at
            if deliver:
                first = min(deliver.values())
                deadline = (float("inf") if cfg.barrier_timeout is None
                            else first + cfg.barrier_timeout)
                in_time = [c for c in deliver if deliver[c] <= deadline]
                for c in sorted(deliver, key=lambda c: (deliver[c], c)):
                    # the store discards deliveries past the deadline
                    # (late_pushes); in-time ones fill the barrier
                    kv.push("grads", pushes[c], group=c, at=deliver[c],
                            unit=c)
                release = (max(deliver[c] for c in in_time)
                           if len(in_time) == kv.expected_pushers
                           else deadline)
                total = kv.pull("grads", now=release)[0]
                k = kv.last_barrier_count or len(in_time)
                mean_g = jax.tree.map(lambda x: x / (k * wpc), total)
                params, opt_state = opt.update(mean_g, opt_state, params)
            else:
                # every live push lost this round: no update, the round
                # still burns the timeout waiting
                release = now + (cfg.barrier_timeout or cfg.compute_time)
            dt = release + comm["ps"] - now
            now = release + comm["ps"]
            step_times.append(dt)
            if losses:
                hist.losses.append(float(np.mean(losses)))
            for c in newly_dead:
                # the missed barrier IS the failure detector: evict after
                # the degraded round, shrinking later barriers
                live.fail(c)
        hist.times.append(now)
        hist.epochs.append(epoch)
        hist.metrics.append(eval_fn(params))
    hist.epoch_time = float(np.mean(step_times)) * cfg.steps_per_epoch
    hist.degraded_syncs = kv.degraded_syncs
    hist.late_pushes = kv.late_pushes
    hist.live_clients = live.live_count
    hist.membership_epochs = live.epoch
    return hist


# ---------------------------------------------------------------------------
# asynchronous (fig. 7): Push(grads); Pull(params) — server runs optimizer
# ---------------------------------------------------------------------------

def _run_async(cfg, init_fn, grad_fn, eval_fn, make_pipeline) -> History:
    C = cfg.effective_clients
    inj = _injector(cfg)
    live = _client_membership(cfg, C) if inj is not None else None
    idents = group_workers(cfg.num_workers, C)
    pipelines = [make_pipeline(w) for w in range(cfg.num_workers)]
    params0 = init_fn(jax.random.key(cfg.seed))
    kv = KVStore.create("async_mpi" if cfg.mode == "mpi_asgd" else "dist_async",
                        num_workers=cfg.num_workers, num_servers=cfg.num_servers,
                        num_clients=C)
    kv.init("params", params0)
    kv.set_optimizer(_make_opt(cfg, params0), rescale=1.0)
    group = _worker_group(cfg)
    for c in range(C):
        kv.register_group(c, group)
    if live is not None:
        kv.attach_membership(live)

    comm = _comm_times(cfg)
    rng = np.random.default_rng(cfg.seed)
    timing = [
        UnitTiming(cfg.compute_time, cfg.jitter,
                   np.random.default_rng((cfg.seed, u)))
        for u in range(C)
    ]
    # contention: concurrent pushers share the server link — async pushes
    # overlap, so charge the expected concurrency factor
    iter_time = cfg.compute_time + comm["intra"]
    solo_push = cost_model.ps_pushpull_time(
        cfg.model_bytes, 1, cfg.num_servers, cfg.net)
    concurrency = max(1.0, C * solo_push / max(iter_time + solo_push, 1e-9))
    push_time = solo_push * concurrency

    engine = AsyncEngine(C, timing)
    tracker = StalenessTracker()
    # the tracker rides the store: push(unit=)/pull(unit=) record
    # apply/pull versions server-side, and (opt-in) the optimize rule
    # damps an s-stale push by 1/(1+s) on the packed FlatBuffer
    kv.attach_staleness(tracker, scale=cfg.staleness_scaling)
    client_params = [params0] * C
    client_iter = [0] * C
    hist = History()
    # an epoch = one pass over every worker's shard: each unit completion
    # consumes workers_per_client batches, so steps_per_epoch * C
    # completions cover steps_per_epoch * num_workers batches — the same
    # data budget as one synchronous epoch.
    per_epoch = cfg.steps_per_epoch * C
    total = cfg.epochs * per_epoch
    state = {"completions": 0, "losses": []}

    def on_complete(unit: int, now: float) -> Optional[float]:
        it = client_iter[unit]
        if inj is not None and inj.is_killed(unit, it):
            # unit dies at dispatch: membership evicts it and the engine
            # never re-queues it; survivors drain the completion budget
            live.fail(unit)
            return None
        epoch = min(it // cfg.steps_per_epoch, cfg.epochs - 1)
        step = it % cfg.steps_per_epoch
        members = [w for w in range(cfg.num_workers)
                   if idents[w].mpi.client == unit]
        batches = [pipelines[w].batch_at(epoch, step) for w in members]
        loss, g = _client_grad(grad_fn, client_params[unit], batches,
                               group)
        state["losses"].append(loss)
        extra = 0.0
        if inj is not None:
            g = inj.corrupt(g, unit, it)
            at = delivery_time(inj, unit, it, now,
                               retries=cfg.push_retries,
                               backoff=cfg.push_backoff)
            if at is not None:
                extra += (at - now) + inj.delay(unit, it)
                kv.push("params", g, unit=unit)
            else:
                kv.late_pushes += 1  # lost for good: server never sees it
            extra += ((inj.straggle_factor(unit, it) - 1.0)
                      * cfg.compute_time)
        else:
            kv.push("params", g, unit=unit)
        client_params[unit] = kv.pull("params", unit=unit)[0]
        client_iter[unit] += 1
        state["completions"] += 1
        if state["completions"] % per_epoch == 0:
            ep = state["completions"] // per_epoch - 1
            hist.times.append(now)
            hist.epochs.append(ep)
            hist.metrics.append(eval_fn(kv.value("params")))
            hist.losses.append(float(np.mean(
                state["losses"][-per_epoch:])))
        return comm["intra"] + push_time + extra

    for u in range(C):
        tracker.on_pull(u)
    engine.start()
    engine.run(total, on_complete)
    hist.mean_staleness = tracker.mean_staleness()
    hist.epoch_time = engine.now / cfg.epochs
    hist.late_pushes = kv.late_pushes
    hist.live_clients = live.live_count if live is not None else C
    hist.membership_epochs = live.epoch if live is not None else 0
    return hist


# ---------------------------------------------------------------------------
# elastic (fig. 8): local SGD; every INTERVAL: Push(params) -> Elastic1 on
# server; Pull(centers); Elastic2 locally
# ---------------------------------------------------------------------------

def _run_esgd(cfg, init_fn, grad_fn, eval_fn, make_pipeline) -> History:
    C = cfg.effective_clients
    inj = _injector(cfg)
    live = _client_membership(cfg, C) if inj is not None else None
    idents = group_workers(cfg.num_workers, C)
    pipelines = [make_pipeline(w) for w in range(cfg.num_workers)]
    params0 = init_fn(jax.random.key(cfg.seed))
    kv = KVStore.create("async_mpi" if cfg.mode == "mpi_esgd" else "dist_async",
                        num_workers=cfg.num_workers, num_servers=cfg.num_servers,
                        num_clients=C, wire_dtype=cfg.effective_wire_dtype,
                        flat_exchange=cfg.flat_exchange)
    kv.init("centers", params0)
    kv.set_elastic(cfg.esgd_alpha)
    group = _worker_group(cfg)
    for c in range(C):
        kv.register_group(c, group)

    comm = _comm_times(cfg)
    timing = [
        UnitTiming(cfg.compute_time, cfg.jitter,
                   np.random.default_rng((cfg.seed, u)))
        for u in range(C)
    ]
    opt = _make_opt(cfg, params0)
    client_params = [params0] * C
    client_opt = [opt.init(params0) for _ in range(C)]
    client_iter = [0] * C

    engine = AsyncEngine(C, timing)
    hist = History()
    total = cfg.epochs * cfg.steps_per_epoch * C
    state = {"completions": 0, "losses": []}
    per_epoch = cfg.steps_per_epoch * C

    def on_complete(unit: int, now: float) -> Optional[float]:
        it = client_iter[unit]
        if inj is not None and inj.is_killed(unit, it):
            # the dead client's local replica is simply abandoned — the
            # center keeps the mass it already absorbed (eq. 2), which
            # is ESGD's whole tolerance story
            live.fail(unit)
            return None
        epoch = min(it // cfg.steps_per_epoch, cfg.epochs - 1)
        step = it % cfg.steps_per_epoch
        members = [w for w in range(cfg.num_workers)
                   if idents[w].mpi.client == unit]
        batches = [pipelines[w].batch_at(epoch, step) for w in members]
        loss, g = _client_grad(grad_fn, client_params[unit], batches,
                               group)
        state["losses"].append(loss)
        comm_cost = comm["intra"]
        if it % cfg.esgd_interval == 0:
            pushed = client_params[unit]
            deliver = True
            if inj is not None:
                pushed = inj.corrupt(pushed, unit, it)
                at = delivery_time(inj, unit, it, now,
                                   retries=cfg.push_retries,
                                   backoff=cfg.push_backoff)
                if at is None:
                    # exchange lost: neither Elastic1 nor Elastic2 runs
                    # this round — the replica just drifts one interval
                    # longer (the elastic penalty pulls it back later)
                    deliver = False
                    kv.late_pushes += 1
                else:
                    comm_cost += (at - now) + inj.delay(unit, it)
            if deliver:
                old_center = kv.value("centers")
                kv.push("centers", pushed)               # Elastic1 on server
                if cfg.flat_exchange:
                    # Elastic2 on the packed FlatBuffer: one fused launch
                    client_params[unit] = elastic_client_packed(
                        client_params[unit], old_center, cfg.esgd_alpha
                    )
                else:
                    client_params[unit] = elastic_client_update(  # per-leaf
                        client_params[unit], old_center, cfg.esgd_alpha
                    )
                comm_cost += cost_model.ps_pushpull_time(
                    cfg.model_bytes, 1, cfg.num_servers, cfg.net,
                    wire_dtype=cfg.effective_wire_dtype)
        new_p, new_s = opt.update(g, client_opt[unit], client_params[unit])
        client_params[unit] = new_p
        client_opt[unit] = new_s
        client_iter[unit] += 1
        state["completions"] += 1
        if state["completions"] % per_epoch == 0:
            ep = state["completions"] // per_epoch - 1
            hist.times.append(now)
            hist.epochs.append(ep)
            hist.metrics.append(eval_fn(kv.value("centers")))
            hist.losses.append(float(np.mean(state["losses"][-per_epoch:])))
        if inj is not None:
            comm_cost += ((inj.straggle_factor(unit, it) - 1.0)
                          * cfg.compute_time)
        return comm_cost

    engine.start()
    engine.run(total, on_complete)
    hist.epoch_time = engine.now / cfg.epochs
    hist.late_pushes = kv.late_pushes
    hist.live_clients = live.live_count if live is not None else C
    hist.membership_epochs = live.epoch if live is not None else 0
    return hist
