"""KVStore-MPI (paper §3.2/§4.2): the distributed <key, value> store with
``create / init / set_optimizer / push / pull / pushpull``.

This is the *semantic* layer the paper adds to MXNET, reproduced over JAX
arrays. The store simulates the PS tier in-process (values sharded over
``num_servers`` for cost accounting); workers address it through the same
API the paper's workers use:

- ``push(key, tensor)``: ``tensor`` is the paper's group-of-vectors — a
  list with one array per local device; it is locally reduced first
  (tensor reduce — the Pallas ``tensor_group_reduce`` kernel's job), then
  the store applies the server rule:
    * sync types buffer pushes until all expected pushers arrive (barrier)
    * async types apply each push immediately (staleness!)
- ``pull(key)`` returns the current server value (copied into every entry
  of the destination tensor list by the caller).
- ``pushpull`` fuses both (the new MXNET API the paper added, §4.2.4).

MPI types ("sync_mpi"/"async_mpi") only change WHO pushes: the client
master, after an intra-client tensor allreduce — see core/algorithms.py.
That intra-client collective is a first-class *group* here (the paper's
MPI-communicators-in-KVStore model): ``register_group`` attaches a
``core.comm.Communicator`` per client group, and ``push``/``pushpull``
accept ``group=`` to run the group collective (vmap emulation of the
real ring programs) before the PS tier — pushpull WITHIN a group, the
elastic/optimizer server rule ACROSS groups.

Pushed pytrees are treated as ONE fused object end-to-end: the sync
barrier accumulates them as packed ``FlatBuffer``s (core/flatbuf.py —
spec memoized per structure, so there is no per-push re-flatten) and
unpacks once when the barrier releases, instead of a per-leaf tree_add
per pusher. The elastic server rule (``set_elastic``) rides the same
substrate: eq. (2) runs as one packed buffer through the fused Pallas
exchange kernel (``flat_exchange=True``, the default), and compressed
pushes quantize that single packed buffer instead of per-leaf codes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import flatbuf
from repro.optim.sgd import Optimizer

VALID_TYPES = ("local", "dist_sync", "dist_async", "sync_mpi", "async_mpi")


def _tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


@jax.jit
def _packed_sum(pushes: tuple) -> Any:
    spec = flatbuf.spec_for(pushes[0])
    buf = spec.pack(pushes[0])
    for other in pushes[1:]:
        buf = buf + spec.pack(other)
    return spec.unpack(buf)


def local_reduce(tensor: list[Any]) -> Any:
    """Reduce the group-of-vectors on a worker (one value per device).

    Values may be arrays or whole pytrees (a fused "tensor"). Uses the
    Pallas grouped-reduction kernel when available (the IBMGpu analogue),
    falling back to jnp.
    """
    if len(tensor) == 1:
        return tensor[0]

    def reduce_leaf(*xs):
        stacked = jnp.stack(xs)
        try:
            from repro.kernels.tensor_reduce.ops import group_reduce

            return group_reduce(stacked)
        except Exception:
            return jnp.sum(stacked, axis=0)

    return jax.tree.map(reduce_leaf, *tensor)


@dataclass
class _ServerRule:
    """What the server does with an aggregated push (set via set_optimizer)."""

    kind: str = "assign"  # assign | optimize | elastic
    optimizer: Optional[Optimizer] = None
    rescale: float = 1.0
    alpha: float = 0.0  # elastic


class KVStore:
    """In-process PS tier + the worker-facing API."""

    def __init__(self, kv_type: str, *, num_workers: int = 1,
                 num_servers: int = 1, num_clients: Optional[int] = None,
                 compress_push: bool = False,
                 wire_dtype: Optional[str] = None,
                 flat_exchange: bool = True,
                 barrier_timeout: Optional[float] = None):
        from repro.core.collectives import check_wire_dtype

        if kv_type not in VALID_TYPES:
            raise ValueError(f"kv_type must be one of {VALID_TYPES}")
        if compress_push:
            raise ValueError(
                "KVStore(compress_push=True) was removed — it is the "
                "int8 wire: pass wire_dtype='int8' instead (one "
                "compression knob, shared with the collective legs)")
        self.kv_type = kv_type
        self.num_workers = num_workers
        self.num_servers = max(num_servers, 1)
        self.num_clients = num_clients or num_workers
        # beyond-paper low-precision PS wire: "int8" block-quantizes the
        # push (kernels/quant_bucket wire codec), "bf16" casts it
        self.wire_dtype = check_wire_dtype(wire_dtype, where="KVStore")
        # elastic server rule as ONE packed buffer + ONE fused Pallas
        # kernel (core.elastic.elastic_exchange_packed) instead of
        # per-leaf tree.maps; False = per-leaf reference
        self.flat_exchange = flat_exchange
        self.pushed_bytes = 0
        self.pushed_bytes_uncompressed = 0
        self.is_mpi = kv_type.endswith("_mpi")
        self.is_sync = kv_type in ("dist_sync", "sync_mpi")
        # number of pushers the sync barrier waits for at FULL strength;
        # the expected_pushers property degrades it to the live-member
        # count when a Membership is attached
        self._static_expected = (self.num_clients if self.is_mpi
                                 else num_workers)
        # failure tolerance (paper §2-3): after ``barrier_timeout``
        # simulated seconds past a round's first arrival, the sync
        # barrier releases with the survivor subset instead of blocking
        # forever on a dead pusher (pull(now=...) drives the clock)
        self.barrier_timeout = barrier_timeout
        self._membership = None
        self._staleness = None
        self._stale_scale = False
        self.degraded_syncs = 0          # barriers released short
        self.late_pushes = 0             # pushes landing after release
        self.last_barrier_count: Optional[int] = None
        self._first_arrival: dict[Any, float] = {}
        self._values: dict[Any, jax.Array] = {}
        self._opt_state: dict[Any, Any] = {}
        self._pending: dict[Any, list[jax.Array]] = {}
        self._rule = _ServerRule()
        self.push_count: dict[Any, int] = {}
        # MPI groups embedded in the store (paper §3-4): group id ->
        # the intra-group communicator; + per-group collective counters
        self._groups: dict[Any, Any] = {}
        self.group_sync_count: dict[Any, int] = {}

    @property
    def expected_pushers(self) -> int:
        """Pushers the sync barrier waits for: the static client/worker
        count, degraded to the live-member count when an elastic
        Membership (core/membership.py) is attached — an ANNOUNCED
        leave/failure shrinks the barrier immediately; unannounced
        deaths degrade via barrier_timeout instead."""
        base = self._static_expected
        if self._membership is not None:
            return max(1, min(base, self._membership.live_count))
        return base

    def attach_membership(self, membership) -> None:
        """Attach the tier's Membership: the barrier tracks its live
        count from now on (and shrinks/grows across epochs)."""
        self._membership = membership

    def attach_staleness(self, tracker, *, scale: bool = False) -> None:
        """Wire a scheduler.StalenessTracker into the server rule:
        ``push(..., unit=)`` records the apply (and its staleness),
        ``pull(..., unit=)`` records the pull. With ``scale=True`` the
        async optimize rule damps a push that is s versions stale by
        1/(1+s) — applied on the packed FlatBuffer
        (core.elastic.scale_packed), the same substrate the wire codec
        rides."""
        self._staleness = tracker
        self._stale_scale = scale

    def _require_key(self, key: Any, what: str) -> None:
        """Actionable unknown-key error: name the key AND the known
        ones, instead of a bare KeyError from the values dict."""
        if key not in self._values:
            known = ", ".join(repr(k) for k in self._values) or "(none)"
            raise KeyError(
                f"{what} of unregistered key {key!r} — known keys: "
                f"{known}; register it first with kv.init({key!r}, value)")

    # -- setup --------------------------------------------------------------
    @classmethod
    def create(cls, kv_type: str, **kw) -> "KVStore":
        return cls(kv_type, **kw)

    def init(self, key: Any, value: jax.Array) -> None:
        """Rank 0 initializes keys on the servers (paper §4.2.1)."""
        if key in self._values:
            raise KeyError(f"key {key!r} already initialized")
        self._values[key] = value
        self.push_count[key] = 0
        if self._rule.kind == "optimize":
            self._opt_state[key] = self._rule.optimizer.init(value)

    def set_optimizer(self, optimizer: Optimizer, *, rescale: float = 1.0) -> None:
        """Ship the update rule to the server (remote config, §3.2)."""
        self._rule = _ServerRule("optimize", optimizer, rescale)
        for key, value in self._values.items():
            self._opt_state[key] = optimizer.init(value)

    def set_elastic(self, alpha: float) -> None:
        """Server-side Elastic1 (eq. 2): values become center variables."""
        self._rule = _ServerRule("elastic", alpha=alpha)

    def register_group(self, gid: Any, group) -> None:
        """Attach an MPI group (a ``core.comm.Communicator``) to the
        store — the paper's communicator-in-KVStore embedding. Pushes
        tagged ``group=gid`` run the group's collective first; the PS
        rule then spans groups."""
        from repro.core.comm import Communicator

        if not isinstance(group, Communicator):
            raise TypeError(
                f"register_group wants a core.comm.Communicator, got "
                f"{type(group).__name__} — build one with "
                "Communicator.world(...).split(...)")
        if group.static_size is None:
            raise ValueError(
                "register_group needs a communicator with static sizes "
                "(the in-process emulation splits the stacked member dim "
                "by them) — build it with Communicator.world(axes, sizes)")
        self._groups[gid] = group
        self.group_sync_count.setdefault(gid, 0)

    def group(self, gid: Any):
        return self._groups[gid]

    def group_reduce(self, gid: Any, stacked: Any, *,
                     mean: bool = False) -> Any:
        """The intra-group collective: ``stacked`` carries a leading
        member dim (= group size); the registered communicator's tensor
        allreduce runs over it (vmap emulation of the same ring
        programs shard_map executes) and the group master's copy is
        returned — sum by default, the client-sum a master pushes.

        Multi-axis groups (e.g. a pod×data hierarchy registered whole)
        have the flat member dim reshaped to the group's axis sizes
        before the nested per-axis emulation — the sizes must be static
        for that, which ``register_group`` guarantees."""
        group = self._groups[gid]
        leaves = jax.tree_util.tree_leaves(stacked)
        members = leaves[0].shape[0] if leaves else 1
        want = group.static_size
        if want is not None and members != want:
            raise ValueError(
                f"group {gid!r} push carries {members} stacked members "
                f"but the registered communicator spans {want} ranks "
                f"(axes {group.axes}, sizes {group.sizes}) — stack one "
                "entry per group member")
        self.group_sync_count[gid] = self.group_sync_count.get(gid, 0) + 1
        if members == 1:
            return jax.tree.map(lambda l: l[0], stacked)
        if len(group.axes) > 1:
            shape = tuple(group.sizes)
            split = jax.tree.map(
                lambda l: l.reshape(shape + l.shape[1:]), stacked)
            synced = group.emulate_reduce(split, mean=mean)
            return jax.tree.map(
                lambda l: l.reshape((members,) + l.shape[len(shape):])[0],
                synced)
        synced = group.emulate_reduce(stacked, mean=mean)
        return jax.tree.map(lambda l: l[0], synced)

    # -- data plane ----------------------------------------------------------
    def push(self, key: Any, tensor: list[jax.Array] | jax.Array, *,
             group: Any = None, at: Optional[float] = None,
             unit: Optional[int] = None) -> None:
        """Worker push. ``group=gid`` marks ``tensor`` as the group's
        stacked member values (leading dim = group size): the registered
        communicator's collective reduces them first (the MPI leg) and
        the group counts as ONE pusher toward the PS barrier — the
        paper's client-master push.

        ``at`` is the push's simulated arrival time: with a
        ``barrier_timeout`` configured, a push landing more than the
        timeout after its round's FIRST arrival is late — the barrier
        already released without it — and is discarded (counted in
        ``late_pushes``). ``unit`` names the pusher for the attached
        StalenessTracker."""
        self._require_key(key, "push")
        if (self.is_sync and at is not None
                and self.barrier_timeout is not None
                and key in self._first_arrival
                and at - self._first_arrival[key] > self.barrier_timeout):
            self.late_pushes += 1
            return
        if group is not None:
            if group not in self._groups:
                raise KeyError(
                    f"push(group={group!r}) before register_group — attach "
                    "the client's Communicator first")
            tensor = self.group_reduce(group, tensor)
        agg = local_reduce(tensor) if isinstance(tensor, list) else tensor
        self.push_count[key] += 1
        raw = sum(l.size * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(agg))
        self.pushed_bytes_uncompressed += raw
        if self.wire_dtype == "bf16":
            # pure-cast wire: half the bytes, no scales, works per leaf
            agg = jax.tree.map(
                lambda l: l.astype(jnp.bfloat16).astype(l.dtype), agg)
            self.pushed_bytes += sum(
                l.size * 2 for l in jax.tree_util.tree_leaves(agg))
        elif self.wire_dtype == "int8":
            if self._flat_elastic_ok(agg):
                # the wire form is ONE packed int8 buffer + per-bucket
                # scales, quantized per push (so the sync barrier sums
                # exactly what crossed the wire, like the per-leaf path)
                from repro.core.elastic import wire_packed
                from repro.kernels.quant_bucket.quant_bucket import wire_nbytes

                self.pushed_bytes += wire_nbytes(flatbuf.spec_for(agg).payload)
                agg = wire_packed(agg)  # what the server receives
            else:
                from repro.kernels.quant_bucket.ops import (
                    compress, compressed_bytes, decompress)

                codes, scales = compress(agg)
                self.pushed_bytes += compressed_bytes(agg)
                agg = decompress(codes, scales, agg)  # what the server sees
        else:
            self.pushed_bytes += raw
        if self.is_sync:
            pend = self._pending.setdefault(key, [])
            if not pend and at is not None:
                self._first_arrival[key] = at
            pend.append(agg)
            if len(pend) >= self.expected_pushers:
                total = self._barrier_sum(pend)
                count = len(pend)
                del self._pending[key]
                self._first_arrival.pop(key, None)
                self.last_barrier_count = count
                self._apply(key, total, count=count, unit=unit)
        else:
            self._apply(key, agg, unit=unit)

    @staticmethod
    def _barrier_sum(pend: list) -> Any:
        """Sum the barrier's pushes as ONE fused flat buffer (single add
        per pusher instead of per-leaf tree_adds), unpacking once at
        release. Runs under jit (cached per tree structure / pusher
        count) so the static-slice packs fuse instead of copying the
        whole buffer eagerly per leaf. Falls back to tree_add for
        non-float leaves, which the f32 buffer would not carry exactly."""
        leaves = jax.tree_util.tree_leaves(pend[0])
        if len(leaves) > 1 and all(
            jnp.issubdtype(l.dtype, jnp.floating) for l in leaves
        ):
            return _packed_sum(tuple(pend))
        total = pend[0]
        for other in pend[1:]:
            total = _tree_add(total, other)
        return total

    def pull(self, key: Any, num_dst: int = 1, *,
             unit: Optional[int] = None,
             now: Optional[float] = None) -> list[jax.Array]:
        """Returns the server value broadcast to ``num_dst`` tensor slots.

        Graceful degradation (paper §2-3): with ``barrier_timeout``
        configured and ``now`` past ``first_arrival + timeout``, an
        incomplete sync barrier RELEASES with the pushes that made it —
        the survivor subset — instead of raising; ``degraded_syncs``
        counts the short releases and ``last_barrier_count`` records how
        many pushes each release summed, so callers can rescale their
        mean by the live contribution. ``unit`` records the pull on the
        attached StalenessTracker."""
        self._require_key(key, "pull")
        if key in self._pending:
            pend = self._pending[key]
            opened = self._first_arrival.get(key)
            timed_out = (
                self.barrier_timeout is not None and now is not None
                and opened is not None
                and now - opened >= self.barrier_timeout)
            if not timed_out:
                raise RuntimeError(
                    f"pull of key {key!r} while sync barrier incomplete "
                    f"({len(pend)}/{self.expected_pushers} pushes)"
                )
            total = self._barrier_sum(pend)
            count = len(pend)
            del self._pending[key]
            self._first_arrival.pop(key, None)
            self.degraded_syncs += 1
            self.last_barrier_count = count
            self._apply(key, total, count=count)
        v = self._values[key]
        if self._staleness is not None and unit is not None:
            self._staleness.on_pull(unit)
        return [v for _ in range(num_dst)]

    def pushpull(self, key: Any, tensor: list[jax.Array] | jax.Array,
                 num_dst: int = 1, *, group: Any = None) -> list[jax.Array]:
        """Fused push+pull (§4.2.4). With 0 servers this is pure tensor
        allreduce; here it is push followed by an immediate pull.
        ``group=gid`` runs the registered group's collective first (the
        MPI leg inside the client) — for sync types the pull still
        honors the cross-group barrier, so the LAST group's pushpull
        releases it."""
        self.push(key, tensor, group=group)
        return self.pull(key, num_dst)

    # -- server rules ---------------------------------------------------------
    def _apply(self, key: Any, pushed: Any, *, count: Optional[int] = None,
               unit: Optional[int] = None) -> None:
        rule = self._rule
        stale = None
        if self._staleness is not None and unit is not None:
            stale = self._staleness.on_apply(unit)
        if rule.kind == "assign":
            self._values[key] = pushed
        elif rule.kind == "optimize":
            rescale = rule.rescale
            if count is not None and count != self._static_expected:
                # degraded/elastic barrier: the sum covers ``count``
                # pushers where the rule's rescale assumed the full
                # roster — rescale by the live fraction so the effective
                # step magnitude survives membership changes
                rescale = rescale * (self._static_expected / count)
            grad = jax.tree.map(lambda g: g * rescale, pushed)
            if self._stale_scale and stale:
                # staleness-scaled async rule on the flat substrate:
                # damp an s-stale push by 1/(1+s) as ONE packed multiply
                factor = 1.0 / (1.0 + stale)
                if all(jnp.issubdtype(l.dtype, jnp.floating)
                       for l in jax.tree_util.tree_leaves(grad)):
                    from repro.core.elastic import scale_packed

                    grad = scale_packed(grad, factor)
                else:
                    grad = jax.tree.map(lambda g: g * factor, grad)
            new_v, new_s = rule.optimizer.update(
                grad, self._opt_state[key], self._values[key]
            )
            self._values[key] = new_v
            self._opt_state[key] = new_s
        elif rule.kind == "elastic":
            if self._flat_elastic_ok(pushed):
                # Elastic1 on the packed FlatBuffer: one fused Pallas
                # launch for the whole tree, only the center written
                # (compressed pushes were already quantized, per push,
                # in the packed domain by push())
                from repro.core.elastic import elastic_server_packed

                self._values[key] = elastic_server_packed(
                    pushed, self._values[key], rule.alpha
                )
            else:
                from repro.core.elastic import elastic_server_update

                self._values[key] = elastic_server_update(
                    self._values[key], pushed, rule.alpha
                )

    def _flat_elastic_ok(self, tree: Any) -> bool:
        """Whether the packed fused exchange can serve this push: elastic
        rule, flat path enabled, and every leaf a float the f32 buffer
        carries."""
        if not (self.flat_exchange and self._rule.kind == "elastic"):
            return False
        return all(jnp.issubdtype(l.dtype, jnp.floating)
                   for l in jax.tree_util.tree_leaves(tree))

    # -- introspection ---------------------------------------------------------
    def value(self, key: Any) -> jax.Array:
        self._require_key(key, "value")
        return self._values[key]

    def keys(self) -> list:
        return list(self._values)

    def server_of(self, key: Any) -> int:
        """Key placement across the server shards (hash partitioning).

        crc32 of the key string, NOT ``hash()``: Python salts str hashes
        per process, and the socket transport needs every worker process
        to route a key to the same shard (net/remote_kv.py mirrors this
        exact rule)."""
        import zlib

        return zlib.crc32(str(key).encode()) % self.num_servers

    def bytes_per_server_per_sync(self, key: Any) -> int:
        """Ingress bytes one server receives per global sync of this key —
        the contention quantity of Fig. 12."""
        nbytes = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(self._values[key])
        )
        return nbytes * self.expected_pushers // self.num_servers
