"""Deterministic fault injection for the PS/MPI stack (paper §2, §3).

The paper's case for embedding MPI groups in a PS task model is that the
loosely-coupled PS tier survives what kills an MPI job wholesale: clients
may fail, straggle, or drop a push between sync barriers. This module is
the harness that *produces* those failures on demand — in the six-mode
simulation (core/algorithms.py), the shard driver
(launch/shard_driver.py), and tests — with one hard rule:

    every lookup is a pure function of (schedule, unit, step).

No wall clock, no shared RNG stream: the same ``FaultSchedule`` replayed
against the same run is bit-identical (the acceptance bar for the chaos
CI job), and corruption noise is seeded per (seed, unit, step) so it
cannot shift when unrelated events reorder.

Fault kinds (``FaultEvent.kind``):

  drop      the unit's push at ``step`` is lost; ``duration`` counts how
            many consecutive delivery *attempts* fail (retry/backoff in
            the KVStore path can still get it through when
            duration <= retries)
  delay     the unit's push/collective leg at ``step`` arrives ``factor``
            seconds late
  straggle  the unit's compute+comm at steps [step, step+duration) is
            stretched ``factor``×
  corrupt   gaussian noise (scale ``sigma``) is added to the unit's
            pushed value at ``step``
  kill      the unit is dead from ``step`` on (membership failure — see
            core/membership.py for the re-split/re-shard that follows)

Schedules parse from a compact string form so they thread through CLI
flags and job specs unchanged:

    "kill@12:unit=1;straggle@0:unit=3:factor=4:duration=20"
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

KINDS = ("drop", "delay", "corrupt", "straggle", "kill")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``factor`` is the straggle multiplier (×) or
    the delay (seconds); ``duration`` is in steps (straggle/kill-free
    kinds ignore it) or delivery attempts (drop); ``sigma`` is the
    corrupt noise scale."""

    kind: str
    unit: int
    step: int
    factor: float = 2.0
    duration: int = 1
    sigma: float = 0.01

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"fault kind must be one of {KINDS}, got {self.kind!r}")
        if self.step < 0 or self.unit < 0:
            raise ValueError(
                f"fault step/unit must be >= 0, got step={self.step} "
                f"unit={self.unit}")
        if self.duration < 1:
            raise ValueError(f"fault duration must be >= 1, "
                             f"got {self.duration}")

    def format(self) -> str:
        out = f"{self.kind}@{self.step}:unit={self.unit}"
        if self.factor != 2.0:
            out += f":factor={self.factor:g}"
        if self.duration != 1:
            out += f":duration={self.duration}"
        if self.kind == "corrupt" and self.sigma != 0.01:
            out += f":sigma={self.sigma:g}"
        return out


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, hashable set of fault events + the corruption seed.

    ``parse``/``format`` round-trip the compact string form
    (semicolon-joined events, ``kind@step:unit=U[:factor=F]
    [:duration=D][:sigma=S]``) so the same schedule travels through
    AlgoConfig, TrainSettings, JobSpec and CI unchanged.
    """

    events: tuple[FaultEvent, ...] = ()
    seed: int = 0

    @classmethod
    def parse(cls, text: Optional[str], seed: int = 0) -> "FaultSchedule":
        if not text:
            return cls((), seed)
        events = []
        for part in text.split(";"):
            part = part.strip()
            if not part:
                continue
            head, _, rest = part.partition(":")
            kind, at, step = head.partition("@")
            if not at or not step:
                raise ValueError(
                    f"fault event {part!r} lacks '@step' — the form is "
                    "kind@step:unit=U[:factor=F][:duration=D][:sigma=S]")
            kw: dict[str, Any] = {"kind": kind, "step": int(step)}
            for item in filter(None, rest.split(":")):
                k, eq, v = item.partition("=")
                if not eq:
                    raise ValueError(
                        f"fault field {item!r} in {part!r} is not key=value")
                if k in ("unit", "step", "duration"):
                    kw[k] = int(v)
                elif k in ("factor", "sigma"):
                    kw[k] = float(v)
                else:
                    raise ValueError(
                        f"unknown fault field {k!r} in {part!r}; fields are "
                        "unit/factor/duration/sigma")
            if "unit" not in kw:
                raise ValueError(f"fault event {part!r} lacks unit=")
            events.append(FaultEvent(**kw))
        return cls(tuple(events), seed)

    def format(self) -> str:
        return ";".join(e.format() for e in self.events)

    @property
    def kinds(self) -> frozenset:
        return frozenset(e.kind for e in self.events)


def as_schedule(faults, seed: int = 0) -> Optional[FaultSchedule]:
    """Normalize a CLI string / FaultSchedule / None to a schedule (None
    when there is nothing to inject)."""
    if faults is None:
        return None
    if isinstance(faults, FaultSchedule):
        return faults if faults.events else None
    sched = FaultSchedule.parse(faults, seed)
    return sched if sched.events else None


class FaultInjector:
    """Pure lookups over a ``FaultSchedule``. Stateless: every method is
    a function of (schedule, unit, step) only, so replay is exact."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule

    def _events(self, kind: str, unit: int) -> list[FaultEvent]:
        return [e for e in self.schedule.events
                if e.kind == kind and e.unit == unit]

    def killed_at(self, unit: int) -> Optional[int]:
        steps = [e.step for e in self._events("kill", unit)]
        return min(steps) if steps else None

    def is_killed(self, unit: int, step: int) -> bool:
        at = self.killed_at(unit)
        return at is not None and step >= at

    def should_drop(self, unit: int, step: int, attempt: int = 0) -> bool:
        """Whether delivery ``attempt`` (0-based) of the unit's push at
        ``step`` is lost. ``duration`` consecutive attempts fail, so a
        retrying pusher gets through on attempt ``duration`` — or never,
        if it gives up first."""
        return any(e.step == step and attempt < e.duration
                   for e in self._events("drop", unit))

    def straggle_factor(self, unit: int, step: int) -> float:
        """Compound slowdown (>= 1.0) active at ``step``."""
        f = 1.0
        for e in self._events("straggle", unit):
            if e.step <= step < e.step + e.duration:
                f *= max(e.factor, 1.0)
        return f

    def delay(self, unit: int, step: int) -> float:
        """Extra seconds added to the unit's leg at ``step``."""
        return sum(e.factor for e in self._events("delay", unit)
                   if e.step == step)

    def corrupt(self, tree: Any, unit: int, step: int) -> Any:
        """The unit's pushed value at ``step`` with scheduled corruption
        applied: gaussian noise of the event's ``sigma``, seeded by
        (schedule.seed, unit, step) — the SAME noise on every replay."""
        events = [e for e in self._events("corrupt", unit) if e.step == step]
        if not events:
            return tree
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng((self.schedule.seed, unit, step))
        sigma = sum(e.sigma for e in events)

        def noisy(leaf):
            if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
                return leaf
            noise = rng.standard_normal(leaf.shape, dtype=np.float32) * sigma
            return (leaf + jnp.asarray(noise, leaf.dtype)).astype(leaf.dtype)

        return jax.tree.map(noisy, tree)

    def active(self, unit: int, step: int) -> bool:
        """Whether ANY event touches this (unit, step) — cheap guard for
        hot loops."""
        for e in self.schedule.events:
            if e.unit != unit:
                continue
            if e.kind in ("straggle",):
                if e.step <= step < e.step + e.duration:
                    return True
            elif e.kind == "kill":
                if step >= e.step:
                    return True
            elif e.step == step:
                return True
        return False


def injector(faults, seed: int = 0) -> Optional[FaultInjector]:
    """``as_schedule`` + wrap: None when there is nothing to inject."""
    sched = as_schedule(faults, seed)
    return FaultInjector(sched) if sched is not None else None


def delivery_time(inj: Optional[FaultInjector], unit: int, step: int,
                  at: float, *, retries: int = 2,
                  backoff: float = 0.05) -> Optional[float]:
    """When the unit's push at ``step`` actually lands, given the
    retry/backoff policy: attempt k fires ``backoff * 2**(k-1)`` after
    attempt k-1 (doubling backoff). Returns None when every attempt
    (1 initial + ``retries``) is dropped — the push is lost for good."""
    if inj is None:
        return at
    for attempt in range(retries + 1):
        if not inj.should_drop(unit, step, attempt):
            return at
        at += backoff * (2 ** attempt)
    return None
