"""Deterministic fault injection for the PS/MPI stack (paper §2, §3).

The paper's case for embedding MPI groups in a PS task model is that the
loosely-coupled PS tier survives what kills an MPI job wholesale: clients
may fail, straggle, or drop a push between sync barriers. This module is
the harness that *produces* those failures on demand — in the six-mode
simulation (core/algorithms.py), the shard driver
(launch/shard_driver.py), and tests — with one hard rule:

    every lookup is a pure function of (schedule, unit, step).

No wall clock, no shared RNG stream: the same ``FaultSchedule`` replayed
against the same run is bit-identical (the acceptance bar for the chaos
CI job), and corruption noise is seeded per (seed, unit, step) so it
cannot shift when unrelated events reorder.

Fault kinds (``FaultEvent.kind``):

  drop      the unit's push at ``step`` is lost; ``duration`` counts how
            many consecutive delivery *attempts* fail (retry/backoff in
            the KVStore path can still get it through when
            duration <= retries)
  delay     the unit's push/collective leg at ``step`` arrives ``factor``
            seconds late
  straggle  the unit's compute+comm at steps [step, step+duration) is
            stretched ``factor``×
  corrupt   gaussian noise (scale ``sigma``) is added to the unit's
            pushed value at ``step``
  kill      the unit is dead from ``step`` on (membership failure — see
            core/membership.py for the re-split/re-shard that follows)
  restart   the unit is *authorized to come back*: the supervisor
            (launch/supervisor.py) respawns the dead process after
            ``delay`` seconds without charging the restart budget, and
            the shard driver (launch/shard_driver.py) re-joins the unit
            at ``step`` (growing the layout if it was never live).
            ``delay`` rides the ``factor`` field (default 0.0).

Kills are **generation-indexed**: a respawned process is spawn
generation a (its REPRO_ATTEMPT), and ``is_killed(unit, step, attempt=a)``
consults the (a+1)-th scheduled kill for that unit — so generation 0
dies at the first kill event, its respawn survives it (and dies at the
second, if scheduled), and ``kill@3:unit=1;kill@5:unit=1`` under a
restart budget of 1 deterministically exhausts the budget. ``attempt=0``
is the default and preserves the PR 6 single-kill semantics.

The in-process six-mode simulation (core/algorithms.py) cannot respawn
a unit — it ignores ``restart`` events (the unit stays dead); only the
supervised tcp tier (launch/run_local.py) and the shard driver honor
them.

Schedules parse from a compact string form so they thread through CLI
flags and job specs unchanged:

    "kill@12:unit=1;straggle@0:unit=3:factor=4:duration=20"
    "kill@2:unit=1;restart@2:unit=1:delay=0.1"
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

KINDS = ("drop", "delay", "corrupt", "straggle", "kill", "restart")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``factor`` is the straggle multiplier (×),
    the delay (seconds), or the restart delay (seconds — spelled
    ``delay=`` in the string form, default 0.0); ``duration`` is in
    steps (straggle/kill-free kinds ignore it) or delivery attempts
    (drop); ``sigma`` is the corrupt noise scale."""

    kind: str
    unit: int
    step: int
    factor: float = 2.0
    duration: int = 1
    sigma: float = 0.01

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"fault kind must be one of {KINDS}, got {self.kind!r}")
        if self.step < 0 or self.unit < 0:
            raise ValueError(
                f"fault step/unit must be >= 0, got step={self.step} "
                f"unit={self.unit}")
        if self.duration < 1:
            raise ValueError(f"fault duration must be >= 1, "
                             f"got {self.duration}")

    def format(self) -> str:
        out = f"{self.kind}@{self.step}:unit={self.unit}"
        if self.kind == "restart":
            if self.factor != 0.0:
                out += f":delay={self.factor:g}"
            return out
        if self.factor != 2.0:
            out += f":factor={self.factor:g}"
        if self.duration != 1:
            out += f":duration={self.duration}"
        if self.kind == "corrupt" and self.sigma != 0.01:
            out += f":sigma={self.sigma:g}"
        return out


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, hashable set of fault events + the corruption seed.

    ``parse``/``format`` round-trip the compact string form
    (semicolon-joined events, ``kind@step:unit=U[:factor=F]
    [:duration=D][:sigma=S]``) so the same schedule travels through
    AlgoConfig, TrainSettings, JobSpec and CI unchanged.
    """

    events: tuple[FaultEvent, ...] = ()
    seed: int = 0

    @classmethod
    def parse(cls, text: Optional[str], seed: int = 0) -> "FaultSchedule":
        if not text:
            return cls((), seed)
        events = []
        for part in text.split(";"):
            part = part.strip()
            if not part:
                continue
            head, _, rest = part.partition(":")
            kind, at, step = head.partition("@")
            if not at or not step:
                raise ValueError(
                    f"fault event {part!r} lacks '@step' — the form is "
                    "kind@step:unit=U[:factor=F][:duration=D][:sigma=S]")
            kw: dict[str, Any] = {"kind": kind, "step": int(step)}
            if kind == "restart":
                kw["factor"] = 0.0      # restart delay defaults to 0 s
            for item in filter(None, rest.split(":")):
                k, eq, v = item.partition("=")
                if not eq:
                    raise ValueError(
                        f"fault field {item!r} in {part!r} is not key=value")
                if k in ("unit", "step", "duration"):
                    kw[k] = int(v)
                elif k in ("factor", "sigma"):
                    kw[k] = float(v)
                elif k == "delay" and kind == "restart":
                    kw["factor"] = float(v)
                else:
                    raise ValueError(
                        f"unknown fault field {k!r} in {part!r}; fields are "
                        "unit/factor/duration/sigma (delay, for restart)")
            if "unit" not in kw:
                raise ValueError(f"fault event {part!r} lacks unit=")
            events.append(FaultEvent(**kw))
        return cls(tuple(events), seed)

    def format(self) -> str:
        return ";".join(e.format() for e in self.events)

    @property
    def kinds(self) -> frozenset:
        return frozenset(e.kind for e in self.events)


def as_schedule(faults, seed: int = 0) -> Optional[FaultSchedule]:
    """Normalize a CLI string / FaultSchedule / None to a schedule (None
    when there is nothing to inject)."""
    if faults is None:
        return None
    if isinstance(faults, FaultSchedule):
        return faults if faults.events else None
    sched = FaultSchedule.parse(faults, seed)
    return sched if sched.events else None


class FaultInjector:
    """Pure lookups over a ``FaultSchedule``. Stateless: every method is
    a function of (schedule, unit, step) only, so replay is exact."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule

    def _events(self, kind: str, unit: int) -> list[FaultEvent]:
        return [e for e in self.schedule.events
                if e.kind == kind and e.unit == unit]

    def killed_at(self, unit: int, attempt: int = 0) -> Optional[int]:
        """The step spawn generation ``attempt`` of ``unit`` dies at:
        the (attempt+1)-th scheduled kill, in step order. None when the
        schedule runs out of kills — that generation survives."""
        steps = sorted(e.step for e in self._events("kill", unit))
        return steps[attempt] if attempt < len(steps) else None

    def is_killed(self, unit: int, step: int, attempt: int = 0) -> bool:
        at = self.killed_at(unit, attempt)
        return at is not None and step >= at

    def restart_delay(self, unit: int, attempt: int = 0) -> Optional[float]:
        """Scheduled-respawn authorization for the death of spawn
        generation ``attempt``: the (attempt+1)-th restart event's delay
        (seconds), or None when none is scheduled (the supervisor then
        falls back to its budget, or gives up)."""
        events = sorted(self._events("restart", unit), key=lambda e: e.step)
        return events[attempt].factor if attempt < len(events) else None

    def restart_units(self, step: int) -> tuple[int, ...]:
        """Units with a restart event at exactly ``step`` — the shard
        driver's join directives (a restart for a non-live unit joins it
        mid-run)."""
        return tuple(sorted({e.unit for e in self.schedule.events
                             if e.kind == "restart" and e.step == step}))

    def should_drop(self, unit: int, step: int, attempt: int = 0) -> bool:
        """Whether delivery ``attempt`` (0-based) of the unit's push at
        ``step`` is lost. ``duration`` consecutive attempts fail, so a
        retrying pusher gets through on attempt ``duration`` — or never,
        if it gives up first."""
        return any(e.step == step and attempt < e.duration
                   for e in self._events("drop", unit))

    def straggle_factor(self, unit: int, step: int) -> float:
        """Compound slowdown (>= 1.0) active at ``step``."""
        f = 1.0
        for e in self._events("straggle", unit):
            if e.step <= step < e.step + e.duration:
                f *= max(e.factor, 1.0)
        return f

    def delay(self, unit: int, step: int) -> float:
        """Extra seconds added to the unit's leg at ``step``."""
        return sum(e.factor for e in self._events("delay", unit)
                   if e.step == step)

    def corrupt(self, tree: Any, unit: int, step: int) -> Any:
        """The unit's pushed value at ``step`` with scheduled corruption
        applied: gaussian noise of the event's ``sigma``, seeded by
        (schedule.seed, unit, step) — the SAME noise on every replay."""
        events = [e for e in self._events("corrupt", unit) if e.step == step]
        if not events:
            return tree
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng((self.schedule.seed, unit, step))
        sigma = sum(e.sigma for e in events)

        def noisy(leaf):
            if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
                return leaf
            noise = rng.standard_normal(leaf.shape, dtype=np.float32) * sigma
            return (leaf + jnp.asarray(noise, leaf.dtype)).astype(leaf.dtype)

        return jax.tree.map(noisy, tree)

    def active(self, unit: int, step: int) -> bool:
        """Whether ANY event touches this (unit, step) — cheap guard for
        hot loops."""
        for e in self.schedule.events:
            if e.unit != unit:
                continue
            if e.kind in ("straggle",):
                if e.step <= step < e.step + e.duration:
                    return True
            elif e.kind == "kill":
                if step >= e.step:
                    return True
            elif e.kind == "restart":
                continue    # supervisor/driver directive, not a data fault
            elif e.step == step:
                return True
        return False


def injector(faults, seed: int = 0) -> Optional[FaultInjector]:
    """``as_schedule`` + wrap: None when there is nothing to inject."""
    sched = as_schedule(faults, seed)
    return FaultInjector(sched) if sched is not None else None


def delivery_time(inj: Optional[FaultInjector], unit: int, step: int,
                  at: float, *, retries: int = 2,
                  backoff: float = 0.05) -> Optional[float]:
    """When the unit's push at ``step`` actually lands, given the
    retry/backoff policy: attempt k fires ``backoff * 2**(k-1)`` after
    attempt k-1 (doubling backoff). Returns None when every attempt
    (1 initial + ``retries``) is dropped — the push is lost for good."""
    if inj is None:
        return at
    for attempt in range(retries + 1):
        if not inj.should_drop(unit, step, attempt):
            return at
        at += backoff * (2 ** attempt)
    return None
