from repro.core.comm import Communicator
from repro.core.kvstore import KVStore
from repro.core.collectives import tensor_allreduce, tensor_pushpull
from repro.core.elastic import elastic_exchange, elastic_exchange_multiclient
from repro.core.hierarchy import SyncConfig, clientize, declientize
