"""Event-driven PS simulator: deterministic asynchrony with an explicit
staleness model.

A single jitted SPMD step cannot express cross-job asynchrony, so the
convergence behaviour of the async modes (dist-ASGD, mpi-ASGD, dist-ESGD)
is reproduced here: each *unit* (a worker, or an MPI client acting as one
unit) has its own clock; completions are processed in simulated-time
order; a unit always computes its gradient against the params it pulled
at dispatch time — the staleness the paper's §2.3 discusses falls out of
the event order rather than being injected artificially.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    unit: int = field(compare=False)


@dataclass
class UnitTiming:
    """Per-unit compute-time distribution (lognormal jitter around base)."""

    base: float
    jitter: float
    rng: np.random.Generator

    def sample(self) -> float:
        if self.jitter <= 0:
            return self.base
        return float(self.base * self.rng.lognormal(0.0, self.jitter))


class AsyncEngine:
    """Runs units' (dispatch -> complete -> update) cycles in time order.

    ``on_complete(unit, now) -> float`` performs the unit's server
    interaction and returns the communication time to charge before the
    unit's next dispatch.
    """

    def __init__(self, num_units: int, timing: list[UnitTiming]):
        self.num_units = num_units
        self.timing = timing
        self.now = 0.0
        self._heap: list[_Event] = []
        self._seq = 0
        self.completions = 0
        # membership failures: killed units' in-flight events are
        # discarded and they are never re-dispatched — the survivors
        # keep draining the completion budget (elastic semantics)
        self.dead: set[int] = set()

    def start(self) -> None:
        for u in range(self.num_units):
            self._push(u, self.timing[u].sample())

    def _push(self, unit: int, dt: float) -> None:
        self._seq += 1
        heapq.heappush(self._heap, _Event(self.now + dt, self._seq, unit))

    def kill(self, unit: int) -> None:
        """Mark a unit dead (fault injection / membership failure)."""
        self.dead.add(unit)

    def run(self, until_completions: int,
            on_complete: Callable[[int, float], float]) -> None:
        """``on_complete(unit, now)`` may return None to signal the unit
        died AT this dispatch (core/faults.py kill events): the event
        neither counts as a completion nor re-queues the unit."""
        while self.completions < until_completions and self._heap:
            ev = heapq.heappop(self._heap)
            if ev.unit in self.dead:
                continue
            self.now = ev.time
            comm = on_complete(ev.unit, self.now)
            if comm is None:
                self.dead.add(ev.unit)
                continue
            self.completions += 1
            if ev.unit not in self.dead:
                self._push(ev.unit, comm + self.timing[ev.unit].sample())


@dataclass
class StalenessTracker:
    """Server-version bookkeeping: staleness of a push = server_version at
    apply time − server_version the pusher pulled."""

    server_version: int = 0
    pulled_version: dict[int, int] = field(default_factory=dict)
    history: list[int] = field(default_factory=list)

    def on_pull(self, unit: int) -> None:
        self.pulled_version[unit] = self.server_version

    def on_apply(self, unit: int) -> int:
        stale = self.server_version - self.pulled_version.get(unit, 0)
        self.history.append(stale)
        self.server_version += 1
        return stale

    def mean_staleness(self) -> float:
        return float(np.mean(self.history)) if self.history else 0.0
