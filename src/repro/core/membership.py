"""Elastic membership over the Communicator/KVStore stack (paper §2-3).

MPI jobs die wholesale when one rank disappears; the paper's PS-embedded
groups instead let the membership *change between barriers*. This module
is that layer for the reproduction:

  ``Membership``        an epoch object tracking the live members of a
                        tier (clients, or devices under the shard
                        driver). ``fail``/``leave``/``join`` advance the
                        epoch and re-split the attached ``Communicator``
                        (``Communicator.resized`` — the MPI_Comm_split
                        a real deployment would run on the survivor
                        group), appending a ``MemberEpoch`` record.

  ``reshard_optstate``  the state half of a re-split: FlatBuffer
                        optimizer state sharded 1/p_old re-laid-out to
                        1/p_new, with every SURVIVOR's shard carried
                        over exactly and the dead members' slices
                        zero-filled (their state is lost — the honest
                        failure model; AdaGrad/AdamW restart those
                        stretches of accumulator/moments from zero).
                        Layout follows collectives.py's ring-major
                        (num_rings, p, chunk) geometry, so the result is
                        bit-identical to re-sharding the reconstructed
                        full buffer with ``optstate_shard_init``'s
                        layout at p_new.

Byte accounting mirrors core/cost_model.py's per-leg contract: realizing
the new layout is an allgather among the s survivors of their old shards
(each receives s-1 shards), so ``moved_bytes`` (per survivor) equals
``cost_model.reshard_leg_bytes(state_nbytes, p_old, survivors=s)``
exactly — benchmarks/bench_faults.py gates on the match.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import flatbuf
from repro.core.comm import Communicator
from repro.optim.sgd import FLAT_STATE_STREAMS, _flat_name, state_stream_dtype


@dataclass(frozen=True)
class MemberEpoch:
    """One membership generation: who was live, and what changed."""

    epoch: int
    live: tuple[int, ...]
    kind: str                  # "init" | "fail" | "leave" | "join"
    member: Optional[int] = None


class Membership:
    """Live-member tracking for one tier, with the Communicator re-split
    on every change.

    ``members`` is the initial roster (an int n means members 0..n-1).
    ``comm`` is the tier's group communicator (static sizes); each
    membership change rebuilds ``self.comm`` over the survivor count via
    ``Communicator.resized`` (``axis`` names which axis the members live
    on when the group spans several).
    """

    def __init__(self, members, comm: Optional[Communicator] = None,
                 *, axis: Optional[str] = None):
        roster = (range(members) if isinstance(members, int) else members)
        self._live = set(int(m) for m in roster)
        if not self._live:
            raise ValueError("membership needs at least one member")
        self.world_comm = comm
        self.axis = axis
        self.comm = comm
        self.history: list[MemberEpoch] = [
            MemberEpoch(0, self.live, "init")]
        self._check_comm()

    def _check_comm(self) -> None:
        if self.world_comm is None:
            return
        if self.world_comm.static_size is None:
            raise ValueError(
                "Membership needs a communicator with static sizes "
                "(Communicator.world(axes, sizes)) — there is nothing "
                "to re-split on the trace-time adapter path")

    # -- state ---------------------------------------------------------------
    @property
    def live(self) -> tuple[int, ...]:
        return tuple(sorted(self._live))

    @property
    def live_count(self) -> int:
        return len(self._live)

    @property
    def epoch(self) -> int:
        return self.history[-1].epoch

    def is_live(self, member: int) -> bool:
        return member in self._live

    def rank_of(self, member: int) -> int:
        """The member's dense rank in the survivor group (the color the
        re-split assigns it)."""
        if member not in self._live:
            raise KeyError(f"member {member} is not live (live: {self.live})")
        return self.live.index(member)

    # -- transitions ---------------------------------------------------------
    def fail(self, member: int) -> MemberEpoch:
        """An unannounced death (detected via timeout — see
        KVStore.barrier_timeout)."""
        return self._change("fail", member)

    def leave(self, member: int) -> MemberEpoch:
        """A graceful departure (preemption notice, scale-down)."""
        return self._change("leave", member)

    def join(self, member: int) -> MemberEpoch:
        """A (re)join: the member enters at the NEXT epoch with fresh
        state (reshard_optstate zero-fills its slices)."""
        if member in self._live:
            raise ValueError(f"member {member} is already live")
        self._live.add(int(member))
        return self._record("join", member)

    def _change(self, kind: str, member: int) -> MemberEpoch:
        if member not in self._live:
            raise ValueError(
                f"cannot {kind} member {member}: not live (live: {self.live})")
        if len(self._live) == 1:
            raise ValueError(
                f"cannot {kind} the last live member {member} — a tier "
                "with zero members has no survivor group to re-split to")
        self._live.discard(member)
        return self._record(kind, member)

    def _record(self, kind: str, member: int) -> MemberEpoch:
        if self.world_comm is not None:
            self.comm = self.world_comm.resized(self.live_count,
                                                axis=self.axis)
        ep = MemberEpoch(self.epoch + 1, self.live, kind, member)
        self.history.append(ep)
        return ep


# ---------------------------------------------------------------------------
# State re-shard: survivors' FlatBuffer optimizer shards re-laid-out
# ---------------------------------------------------------------------------

def _reshard_stream(stream: jax.Array, n: int, p_old: int, p_new: int,
                    survivors: Sequence[int], nr: int) -> jax.Array:
    """Re-layout ONE stacked state stream (p_old, ..., shard_old) ->
    (p_new, ..., shard_new) under the ring-major (nr, p, chunk) flat
    geometry (collectives.ring_reduce_scatter / shard_select): old
    device d owned ``full.reshape(nr, p_old, chunk)[:, d, :]``; the
    same identity at p_new defines the new shards. Dead members' slices
    of the reconstructed buffer stay zero."""
    lead = stream.shape[1:-1]
    chunk_o, total_o = flatbuf.shard_geometry(n, p_old, nr)
    chunk_n, total_n = flatbuf.shard_geometry(n, p_new, nr)
    full = jnp.zeros(lead + (nr, p_old, chunk_o), stream.dtype)
    for d in survivors:
        full = full.at[..., d, :].set(
            stream[d].reshape(lead + (nr, chunk_o)))
    flat = full.reshape(lead + (total_o,))[..., :n]
    pad = [(0, 0)] * len(lead) + [(0, total_n - n)]
    flat = jnp.pad(flat, pad)
    view = flat.reshape(lead + (nr, p_new, chunk_n))
    return jnp.stack(
        [view[..., d, :].reshape(lead + (nr * chunk_n,))
         for d in range(p_new)], axis=0)


def reshard_optstate(hyper, spec: flatbuf.FlatBuffer, stacked_state: Any,
                     p_old: int, p_new: int, *,
                     survivors: Optional[Sequence[int]] = None,
                     num_rings: int = 1,
                     bucket_bytes: Optional[int] = None,
                     state_dtypes=None) -> tuple[Any, dict]:
    """Re-shard stacked flat optimizer state across a membership change.

    ``stacked_state`` carries a leading p_old device dim (the shard
    driver's layout); ``survivors`` names the OLD ranks whose shards
    carry over, in their new rank order (default: the first p_new old
    ranks — a clean scale-down). Every family ``optstate_shard_init``
    lays out is handled: sgd/adagrad's (n,) stream, adamw's
    {"mv": (2, n), "t": ()} pair (t is a per-device scalar: survivors
    keep theirs, joiners inherit the first survivor's count).

    Returns ``(new_stacked_state, info)`` where info carries the byte
    accounting the cost model mirrors:

      state_nbytes  total bytes of the full-length state streams
                    (p_old × per-shard bytes)
      moved_bytes   wire bytes ONE survivor receives to realize the new
                    layout (the (s-1)-shard allgather leg) — equals
                    cost_model.reshard_leg_bytes(state_nbytes, p_old,
                    survivors=s) exactly
    """
    if survivors is None:
        survivors = tuple(range(min(p_old, p_new)))
    survivors = tuple(int(s) for s in survivors)
    if len(set(survivors)) != len(survivors):
        raise ValueError(f"duplicate survivors: {survivors}")
    bad = [s for s in survivors if not 0 <= s < p_old]
    if bad:
        raise ValueError(
            f"survivors {bad} outside the old device range [0, {p_old})")
    if len(survivors) > p_new:
        raise ValueError(
            f"{len(survivors)} survivors cannot fit a {p_new}-way layout")

    name = _flat_name(hyper)
    if name not in FLAT_STATE_STREAMS:
        raise ValueError(
            f"reshard_optstate knows the flat families "
            f"{sorted(FLAT_STATE_STREAMS)}, got {name!r}")
    sd = state_stream_dtype(hyper, state_dtypes)
    nr = flatbuf.effective_rings(spec.nbytes, num_rings, bucket_bytes)
    n = spec.size

    def leaves_of(state):
        if name == "adamw":
            return state["mv"]
        return state

    stream = leaves_of(stacked_state)
    want_shard = flatbuf.shard_size(spec, p_old, num_rings, bucket_bytes)
    if stream.shape[0] != p_old or stream.shape[-1] != want_shard:
        raise ValueError(
            f"stacked state has shape {stream.shape} but the {p_old}-way "
            f"ring-{nr} layout of this spec needs a leading dim {p_old} "
            f"and shard length {want_shard} — was it built with "
            "optstate_shard_init under the same geometry?")

    new_stream = _reshard_stream(stream, n, p_old, p_new, survivors, nr)
    new_stream = new_stream.astype(sd)
    if name == "adamw":
        t = stacked_state["t"]
        keep = t[survivors[0]] if survivors else jnp.zeros((), t.dtype)
        new_t = jnp.full((p_new,) + t.shape[1:], keep, t.dtype)
        for new_rank, d in enumerate(survivors):
            new_t = new_t.at[new_rank].set(t[d])
        new_state: Any = {"mv": new_stream, "t": new_t}
    else:
        new_state = new_stream

    shard_nbytes = int(stream[0].size * stream[0].dtype.itemsize)
    s = len(survivors)
    info = {
        "state_nbytes": p_old * shard_nbytes,
        "moved_bytes": float((s - 1) * shard_nbytes) if s > 1 else 0.0,
        "survivors": survivors,
        "p_old": p_old,
        "p_new": p_new,
        "num_rings": nr,
    }
    return new_state, info
