"""Dual namespaces (paper §4.1.1): every worker has a PS identity
(scheduler/server/worker rank in the global job) and an MPI identity
(rank within its client's communicator). The launcher (§4.1.2) computes
the grouping; this module is the bookkeeping both sides share.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class PSName:
    role: str  # "scheduler" | "server" | "worker"
    rank: int  # rank within role

    def __str__(self) -> str:
        return f"{self.role}:{self.rank}"


@dataclass(frozen=True)
class MPIName:
    client: int  # which MPI_COMM_WORLD (client id)
    rank: int    # rank within the client communicator

    def __str__(self) -> str:
        return f"client{self.client}/rank{self.rank}"

    @property
    def is_master(self) -> bool:
        """mpi_rank == 0 talks to the servers (paper figs. 4/5)."""
        return self.rank == 0


@dataclass(frozen=True)
class WorkerIdentity:
    ps: PSName
    mpi: MPIName


def group_workers(num_workers: int, num_clients: int) -> list[WorkerIdentity]:
    """Contiguous grouping of workers into clients (launcher policy)."""
    if num_workers % num_clients:
        raise ValueError(
            f"num_workers={num_workers} not divisible by num_clients={num_clients}"
        )
    per = num_workers // num_clients
    out = []
    for w in range(num_workers):
        out.append(
            WorkerIdentity(
                ps=PSName("worker", w),
                mpi=MPIName(client=w // per, rank=w % per),
            )
        )
    return out


def masters(identities: list[WorkerIdentity]) -> list[WorkerIdentity]:
    return [w for w in identities if w.mpi.is_master]


def client_members(identities: list[WorkerIdentity], client: int) -> list[WorkerIdentity]:
    return [w for w in identities if w.mpi.client == client]
