"""Elastic Averaging SGD (paper §2.2, eqs. (2)/(3); Zhang et al. 2015).

The PS stores *center variables* w̃. Every INTERVAL iterations a client
exchanges with the PS:

    server (Elastic1):  w̃ ← w̃ + α (w − w̃)        eq. (2)
    client (Elastic2):  w  ← w  − α (w − w̃_old)    eq. (3)

Both use the *same* pre-update difference (w − w̃): the elastic force is
symmetric — the pair conserves w + w̃ up to the α-weighted pull.

At production scale (launch/train.py) the same math runs across the
``pod`` axis of the mesh: each pod is a client holding its own replica in
a leading client dim, the centers are a co-sharded pytree, and the lazy
exchange is the only cross-pod communication — the paper's
communication-avoiding path to cluster-wide scaling.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def elastic_server_update(center: Any, client_params: Any, alpha: float) -> Any:
    """Eq. (2): move the center toward the client's params."""
    return jax.tree.map(
        lambda c, w: (
            c.astype(jnp.float32)
            + alpha * (w.astype(jnp.float32) - c.astype(jnp.float32))
        ).astype(c.dtype),
        center, client_params,
    )


def elastic_client_update(params: Any, center: Any, alpha: float) -> Any:
    """Eq. (3): pull the client's params toward the (old) center."""
    return jax.tree.map(
        lambda w, c: (
            w.astype(jnp.float32)
            - alpha * (w.astype(jnp.float32) - c.astype(jnp.float32))
        ).astype(w.dtype),
        params, center,
    )


def elastic_exchange(params: Any, center: Any, alpha: float) -> tuple[Any, Any]:
    """One full exchange: both updates computed from the same (w − w̃)."""
    new_center = elastic_server_update(center, params, alpha)
    new_params = elastic_client_update(params, center, alpha)
    return new_params, new_center


def elastic_exchange_multiclient(
    client_params: Any, center: Any, alpha: float
) -> tuple[Any, Any]:
    """Vectorized exchange for params with a leading client dim C.

    Server applies eq. (2) sequentially w.r.t. each client in expectation;
    with simultaneous clients the standard EASGD generalization is
    w̃ ← w̃ + α Σ_c (w_c − w̃). Each client applies eq. (3) with the shared
    old center.
    """
    def server(c, w):
        c32 = c.astype(jnp.float32)
        diff = jnp.sum(w.astype(jnp.float32) - c32[None], axis=0)
        return (c32 + alpha * diff).astype(c.dtype)

    new_center = jax.tree.map(server, center, client_params)
    new_params = jax.tree.map(
        lambda w, c: (
            w.astype(jnp.float32)
            - alpha * (w.astype(jnp.float32) - c.astype(jnp.float32)[None])
        ).astype(w.dtype),
        client_params, center,
    )
    return new_params, new_center
