"""Elastic Averaging SGD (paper §2.2, eqs. (2)/(3); Zhang et al. 2015).

The PS stores *center variables* w̃. Every INTERVAL iterations a client
exchanges with the PS:

    server (Elastic1):  w̃ ← w̃ + α (w − w̃)        eq. (2)
    client (Elastic2):  w  ← w  − α (w − w̃_old)    eq. (3)

Both use the *same* pre-update difference (w − w̃): the elastic force is
symmetric — the pair conserves w + w̃ up to the α-weighted pull.

At production scale (launch/train.py) the same math runs across the
``pod`` axis of the mesh: each pod is a client holding its own replica in
a leading client dim, the centers are a co-sharded pytree, and the lazy
exchange is the only cross-pod communication — the paper's
communication-avoiding path to cluster-wide scaling.

Two substrates implement the exchange:

  per-leaf  ``jax.tree.map`` of the f32 update over every leaf — the
            readable reference (this file's top half)
  flat      the whole pytree packed ONCE through ``core.flatbuf`` and a
            single fused Pallas kernel applying eqs. (2)+(3) in one HBM
            pass (``elastic_exchange_packed`` / ``_multiclient_flat``),
            plus the sharded cross-pod leg (``elastic_exchange_sharded``)
            that ring reduce-scatters the packed differences so the
            exchange waits on (p−1)/p·n bytes instead of an allreduce's
            2·(p−1)/p·n — the default since the SyncEngine refactor
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import flatbuf


def elastic_server_update(center: Any, client_params: Any, alpha: float) -> Any:
    """Eq. (2): move the center toward the client's params."""
    return jax.tree.map(
        lambda c, w: (
            c.astype(jnp.float32)
            + alpha * (w.astype(jnp.float32) - c.astype(jnp.float32))
        ).astype(c.dtype),
        center, client_params,
    )


def elastic_client_update(params: Any, center: Any, alpha: float) -> Any:
    """Eq. (3): pull the client's params toward the (old) center."""
    return jax.tree.map(
        lambda w, c: (
            w.astype(jnp.float32)
            - alpha * (w.astype(jnp.float32) - c.astype(jnp.float32))
        ).astype(w.dtype),
        params, center,
    )


def elastic_exchange(params: Any, center: Any, alpha: float) -> tuple[Any, Any]:
    """One full exchange: both updates computed from the same (w − w̃)."""
    new_center = elastic_server_update(center, params, alpha)
    new_params = elastic_client_update(params, center, alpha)
    return new_params, new_center


def elastic_exchange_multiclient(
    client_params: Any, center: Any, alpha: float
) -> tuple[Any, Any]:
    """Vectorized exchange for params with a leading client dim C.

    Server applies eq. (2) sequentially w.r.t. each client in expectation;
    with simultaneous clients the standard EASGD generalization is
    w̃ ← w̃ + α Σ_c (w_c − w̃). Each client applies eq. (3) with the shared
    old center.
    """
    def server(c, w):
        c32 = c.astype(jnp.float32)
        diff = jnp.sum(w.astype(jnp.float32) - c32[None], axis=0)
        return (c32 + alpha * diff).astype(c.dtype)

    new_center = jax.tree.map(server, center, client_params)
    new_params = jax.tree.map(
        lambda w, c: (
            w.astype(jnp.float32)
            - alpha * (w.astype(jnp.float32) - c.astype(jnp.float32)[None])
        ).astype(w.dtype),
        client_params, center,
    )
    return new_params, new_center


# ---------------------------------------------------------------------------
# Flat substrate: the exchange as ONE packed buffer + ONE fused kernel
# ---------------------------------------------------------------------------

def _wire_roundtrip(buf: jax.Array, wire_dtype: Optional[str]) -> jax.Array:
    """The low-precision wire model on ONE packed buffer: encode +
    decode = what the receiving end of a compressed push sees. The
    single place the packed (hop-free) wire form is defined — int8 rides
    the streaming WIRE_BLOCK Pallas pair (one quantize/dequantize kernel
    launch for the whole buffer), bf16 is a pure cast XLA fuses away."""
    from repro.core.collectives import check_wire_dtype
    from repro.kernels.quant_bucket.quant_bucket import (
        dequantize_wire, quantize_wire)

    wire = check_wire_dtype(wire_dtype, where="_wire_roundtrip")
    if wire is None:
        return buf
    if wire == "bf16":
        return buf.astype(jnp.bfloat16).astype(buf.dtype)
    codes, scales = quantize_wire(buf)
    return dequantize_wire(codes, scales, buf.shape[0], buf.dtype)


def _quant_roundtrip(buf: jax.Array) -> jax.Array:
    """Back-compat spelling of the int8 packed wire."""
    return _wire_roundtrip(buf, "int8")


@partial(jax.jit, static_argnames=("wire_dtype",))
def _elastic_exchange_packed(params: Any, center: Any, alpha,
                             *, wire_dtype: Optional[str] = None
                             ) -> tuple[Any, Any]:
    from repro.kernels.fused_elastic.fused_elastic import elastic_exchange_flat

    spec_w = flatbuf.spec_for(params)
    spec_c = flatbuf.spec_for(center)
    w = spec_w.pack(params)
    c = spec_c.pack(center)
    w = _wire_roundtrip(w, wire_dtype)
    new_w, new_c = elastic_exchange_flat(w, c, jnp.asarray(alpha, jnp.float32))
    return spec_w.unpack(new_w), spec_c.unpack(new_c)


def elastic_exchange_packed(params: Any, center: Any, alpha,
                            *, compress: bool = False,
                            wire_dtype: Optional[str] = None
                            ) -> tuple[Any, Any]:
    """Eqs. (2)+(3) on the WHOLE pytree as one packed FlatBuffer.

    Pack w and w̃ (static lane-aligned offsets, spec memoized per tree
    structure), run the fused Pallas kernel once — one HBM pass, one
    launch — and unpack. Zero per-leaf tree.map updates; the per-leaf
    reference is ``elastic_exchange``.

    ``wire_dtype`` ("bf16"/"int8") runs the packed w buffer through the
    wire roundtrip first — the PS-push wire form — so the exchange sees
    exactly what a compressed push delivers. The removed ``compress=True``
    alias is a hard error: it WAS ``wire_dtype="int8"``.
    """
    if compress:
        raise ValueError(
            "elastic_exchange_packed(compress=True) was removed — it is "
            "the int8 wire: pass wire_dtype='int8' instead")
    return _elastic_exchange_packed(params, center, alpha,
                                    wire_dtype=wire_dtype)


@jax.jit
def elastic_client_packed(params: Any, center: Any, alpha) -> Any:
    """Eq. (3) only, on the packed FlatBuffer: the client's local half of
    the exchange (the server half runs remotely — e.g. the KVStore's
    elastic rule), one fused pass, nothing extra written."""
    from repro.kernels.fused_elastic.fused_elastic import elastic_client_flat

    spec_w = flatbuf.spec_for(params)
    spec_c = flatbuf.spec_for(center)
    new_w = elastic_client_flat(
        spec_w.pack(params), spec_c.pack(center),
        jnp.asarray(alpha, jnp.float32))
    return spec_w.unpack(new_w)


@jax.jit
def elastic_server_packed(pushed: Any, center: Any, alpha) -> Any:
    """Eq. (2) only, on the packed FlatBuffer: the server rule applied to
    a pushed w — one fused pass, only the new center written."""
    from repro.kernels.fused_elastic.fused_elastic import elastic_server_flat

    spec_w = flatbuf.spec_for(pushed)
    spec_c = flatbuf.spec_for(center)
    new_c = elastic_server_flat(
        spec_w.pack(pushed), spec_c.pack(center),
        jnp.asarray(alpha, jnp.float32))
    return spec_c.unpack(new_c)


@partial(jax.jit, static_argnames=("wire_dtype",))
def wire_packed(tree: Any, wire_dtype: Optional[str] = "int8") -> Any:
    """Wire roundtrip of the packed FlatBuffer: what a compressed PS
    push delivers to the server (the ONE packed buffer through the
    WIRE_BLOCK codec or a bf16 cast, instead of per-leaf codes)."""
    spec = flatbuf.spec_for(tree)
    return spec.unpack(_wire_roundtrip(spec.pack(tree), wire_dtype))


def quantize_packed(tree: Any) -> Any:
    """Removed alias of the int8 packed wire roundtrip."""
    raise ValueError(
        "quantize_packed was removed — it is the int8 wire: call "
        "wire_packed(tree, wire_dtype='int8') instead")


@jax.jit
def scale_packed(tree: Any, factor) -> Any:
    """Scale a whole pytree as ONE packed FlatBuffer multiply — the
    staleness-scaling leg of the async server rule (KVStore
    attach_staleness): a push that is s versions stale is damped by
    1/(1+s) on the same flat substrate the wire codec rides, instead of
    per-leaf tree.maps."""
    spec = flatbuf.spec_for(tree)
    return spec.unpack(spec.pack(tree) * jnp.asarray(factor, jnp.float32))


@jax.jit
def elastic_exchange_multiclient_flat(
    client_params: Any, center: Any, alpha
) -> tuple[Any, Any]:
    """Flat-substrate ``elastic_exchange_multiclient``: vmap-pack the C
    client replicas into one (C, size) buffer, run ONE fused Pallas
    kernel for every client's eq. (3) and the summed eq. (2) center
    move, vmap-unpack. Matches the per-leaf version leaf-for-leaf (both
    compute in f32)."""
    from repro.kernels.fused_elastic.fused_elastic import elastic_exchange_flat_mc

    one = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), client_params
    )
    spec_w = flatbuf.spec_for(one)
    spec_c = flatbuf.spec_for(center)
    stacked = jax.vmap(spec_w.pack)(client_params)
    cbuf = spec_c.pack(center)
    new_w, new_c = elastic_exchange_flat_mc(
        stacked, cbuf, jnp.asarray(alpha, jnp.float32)
    )
    return jax.vmap(spec_w.unpack)(new_w), spec_c.unpack(new_c)


def elastic_exchange_sharded(spec: flatbuf.FlatBuffer, params: Any,
                             center: Any, alpha, *,
                             comm=None,
                             axis_name: Optional[str] = None,
                             num_rings: int = 1,
                             bucket_bytes: Optional[int] = None,
                             wire_dtype: Optional[str] = None,
                             interpret: Optional[bool] = None
                             ) -> tuple[Any, Any]:
    """Per-device cross-pod exchange (run inside shard_map over the pod
    axis, or vmap emulation): this device IS one client, the center is
    replicated.

      1. pack w and w̃; ONE Pallas pass computes eq. (3)'s new w AND the
         f32 difference (w − w̃)
      2. ring reduce-scatter the differences over the pod axis — the
         exchange leg waits on (p−1)/p·n bytes instead of an allreduce's
         2·(p−1)/p·n, the same cut the gradient path took in PR 1
      3. fused eq. (2) kernel on this device's 1/p shard of the center
      4. ring allgather of the updated center shards

    ``comm`` is the exchange group (``core.comm.Communicator`` — the
    paper's PS tier, e.g. ``world.split("pod")``); its policy supplies
    the ring count, bucketing and the wire protocol (``wire_dtype``
    "bf16"/"int8": the reduce-scattered differences and the allgathered
    center shards ride the compressed wire, hp accumulation per hop).
    A trivial group (or axis of size 1)
    degenerates to the local exchange: both kernels over the whole
    buffer, no collective. The old ``axis_name=`` string spelling was
    removed — build the group with ``Communicator.from_axis_name`` and
    pass ``comm=``. Returns ``(new_params, new_center)``, both full
    trees.
    """
    from repro.core import comm as _comm
    from repro.kernels.fused_elastic.fused_elastic import (
        elastic_center_flat, elastic_client_diff_flat)

    if axis_name is not None:
        _comm._axis_name_removed("elastic_exchange_sharded")
    if comm is None:
        comm = _comm.LOCAL.with_policy(
            num_rings=num_rings,
            bucket_bytes=bucket_bytes, wire_dtype=wire_dtype)
    elif num_rings != 1 or bucket_bytes is not None or wire_dtype is not None:
        raise ValueError(
            "with comm= the ring/wire policy lives on the communicator — "
            "set num_rings/bucket_bytes/wire_dtype there "
            "(Communicator.with_policy), not as arguments")

    p = comm.resolve_size()
    nr = comm.rings_for(spec.nbytes)
    _, total = flatbuf.shard_geometry(spec.size, p, nr)
    w = flatbuf.pack_padded(spec, params, total)
    c = flatbuf.pack_padded(spec, center, total)
    alpha = jnp.asarray(alpha, jnp.float32)

    new_w, diff = elastic_client_diff_flat(w, c, alpha, interpret=interpret)
    if p == 1:
        diff_sum, c_shard = diff, c
    else:
        diff_sum = comm.reduce_scatter(diff, num_rings=nr)
        c_shard = comm.shard_select(c, num_rings=nr)
    new_c_shard = elastic_center_flat(c_shard, diff_sum, alpha,
                                      interpret=interpret)
    new_c = (new_c_shard if p == 1
             else comm.allgather(new_c_shard, num_rings=nr))
    return spec.unpack(new_w[:spec.size]), spec.unpack(new_c[:spec.size])
