"""Version-gated jax API shims.

The repo targets the modern jax surface (``lax.axis_size``,
``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.set_mesh``) but must
also run on the pinned 0.4.x toolchain in CI containers, where those
names either live elsewhere or do not exist. Everything that is
version-sensitive goes through here so the rest of the codebase imports
one stable spelling.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax import lax


def axis_size(axis_name: Any) -> int:
    """Static size of a named mesh/vmap axis.

    ``lax.axis_size`` where available; otherwise ``psum(1, axis)`` — with
    a Python-int operand the sum is evaluated statically, so this returns
    a concrete int under both shard_map and vmap emulation.
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def shard_map(*args, **kwargs):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (0.4.x).

    The 0.4.x version spells ``check_vma`` as ``check_rep``; translate.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(*args, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _sm(*args, **kwargs)


def make_mesh(shape, axis_names, *, auto: bool = True):
    """``jax.make_mesh`` with ``AxisType.Auto`` when the installed jax has
    typed mesh axes, plain ``jax.make_mesh`` otherwise (0.4.x meshes are
    implicitly auto)."""
    try:
        from jax.sharding import AxisType  # jax >= 0.5

        types = (AxisType.Auto if auto else AxisType.Explicit,) * len(axis_names)
        return jax.make_mesh(shape, axis_names, axis_types=types)
    except ImportError:
        return jax.make_mesh(shape, axis_names)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on new jax; on 0.4.x the ``Mesh`` object itself is
    the context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)
