"""SyncEngine: the strategy layer behind every lowerable sync mode.

``launch/train.py`` and ``launch/shard_driver.py`` used to branch inline
on HOW a step syncs and updates (``fused_path_active`` / ``step_c1`` /
``step_multiclient``). That choice is now made ONCE, here, and the step
builders drive a single interface:

  init_opt             optimizer-state layout (flat momentum buffer vs
                       per-leaf pytree)
  update               the sync+update leg (packed reduce-scatter ->
                       fused Pallas kernel -> allgather, vs per-leaf
                       ``Optimizer.update``)
  exchange_multiclient the elastic leg for C stacked replicas (packed
                       single-launch kernel vs per-leaf tree.maps)
  check_opt_layout     loud trace-time guard that the state factory and
                       the step factory agreed on the layout

Selection (``make_sync_engine``):

  flat update    ``fused_update`` and a lowerable optimizer (momentum
                 SGD with f32 state, AdaGrad, or AdamW — the K-stream
                 fused kernels in kernels/fused_sgd + kernels/fused_optim)
                 and NO ambient mesh — both ``mpi_sgd`` (C=1, collectives
                 over the gradient Communicator) and ``mpi_esgd`` (per-client local
                 geometry; the step vmaps ``update`` over the client dim)
  flat exchange  ``flat_exchange`` and no mesh — independent of the
                 update substrate, so e.g. a custom-optimizer run still
                 gets the packed elastic leg

With an ambient mesh GSPMD owns the collectives: both legs stay per-leaf
so parameter sharding is undisturbed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import comm as comm_lib, flatbuf
from repro.core.elastic import (
    elastic_exchange_multiclient,
    elastic_exchange_multiclient_flat,
)
from repro.core.hierarchy import SyncConfig
from repro.optim.sgd import (
    FLAT_STATE_STREAMS,
    Optimizer,
    optstate_sched_init,
    optstate_shard_init,
    overlap_update,
    scatter_update_gather,
)


def flat_update_supported(optimizer: Optimizer, sync: SyncConfig,
                          mesh=None) -> bool:
    """Whether the packed fused-kernel update can replace per-leaf.

    Requires a lowerable optimizer — momentum SGD, AdaGrad or AdamW
    (``optim.sgd.FLAT_STATE_STREAMS``); for SGD the momentum dtype must
    be the buffer's f32 (an explicit low-precision ``state_dtype`` keeps
    the per-leaf path that honors it) — and no ambient mesh: with a
    mesh, GSPMD owns the gradient collectives and per-leaf updates keep
    parameter sharding undisturbed.
    """
    hyper = optimizer.hyper
    if not (sync.fused_update and sync.mode in ("mpi_sgd", "mpi_esgd")
            and mesh is None):
        return False
    # the flat_* Optimizer wrappers alias their per-leaf family
    name = hyper.get("name", "")
    name = name[5:] if name.startswith("flat_") else name
    if name == "sgd":
        return (hyper.get("momentum", 0.0) > 0.0
                and hyper.get("state_dtype") in (None, jnp.float32))
    return name in FLAT_STATE_STREAMS


def flat_exchange_active(sync: SyncConfig, mesh=None) -> bool:
    """Whether the elastic leg runs packed (FlatBuffer + fused kernel)."""
    return sync.mode == "mpi_esgd" and sync.flat_exchange and mesh is None


@dataclass(frozen=True)
class SyncEngine:
    """Per-leaf strategy (the GSPMD / custom-optimizer path).

    ``comm`` is the gradient group (``core.comm.Communicator``) the
    update leg syncs over — trivial for the local / per-client path.
    """

    optimizer: Optimizer
    sync: SyncConfig
    comm: comm_lib.Communicator = comm_lib.LOCAL
    flat_exchange: bool = False
    spec: Optional[flatbuf.FlatBuffer] = None

    fused = False  # class attr, not a field: FlatEngine overrides

    # -- update leg ---------------------------------------------------------
    def init_opt(self, params: Any) -> Any:
        return self.optimizer.init(params)

    def update(self, grads: Any, opt_state: Any, params: Any):
        return self.optimizer.update(grads, opt_state, params)

    def check_opt_layout(self, opt_state: Any, num_clients: int = 1) -> None:
        if isinstance(opt_state, jax.Array) or _is_flat_adamw_state(opt_state):
            raise ValueError(
                "per-leaf update got a flat fused state buffer — pass "
                "the same mesh to make_train_state(..., mesh=...) and "
                "make_train_step(..., mesh), or set "
                "SyncConfig.fused_update=False for both")

    # -- elastic leg --------------------------------------------------------
    def exchange_multiclient(self, client_params: Any, center: Any, alpha):
        """One elastic exchange over C stacked replicas (eqs. 2+3)."""
        if self.flat_exchange:
            return elastic_exchange_multiclient_flat(client_params, center,
                                                     alpha)
        return elastic_exchange_multiclient(client_params, center, alpha)


def _is_flat_adamw_state(opt_state: Any) -> bool:
    """The flat AdamW layout ({"mv": (2, n), "t": ()}) — distinct from the
    per-leaf adamw pytree ({"m": tree, "v": tree, "t": ()})."""
    return isinstance(opt_state, dict) and set(opt_state) == {"mv", "t"}


@dataclass(frozen=True)
class FlatEngine(SyncEngine):
    """Flat-buffer strategy: the whole gradient pytree rides one packed
    buffer through ring collectives and ONE fused Pallas kernel, with the
    K optimizer-state streams stored as flat (sharded) buffers — in the
    declared stream dtype (``hyper["state_dtype"]``: bf16 halves the
    state bytes on top of the 1/p sharding), over the gradient
    communicator's full policy (rings, bucketing, and the bf16/int8
    low-precision wire protocol on every hop)."""

    fused = True

    # backward-overlapped path (SyncConfig.overlap): the schedule over
    # the STAGED param spec (flatbuf.BucketSchedule, bucket == backward
    # stage), built at the gradient group's p. None = monolithic leg.
    schedule: Optional[flatbuf.BucketSchedule] = None

    def _num_rings(self) -> int:
        return self.comm.rings_for(self.spec.nbytes)

    def init_opt(self, params: Any) -> Any:
        # local (p=1) geometry; device-sharded drivers re-init per device
        # with optstate_shard_init(hyper, spec, p, ...) — or, overlapped,
        # optstate_sched_init(hyper, schedule) at the device schedule
        if self.schedule is not None:
            return optstate_sched_init(self.optimizer.hyper,
                                       self.schedule.with_p(1))
        return optstate_shard_init(self.optimizer.hyper, self.spec, 1,
                                   self._num_rings())

    def update(self, grads: Any, opt_state: Any, params: Any):
        return scatter_update_gather(
            self.spec, grads, params, opt_state,
            hyper=self.optimizer.hyper, comm=self.comm,
        )

    def update_overlapped(self, g_shard: Any, staged_params: Any,
                          opt_state: Any):
        """The post-backward half of the overlapped step: fused kernel on
        the bucket-major shard + the ONE trailing allgather. ``g_shard``
        comes from the staged grad fn (per-bucket reduce-scatter legs
        already issued mid-backward); returns staged params."""
        return overlap_update(
            self.schedule, g_shard, staged_params, opt_state,
            hyper=self.optimizer.hyper, comm=self.comm,
        )

    def check_opt_layout(self, opt_state: Any, num_clients: int = 1) -> None:
        if self.optimizer.hyper.get("name", "").endswith("adamw"):
            if not _is_flat_adamw_state(opt_state):
                raise ValueError(
                    "fused adamw sync path expects the flat {'mv', 't'} "
                    "state, but the train state carries a per-leaf opt "
                    "state — pass the same mesh to "
                    "make_train_state(..., mesh=...) and "
                    "make_train_step(..., mesh)")
            buf, streams = opt_state["mv"], 2
        else:
            if not isinstance(opt_state, jax.Array):
                raise ValueError(
                    "fused sync path expects the flat state buffer, but the "
                    "train state carries a per-leaf opt state — pass the "
                    "same mesh to make_train_state(..., mesh=...) and "
                    "make_train_step(..., mesh)")
            buf, streams = opt_state, 1
        # C>1 vmaps the update per client, so each client is p=1 geometry
        p = 1 if num_clients > 1 else self.comm.resolve_size()
        if self.schedule is not None:
            # overlapped layout: bucket-major concat of per-bucket chunks
            want = self.schedule.with_p(p).shard_size
        else:
            want = flatbuf.shard_size(self.spec, p, self.sync.num_rings,
                                      self.sync.bucket_bytes)
        per_client = buf.size // (streams * max(num_clients, 1))
        if per_client != want:
            raise ValueError(
                f"fused state shard has {per_client} elements per stream "
                f"but the {p}-way axis geometry needs {want} — per-device "
                "state for sharded drivers comes from "
                "optim.sgd.optstate_shard_init(hyper, spec, p, ...), not "
                "from make_train_state's local (p=1) buffer; state saved "
                "under a DIFFERENT device count (elastic membership "
                "change, restore on new geometry) re-lays-out with "
                "core.membership.reshard_optstate(hyper, spec, state, "
                "p_old, p_new)")


def make_sync_engine(optimizer: Optimizer, sync: SyncConfig, mesh=None, *,
                     comm: Optional[comm_lib.Communicator] = None,
                     axis_name: Optional[str] = None,
                     spec: Optional[flatbuf.FlatBuffer] = None,
                     schedule: Optional[flatbuf.BucketSchedule] = None,
                     ) -> SyncEngine:
    """Resolve the strategy for (optimizer, sync, mesh) once.

    ``comm`` is the gradient group the update leg syncs over; omitted,
    it is built from the SyncConfig recipe (trivial group — the local /
    per-client geometry). The old ``axis_name=`` string spelling was
    removed — build the group with ``Communicator.from_axis_name`` and
    pass ``comm=``. ``spec`` (the param-tree FlatBuffer) is required
    whenever a flat leg engages; callers that might need it build it
    with ``launch.train.grad_spec``.
    """
    if axis_name is not None:
        comm_lib._axis_name_removed("make_sync_engine")
    if comm is None:
        comm = comm_lib.from_sync(sync)
    fused = flat_update_supported(optimizer, sync, mesh)
    flat_ex = flat_exchange_active(sync, mesh)
    if fused and spec is None:
        raise ValueError("flat-update engine needs the FlatBuffer spec")
    if sync.overlap and not fused:
        raise ValueError(
            "SyncConfig.overlap=True but the fused flat update cannot "
            "engage for this (optimizer, sync, mesh) — overlap rides the "
            "fused path only (core.sync_engine.flat_update_supported): "
            "use momentum SGD / AdaGrad / AdamW with fused_update=True "
            "and no ambient mesh")
    if sync.overlap and schedule is None:
        raise ValueError(
            "overlap engine needs the BucketSchedule — build it with "
            "launch.train.overlap_schedule(model, sync, p) from the "
            "model's staged param spec")
    if not fused:
        schedule = None
    if fused:
        return FlatEngine(optimizer, sync, comm=comm, flat_exchange=flat_ex,
                          spec=spec, schedule=schedule)
    return SyncEngine(optimizer, sync, comm=comm, flat_exchange=flat_ex,
                      spec=spec)
