"""Tensor collectives (paper §6), TPU-native.

The paper's "tensor" is a *group of vectors treated as one object* so that
single-vector ring algorithms apply to the whole group at once. The TPU
adaptation: the gradient pytree is packed ONCE into a persistent
``FlatBuffer`` (core/flatbuf.py — static lane-aligned offsets computed a
single time per model, no per-step concatenate) and a single bucket (ring)
algorithm runs over it — gradient-bucket fusion — instead of one
collective per parameter (``method="per_leaf"`` is that baseline).
Variants:

  ring            bucket algorithm: ring reduce-scatter + ring allgather
                  (bandwidth-optimal: (p-1)a + 2*(p-1)/p*n*b + (p-1)/p*n*g)
  multi_ring      the paper's overlap: buffer split across R independent
                  ring schedules whose compute/transfer steps interleave
                  (XLA is the dependency engine that overlaps them, like
                  the paper's Engine.push lambdas)
  tree            binomial reduce-to-0 + broadcast — the `reg` baseline
                  and the PS push/pull communication pattern
  psum            XLA's native fused all-reduce (beyond-paper reference)
  scatter_gather  explicit reduce-scatter + allgather halves: the substrate
                  of the sharded fused-optimizer path (optim/sgd.py
                  ``scatter_update_gather`` runs the update between the
                  halves, so the gradient leg is (p-1)/p*n instead of
                  2*(p-1)/p*n and momentum lives sharded 1/p per device)

``ring_reduce_scatter``/``ring_allgather``/``shard_select`` all take a
``num_rings`` knob: the buffer splits into R independent ring schedules
(bucket chunking — ``SyncConfig.bucket_bytes`` maps onto it via
``flatbuf.effective_rings``), emitted interleaved so the scheduler
overlaps ring r's reduction with ring r+1's transfer.

``ring_reduce_scatter``/``ring_allgather`` additionally take a
``wire_dtype`` knob — the low-precision wire protocol:

  None/"f32"  every hop sends the full-precision chunk (the baseline)
  "bf16"      each hop casts the outgoing chunk to bf16 (pure cast, no
              scales) — 0.5x the f32 wire bytes
  "int8"      each hop sends int8 codes + one f32 scale per WIRE_BLOCK
              (=LANE) bucket (kernels/quant_bucket.wire_encode) —
              (1 + 4/128)/4 ~ 0.258x the f32 wire bytes

The ACCUMULATOR always stays high-precision: a reduce-scatter hop
dequantizes the received chunk, adds it to the local f32 partial, and
re-quantizes only what the next hop sends (dequant-accumulate-requant).
An allgather shard is encoded ONCE and its codes forwarded verbatim —
and the owner roundtrips its own shard through the codec too, so every
device reconstructs bit-identical values and replicas cannot diverge.
The codec is plain jnp traced inline (XLA fuses it): a quantized hop
adds ZERO kernel launches to the step.

All algorithms are written against ``lax.ppermute``/named axes, so the
same code runs inside ``shard_map`` on a real mesh *and* under
``jax.vmap(..., axis_name=...)`` single-device emulation (used by tests).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import flatbuf
from repro.core.compat import axis_size as _axis_size

Method = str
_METHODS = ("ring", "multi_ring", "tree", "psum", "per_leaf", "scatter_gather")

#: wire dtypes of the low-precision protocol; None and "f32" are the
#: full-precision baseline, the ring-family methods accept all of them
WIRE_DTYPES = (None, "f32", "bf16", "int8")
#: the methods whose hops can carry a quantized wire (explicit ppermute
#: rings; psum/tree are XLA-native or full-buffer baselines)
RING_METHODS = ("ring", "multi_ring", "scatter_gather")


def check_wire_dtype(wire_dtype, *, where: str) -> "str | None":
    """Validate + normalize a wire dtype ("f32" -> None)."""
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"{where}: wire_dtype must be one of {WIRE_DTYPES}, "
            f"got {wire_dtype!r}")
    return None if wire_dtype == "f32" else wire_dtype


def _hop_permute(x: jax.Array, axis_name: str, perm,
                 wire_dtype: "str | None") -> jax.Array:
    """One ring hop of ``x`` under the wire protocol: returns the
    receiver's high-precision (f32) view of what crossed the wire."""
    if wire_dtype is None:
        return lax.ppermute(x, axis_name, perm)
    if wire_dtype == "bf16":
        return lax.ppermute(
            x.astype(jnp.bfloat16), axis_name, perm).astype(jnp.float32)
    # int8: codes + per-bucket scales both ride the permute; dequant at
    # the receiver (inline jnp — no extra kernel launch)
    from repro.kernels.quant_bucket.quant_bucket import wire_decode, wire_encode

    codes, scales = wire_encode(x)
    codes = lax.ppermute(codes, axis_name, perm)
    scales = lax.ppermute(scales, axis_name, perm)
    return wire_decode(codes, scales, x.shape[0])


def ring_allreduce(x: jax.Array, axis_name: str, *, num_rings: int = 1) -> jax.Array:
    """Bucket-algorithm allreduce of ``x`` over ``axis_name`` (sum)."""
    p = _axis_size(axis_name)
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    shape, n = x.shape, x.size
    nr = max(1, num_rings)
    chunk = -(-n // (p * nr))
    flat = jnp.pad(x.reshape(-1), (0, chunk * p * nr - n))
    bufs = flat.reshape(nr, p, chunk)
    fwd = [(i, (i + 1) % p) for i in range(p)]

    # Emit all rings' step-s ops together: each ring's chain is independent,
    # so the scheduler overlaps ring r's reduction with ring r+1's transfer
    # (paper fig. 9's GpuStart/SendRecv pipeline, compiler-scheduled).
    acc = [None] * nr
    for s in range(p - 1):
        for r in range(nr):
            send = jnp.take(bufs[r], (idx - s) % p, axis=0) if s == 0 else acc[r]
            recv = lax.ppermute(send, axis_name, fwd)
            acc[r] = jnp.take(bufs[r], (idx - s - 1) % p, axis=0) + recv

    outs = []
    for r in range(nr):
        out = lax.dynamic_update_slice_in_dim(
            bufs[r], acc[r][None], (idx + 1) % p, axis=0
        )
        outs.append(out)
    cur = list(acc)
    for s in range(p - 1):
        for r in range(nr):
            nxt = lax.ppermute(cur[r], axis_name, fwd)
            outs[r] = lax.dynamic_update_slice_in_dim(
                outs[r], nxt[None], (idx - s) % p, axis=0
            )
            cur[r] = nxt
    flat_out = jnp.stack(outs).reshape(-1)[:n]
    return flat_out.reshape(shape)


def ring_reduce_scatter(x: jax.Array, axis_name: str, *,
                        num_rings: int = 1,
                        wire_dtype: "str | None" = None) -> jax.Array:
    """Each device ends with its own fully-reduced 1/p slice.

    With ``num_rings = R > 1`` the buffer splits into R independent ring
    schedules (layout ``(R, p, chunk)``, emitted interleaved for overlap)
    and the local shard is the R per-ring chunks raveled to
    ``(R*chunk,)`` — the same strided selection ``shard_select`` makes,
    and what ``ring_allgather(num_rings=R)`` inverts.

    With a low-precision ``wire_dtype`` every hop sends the compressed
    chunk (bf16 cast, or int8 codes + per-bucket scales) while the
    accumulator stays f32: dequant-accumulate-requant per hop, so the
    quantization error never compounds through the running sum — each
    hop's error is one encode of the current partial. The result is f32.
    """
    wire = check_wire_dtype(wire_dtype, where="ring_reduce_scatter")
    p = _axis_size(axis_name)
    n = x.size
    nr = max(1, num_rings)
    chunk = -(-n // (p * nr))
    flat = jnp.pad(x.reshape(-1), (0, chunk * p * nr - n))
    if p == 1:
        return flat
    idx = lax.axis_index(axis_name)
    bufs = flat.reshape(nr, p, chunk)
    fwd = [(i, (i + 1) % p) for i in range(p)]
    acc = [None] * nr
    # shifted schedule so device i ends owning chunk i of every ring
    for s in range(p - 1):
        for r in range(nr):
            send = jnp.take(bufs[r], (idx - s - 1) % p, axis=0) if s == 0 else acc[r]
            recv = _hop_permute(send, axis_name, fwd, wire)
            local = jnp.take(bufs[r], (idx - s - 2) % p, axis=0)
            if wire is not None:
                local = local.astype(jnp.float32)  # hp accumulator
            acc[r] = local + recv
    if nr == 1:
        return acc[0]  # fully-reduced chunk idx
    return jnp.stack(acc).reshape(-1)


def ring_allgather(x: jax.Array, axis_name: str, *,
                   num_rings: int = 1,
                   wire_dtype: "str | None" = None) -> jax.Array:
    """Inverse of reduce-scatter: gather per-device shards to the full
    ``(nr*p*chunk,)`` buffer (ring-major layout, matching
    ``ring_reduce_scatter(num_rings=nr)``).

    With a low-precision ``wire_dtype`` each shard is encoded ONCE and
    its codes forwarded verbatim hop to hop (gathering moves values, it
    never re-reduces them, so nothing compounds) — and the owner
    roundtrips its OWN shard through the codec too, so every device
    reconstructs bit-identical buffers and replicated params cannot
    diverge. The result is f32.
    """
    from repro.kernels.quant_bucket.quant_bucket import wire_decode, wire_encode

    wire = check_wire_dtype(wire_dtype, where="ring_allgather")
    p = _axis_size(axis_name)
    nr = max(1, num_rings)
    if p == 1:
        return x.reshape(-1) if wire is None else \
            x.reshape(-1).astype(jnp.float32)
    idx = lax.axis_index(axis_name)
    chunk = x.size // nr
    shards = x.reshape(nr, chunk)
    fwd = [(i, (i + 1) % p) for i in range(p)]
    outs, cur = [], []
    for r in range(nr):
        if wire is None:
            own, wired = shards[r], shards[r]
        elif wire == "bf16":
            wired = shards[r].astype(jnp.bfloat16)
            own = wired.astype(jnp.float32)
        else:
            wired = wire_encode(shards[r])  # (codes, scales)
            own = wire_decode(*wired, chunk)
        out = jnp.zeros((p, chunk), own.dtype)
        out = lax.dynamic_update_slice_in_dim(out, own[None], idx, axis=0)
        outs.append(out)
        cur.append(wired)
    for s in range(p - 1):
        for r in range(nr):
            if wire == "int8":
                nxt = tuple(lax.ppermute(c, axis_name, fwd) for c in cur[r])
                val = wire_decode(*nxt, chunk)
            else:
                nxt = lax.ppermute(cur[r], axis_name, fwd)
                val = nxt if wire is None else nxt.astype(jnp.float32)
            outs[r] = lax.dynamic_update_slice_in_dim(
                outs[r], val[None], (idx - s - 1) % p, axis=0
            )
            cur[r] = nxt
    if nr == 1:
        return outs[0].reshape(-1)
    return jnp.stack(outs).reshape(-1)


def shard_select(flat: jax.Array, axis_name: str, *,
                 num_rings: int = 1) -> jax.Array:
    """This device's shard of a *replicated* flat buffer — exactly the
    slice ``ring_reduce_scatter`` with the same geometry would leave here
    (used to pair the replicated params with the reduce-scattered grads).
    ``flat.size`` must divide by ``p * num_rings`` (pad via
    ``flatbuf.shard_geometry`` first)."""
    p = _axis_size(axis_name)
    nr = max(1, num_rings)
    if p == 1:
        return flat.reshape(-1)
    idx = lax.axis_index(axis_name)
    chunk = flat.size // (p * nr)
    sel = jnp.take(flat.reshape(nr, p, chunk), idx, axis=1)
    return sel.reshape(-1)


def _complete_perm(perm: list[tuple[int, int]], p: int) -> list[tuple[int, int]]:
    """ppermute under vmap emulation requires a full permutation; complete a
    partial one with dummy routes (receivers mask them out explicitly, so
    semantics are identical on a real mesh)."""
    srcs = {s for s, _ in perm}
    dsts = {d for _, d in perm}
    free_s = sorted(set(range(p)) - srcs)
    free_d = sorted(set(range(p)) - dsts)
    return perm + list(zip(free_s, free_d))


def tree_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Binomial reduce to rank 0 + binomial broadcast (`reg` baseline —
    also the PS push/pull pattern: everyone pushes, server broadcasts)."""
    p = _axis_size(axis_name)
    if p == 1:
        return x
    assert p & (p - 1) == 0, "tree_allreduce requires power-of-two axis"
    idx = lax.axis_index(axis_name)
    d = 1
    while d < p:
        perm = _complete_perm(
            [(i, i - d) for i in range(p) if i % (2 * d) == d], p
        )
        recv = lax.ppermute(x, axis_name, perm)
        is_dst = (idx % (2 * d)) == 0
        x = x + jnp.where(is_dst, recv, jnp.zeros_like(recv))
        d *= 2
    d //= 2
    while d >= 1:
        perm = _complete_perm(
            [(i - d, i) for i in range(p) if i % (2 * d) == d], p
        )
        recv = lax.ppermute(x, axis_name, perm)
        is_dst = (idx % (2 * d)) == d
        x = jnp.where(is_dst, recv, x)
        d //= 2
    return x


def scatter_gather_allreduce(x: jax.Array, axis_name: str, *,
                             num_rings: int = 1,
                             wire_dtype: "str | None" = None) -> jax.Array:
    """Allreduce as its two explicit halves (reduce-scatter + allgather).

    Same wire bytes as ``ring`` — the point is that the halves are
    *separable*: the sharded fused-step path runs the optimizer between
    them, so the second half carries updated params instead of gradients.
    Each half applies the ``wire_dtype`` protocol independently.
    """
    p = _axis_size(axis_name)
    if p == 1:
        return x
    shape, n = x.shape, x.size
    nr = max(1, num_rings)
    shard = ring_reduce_scatter(x, axis_name, num_rings=nr,
                                wire_dtype=wire_dtype)
    full = ring_allgather(shard, axis_name, num_rings=nr,
                          wire_dtype=wire_dtype)
    return full[:n].reshape(shape).astype(x.dtype)


def allreduce(x: jax.Array, axis_name: str, method: Method = "ring",
              *, num_rings: int = 2) -> jax.Array:
    if method == "psum":
        return lax.psum(x, axis_name)
    if method == "ring":
        return ring_allreduce(x, axis_name, num_rings=1)
    if method == "multi_ring":
        return ring_allreduce(x, axis_name, num_rings=num_rings)
    if method == "tree":
        return tree_allreduce(x, axis_name)
    if method == "scatter_gather":
        return scatter_gather_allreduce(x, axis_name, num_rings=num_rings)
    raise ValueError(f"unknown allreduce method {method!r}")


# --------------------------------------------------------------------------
# Schedule-bucketed legs (backward overlap)
# --------------------------------------------------------------------------
#
# A ``flatbuf.BucketSchedule`` partitions the packed buffer at stage
# boundaries; each bucket gets its OWN single-ring reduce-scatter leg so
# the grad fn can issue bucket b's leg while later (earlier-in-forward)
# stages are still differentiating. One trailing allgather moves the
# whole updated shard, and ``sched_reassemble`` statically re-stitches
# the device-major gather into the packed layout. Multi-axis (pod×data)
# nesting lives on ``Communicator.reduce_scatter_bucket`` /
# ``allgather_sched``, which compose these per level.

def sched_reduce_scatter_bucket(seg: jax.Array, axis_name: str,
                                schedule, b: int, *,
                                wire_dtype: "str | None" = None) -> jax.Array:
    """One schedule bucket's ring reduce-scatter leg (single axis).

    ``seg`` is bucket ``b``'s packed ``(sizes[b],)`` segment (or its
    already-padded ``(p*chunks[b],)`` form); returns this device's
    fully-reduced ``(chunks[b],)`` chunk. Single-ring on purpose: the
    schedule buckets are the overlap units — extra rings inside one
    would fight the backward-stage interleave.
    """
    padded = schedule.bucket_padded(b)
    if seg.size < padded:
        seg = jnp.pad(seg.reshape(-1), (0, padded - seg.size))
    return ring_reduce_scatter(seg, axis_name, num_rings=1,
                               wire_dtype=wire_dtype)


def sched_reassemble(gathered: jax.Array, schedule) -> jax.Array:
    """Invert the scheduled allgather: ``gathered`` is the device-major
    ``(p * shard_size,)`` concatenation of per-device schedule shards
    (each shard the bucket-major concat of its per-bucket chunks);
    returns the ``(spec.size,)`` packed buffer. Pure static slices."""
    m = schedule.shard_size
    offs = schedule.shard_offsets
    parts = []
    for b in range(schedule.num_buckets):
        cb = schedule.chunks[b]
        pieces = [gathered[d * m + offs[b]: d * m + offs[b] + cb]
                  for d in range(schedule.p)]
        full = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
        parts.append(full[: schedule.sizes[b]])
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Tensor (fused-pytree) collectives — the paper's group-of-vectors object
# --------------------------------------------------------------------------
#
# The canonical spelling is ``Communicator.tensor_allreduce`` /
# ``Communicator.pushpull`` (core/comm.py): the group object owns the
# whole ``CollectivePolicy``. The free functions below only accept a
# Communicator now — the ``axis_name=`` string form is a hard error.

def _as_group(axis_name_or_comm, method, num_rings, bucket_bytes=None,
              wire_dtype=None, *, where: str):
    """A Communicator passes through (explicit policy knobs alongside it
    are rejected — the policy lives on the group, matching
    ``scatter_update_gather``'s contract); the removed axis-name string
    form raises, naming ``Communicator.from_axis_name``."""
    from repro.core import comm as _comm

    if isinstance(axis_name_or_comm, _comm.Communicator):
        if method is not None or num_rings is not None \
                or wire_dtype is not None:
            raise ValueError(
                f"{where}: with a Communicator the collective policy "
                "lives on the group — set method/num_rings/wire_dtype "
                "there (Communicator.with_policy), not as arguments")
        return axis_name_or_comm
    _comm._axis_name_removed(where)


def tensor_allreduce(tree: Any, axis_name: "str | Any",
                     method: Method | None = None, *,
                     num_rings: int | None = None,
                     wire_dtype: "str | None" = None,
                     mean: bool = False,
                     spec: flatbuf.FlatBuffer | None = None) -> Any:
    """Allreduce a whole pytree as ONE fused buffer (tensor collective).

    ``axis_name`` must be a ``core.comm.Communicator`` (the policy lives
    on the group, and explicit ``method``/``num_rings`` arguments are
    rejected); the removed bare-string form raises, naming
    ``Communicator.from_axis_name``. The flat-buffer spec is memoized
    per tree structure (``spec_for``) or passed in by callers that built
    it once at setup time — either way there is no per-step
    re-flatten/concatenate.
    """
    group = _as_group(axis_name_or_comm=axis_name, method=method,
                      num_rings=num_rings, wire_dtype=wire_dtype,
                      where="tensor_allreduce")
    return group.tensor_allreduce(tree, mean=mean, spec=spec)


def tensor_pushpull(tree: Any, axis_name: "str | Any", *, fused: bool = True,
                    method: Method | None = None,
                    num_rings: int | None = None,
                    wire_dtype: "str | None" = None,
                    spec: flatbuf.FlatBuffer | None = None) -> Any:
    """KVStore.pushpull comm pattern. ``fused=True`` is the paper's new API
    (one tensor allreduce, with ``method`` selecting the bucket algorithm,
    default ring); ``fused=False`` is push (reduce-to-master) + pull
    (broadcast) — two binomial-tree phases like ZPush + ZPull, which IS
    the communication pattern, so ``method`` must be left unset (or
    "tree") there. ``axis_name`` must be a ``Communicator``; the removed
    bare-string form raises."""
    if not fused and method not in (None, "tree"):
        raise ValueError(
            f"method={method!r} is only meaningful for fused=True; the "
            "unfused path is defined as tree push + tree pull")
    group = _as_group(axis_name_or_comm=axis_name, method=method,
                      num_rings=num_rings, wire_dtype=wire_dtype,
                      where="tensor_pushpull")
    return group.pushpull(tree, fused=fused, spec=spec)


# --------------------------------------------------------------------------
# Single-device emulation (tests / CPU benches): vmap provides the axis
# --------------------------------------------------------------------------

def emulate(fn: Callable, stacked: Any, axis_name: str = "ring", **kw) -> Any:
    """Run a collective over a *stacked* leading device dim via vmap."""
    return jax.vmap(lambda t: fn(t, axis_name, **kw), axis_name=axis_name)(stacked)


def _selftest(p: int = 8) -> None:  # pragma: no cover (subprocess helper)
    import numpy as np

    key = jax.random.key(0)
    x = jax.random.normal(key, (p, 1000))
    want = jnp.sum(x, axis=0)
    methods = ("ring", "multi_ring", "tree", "psum", "scatter_gather")
    for method in methods:
        got = emulate(allreduce, x, method=method)
        np.testing.assert_allclose(got, jnp.broadcast_to(want, got.shape),
                                   rtol=2e-5, atol=2e-5)
    print(f"collectives selftest OK p={p} (vmap emulation)")

    # real shard_map path when the process has >= p devices
    if len(jax.devices()) >= p:
        from jax.sharding import PartitionSpec as P

        from repro.core.compat import make_mesh, shard_map

        mesh = make_mesh((p,), ("ring",))
        for method in methods:
            fn = shard_map(
                lambda v: allreduce(v, "ring", method=method),
                mesh=mesh, in_specs=P("ring", None), out_specs=P("ring", None),
                check_vma=False,
            )
            got = fn(x)  # (p, 1000) sharded over ring -> each shard summed
            np.testing.assert_allclose(
                got, jnp.broadcast_to(want, got.shape), rtol=2e-5, atol=2e-5)
        print(f"collectives selftest OK p={p} (shard_map on "
              f"{len(jax.devices())} devices)")


if __name__ == "__main__":  # pragma: no cover
    import sys

    _selftest(int(sys.argv[1]) if len(sys.argv) > 1 else 8)
