"""Client ↔ mesh mapping: the paper's `#clients` knob at production scale.

At scale a *client* is a mesh slice: the `data` axis of one pod is one
MPI communicator; the `pod` axis is the PS tier. Params optionally carry a
leading client dim C (one replica per client, sharded over `pod`), so:

  C = 1            pure-MPI mode: one communicator spanning all data axes,
                   gradients fully allreduced every step (mpi-SGD,
                   #servers = 0, pushpull = tensor allreduce)
  C = #pods        one client per pod: gradient sync inside the pod only;
                   cross-pod communication is the lazy elastic exchange
                   every INTERVAL steps (mpi-ESGD)

This file holds the *logic* (pure pytree/spec transforms); launch/train.py
binds it to the real mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import InitVar, dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.comm import CollectivePolicy, filter_mirrors, resolve_policy

#: the flat-field defaults SyncConfig historically shipped — the base
#: point the deprecation shim resolves non-default flat kwargs against
_SYNC_BASE = CollectivePolicy(method="psum", num_rings=2)


@dataclass(frozen=True)
class SyncConfig:
    """Production gradient-sync mode (the lowerable subset of MODES).

    The collective policy — allreduce method, ring count, bucketing,
    wire protocol, overlap — is ONE ``CollectivePolicy``: pass it as
    ``policy=`` and read it back as ``.policy``. The old flat fields
    remain as mirrors of the resolved policy for one release (writing
    them routes through the single ``comm.resolve_policy`` shim, which
    warns whenever they change the policy), so ``cfg.allreduce_method``
    keeps reading and ``dataclasses.replace(cfg, wire_dtype=...)`` keeps
    working while callers migrate to
    ``replace(cfg, policy=cfg.policy.replace(...))``.
    """

    mode: str = "mpi_sgd"       # "mpi_sgd" | "mpi_esgd"
    num_clients: int = 1        # C; >1 requires a "pod" axis of that size
    esgd_alpha: float = 0.5
    esgd_interval: int = 64
    # -- deprecated flat mirrors of ``policy`` (one release) ---------------
    # which collective implements the intra-client tensor allreduce:
    # "psum" (XLA-native), "ring"/"multi_ring"/"tree" (paper-faithful), or
    # "scatter_gather" (the separable halves the fused step runs between)
    allreduce_method: str = "psum"
    num_rings: int = 2
    # sharded fused step (default for mpi_sgd): pack grads into the
    # persistent FlatBuffer, ring reduce-scatter, fused momentum-SGD Pallas
    # kernel on the local 1/p shard (momentum stays sharded), allgather the
    # updated params. Collective-explicit drivers only — the GSPMD path
    # (make_train_step with a mesh) keeps per-leaf updates.
    fused_update: bool = True
    # flat elastic leg (default for mpi_esgd): the exchange packs params
    # and centers through the FlatBuffer and runs ONE fused Pallas kernel
    # (eqs. 2+3 in one HBM pass) instead of O(num_leaves) tree.maps; the
    # shard_map driver additionally ring reduce-scatters the packed
    # differences over the pod axis. False = per-leaf reference. Like
    # fused_update, collective-explicit (no-mesh) drivers only.
    flat_exchange: bool = True
    # split the flat buffer into ceil(bytes/bucket_bytes) independent ring
    # schedules (composes with num_rings; see flatbuf.effective_rings)
    bucket_bytes: Optional[int] = None
    # low-precision wire protocol on the explicit ring hops (gradient
    # reduce-scatter / param allgather / elastic diff+center legs):
    # None/"f32" full precision, "bf16" cast per hop (0.5x bytes), "int8"
    # codes + per-128-bucket f32 scales per hop (~0.258x bytes). Requires
    # a ring-family allreduce_method — psum/tree hops are XLA-native or
    # full-buffer patterns the codec cannot ride.
    wire_dtype: Optional[str] = None
    fsdp: bool = False  # ZeRO-3: params/opt-state also sharded over 'data'
    # backward-overlapped bucketed reduce-scatter: the grad fn stages
    # backprop (Model.overlap_stages) and issues each schedule bucket's
    # ring reduce-scatter leg as soon as that bucket's grads exist —
    # while earlier layers are still differentiating — so the wire leg
    # hides behind backward compute; the fused update then consumes the
    # bucket-major shard and runs ONE trailing allgather. Requires the
    # fused flat path + a ring-family method (see validate).
    overlap: bool = False
    overlap_buckets: int = 4  # schedule buckets == backward stages
    # internal bookkeeping: the policy the mirrors above were backfilled
    # from. ``dataclasses.replace`` passes it back, letting __post_init__
    # tell a mirror the caller actually changed from one merely restating
    # the previous policy. Never pass it yourself.
    policy_src: Optional[CollectivePolicy] = dataclasses.field(
        default=None, repr=False, compare=False)
    # -- the ONE policy field (canonical; mirrors derive from it) ----------
    policy: InitVar[Optional[CollectivePolicy]] = None

    def __post_init__(self, policy: Optional[CollectivePolicy]) -> None:
        flat = {
            "method": self.allreduce_method, "num_rings": self.num_rings,
            "bucket_bytes": self.bucket_bytes, "wire_dtype": self.wire_dtype,
            "overlap": self.overlap, "overlap_buckets": self.overlap_buckets,
        }
        # only knobs the caller moved off the legacy defaults (or, on a
        # replace() round-trip, off the previous policy) count as "passed"
        flat = filter_mirrors(
            flat, defaults={k: getattr(_SYNC_BASE, k) for k in flat},
            prior=self.policy_src)
        pol = resolve_policy(policy, flat, base=_SYNC_BASE,
                             where="SyncConfig")
        object.__setattr__(self, "policy", pol)
        object.__setattr__(self, "policy_src", pol)
        object.__setattr__(self, "allreduce_method", pol.method)
        object.__setattr__(self, "num_rings", pol.num_rings)
        object.__setattr__(self, "bucket_bytes", pol.bucket_bytes)
        object.__setattr__(self, "wire_dtype", pol.wire_dtype)
        object.__setattr__(self, "overlap", pol.overlap)
        object.__setattr__(self, "overlap_buckets", pol.overlap_buckets)

    def validate(self, mesh: Optional[Mesh] = None) -> None:
        """Check the config against a mesh BEFORE any step is traced.

        Called by ``launch.train.make_train_step`` /
        ``launch.shard_driver`` so a client-count/mesh mismatch fails
        here with an actionable message instead of surfacing deep inside
        shard_map as an opaque reshape/shape error. ``mesh=None`` (the
        single-process vmap-emulation drivers) skips the axis checks.
        """
        if self.mode not in ("mpi_sgd", "mpi_esgd"):
            raise ValueError(f"lowerable modes are mpi_sgd/mpi_esgd, got {self.mode}")
        # the policy-level guards (method membership, wire ⇒ ring-family,
        # overlap ⇒ ring + single-ring + no byte-bucketing) live in ONE
        # place now; only the layer-specific checks remain below
        self.policy.validate(where="SyncConfig")
        if self.overlap:
            if not self.fused_update:
                raise ValueError(
                    "overlap=True rides the fused flat path — the staged "
                    "grad fn hands the update ONE bucket-major shard "
                    "buffer, which only the fused Pallas kernel consumes; "
                    "set fused_update=True (per-leaf updates would need "
                    "the full gradient pytree the overlapped step never "
                    "materializes)")
            if self.mode != "mpi_sgd":
                raise ValueError(
                    f"overlap=True is the mpi_sgd (C=1) gradient leg — "
                    f"mode={self.mode!r} runs per-client local updates "
                    "(p=1 geometry, no ring leg to hide); drop overlap "
                    "or use mode='mpi_sgd'")
            if self.fsdp:
                raise ValueError(
                    "overlap=True assumes replicated params (the staged "
                    "grad fn re-stages the full param tree per device); "
                    "fsdp=True shards them over 'data' — pick one")
            if mesh is not None:
                raise ValueError(
                    "overlap=True is collective-explicit (the per-bucket "
                    "ppermute legs are issued by the traced backward, "
                    "vmap emulation or shard_map worker programs) — with "
                    "an ambient mesh GSPMD owns the gradient collectives "
                    "and would not interleave them; drop the mesh or "
                    "overlap")
        if mesh is None or self.num_clients <= 1:
            return
        C = self.num_clients
        if "pod" not in mesh.shape:
            raise ValueError(
                f"SyncConfig(num_clients={C}) needs a 'pod' mesh axis to "
                f"shard the client dim over, but the mesh only has axes "
                f"{dict(mesh.shape)} — build it with a pod axis of size "
                f"{C}, e.g. compat.make_mesh(({C}, D), ('pod', 'data')) "
                "or launch.mesh.make_production_mesh(multi_pod=True); "
                "without it the client dim cannot be laid out and the "
                "failure would otherwise surface inside shard_map as a "
                "shape error")
        if mesh.shape["pod"] != C:
            raise ValueError(
                f"SyncConfig(num_clients={C}) != 'pod' axis size "
                f"{mesh.shape['pod']} (mesh axes {dict(mesh.shape)}) — "
                "one client per pod: set num_clients to the pod axis "
                "size or rebuild the mesh with a pod axis of size "
                f"{C}")


def clientize(params: Any, num_clients: int) -> Any:
    """Give every client its own replica: leading dim C on every leaf."""
    if num_clients <= 1:
        return params
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_clients,) + p.shape).copy(),
        params,
    )


def clientize_specs(specs: Any, num_clients: int) -> Any:
    """Prepend the 'pod' axis to every PartitionSpec."""
    if num_clients <= 1:
        return specs
    return jax.tree.map(
        lambda s: P("pod", *tuple(s)), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def declientize(params: Any, num_clients: int) -> Any:
    """Consensus model: mean over the client dim (end of training)."""
    if num_clients <= 1:
        return params
    return jax.tree.map(lambda p: jnp.mean(p, axis=0), params)


def grad_sync_axes(mesh: Mesh, num_clients: int) -> tuple[str, ...]:
    """Axes a client's gradient allreduce runs over."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if num_clients > 1:
        axes = tuple(a for a in axes if a != "pod")
    return axes


def should_elastic_sync(step: jax.Array, interval: int) -> jax.Array:
    return (step % interval) == 0


def pod_mean(tree: Any) -> Any:
    """Cross-client average over the leading client dim (the ESGD server
    interaction, lowered as an all-reduce over the 'pod' axis)."""
    return jax.tree.map(lambda p: jnp.mean(p, axis=0, keepdims=True), tree)
