"""Client ↔ mesh mapping: the paper's `#clients` knob at production scale.

At scale a *client* is a mesh slice: the `data` axis of one pod is one
MPI communicator; the `pod` axis is the PS tier. Params optionally carry a
leading client dim C (one replica per client, sharded over `pod`), so:

  C = 1            pure-MPI mode: one communicator spanning all data axes,
                   gradients fully allreduced every step (mpi-SGD,
                   #servers = 0, pushpull = tensor allreduce)
  C = #pods        one client per pod: gradient sync inside the pod only;
                   cross-pod communication is the lazy elastic exchange
                   every INTERVAL steps (mpi-ESGD)

This file holds the *logic* (pure pytree/spec transforms); launch/train.py
binds it to the real mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class SyncConfig:
    """Production gradient-sync mode (the lowerable subset of MODES)."""

    mode: str = "mpi_sgd"       # "mpi_sgd" | "mpi_esgd"
    num_clients: int = 1        # C; >1 requires a "pod" axis of that size
    esgd_alpha: float = 0.5
    esgd_interval: int = 64
    # which collective implements the intra-client tensor allreduce:
    # "psum" (XLA-native), "ring"/"multi_ring"/"tree" (paper-faithful), or
    # "scatter_gather" (the separable halves the fused step runs between)
    allreduce_method: str = "psum"
    num_rings: int = 2
    # sharded fused step (default for mpi_sgd): pack grads into the
    # persistent FlatBuffer, ring reduce-scatter, fused momentum-SGD Pallas
    # kernel on the local 1/p shard (momentum stays sharded), allgather the
    # updated params. Collective-explicit drivers only — the GSPMD path
    # (make_train_step with a mesh) keeps per-leaf updates.
    fused_update: bool = True
    # flat elastic leg (default for mpi_esgd): the exchange packs params
    # and centers through the FlatBuffer and runs ONE fused Pallas kernel
    # (eqs. 2+3 in one HBM pass) instead of O(num_leaves) tree.maps; the
    # shard_map driver additionally ring reduce-scatters the packed
    # differences over the pod axis. False = per-leaf reference. Like
    # fused_update, collective-explicit (no-mesh) drivers only.
    flat_exchange: bool = True
    # split the flat buffer into ceil(bytes/bucket_bytes) independent ring
    # schedules (composes with num_rings; see flatbuf.effective_rings)
    bucket_bytes: Optional[int] = None
    # low-precision wire protocol on the explicit ring hops (gradient
    # reduce-scatter / param allgather / elastic diff+center legs):
    # None/"f32" full precision, "bf16" cast per hop (0.5x bytes), "int8"
    # codes + per-128-bucket f32 scales per hop (~0.258x bytes). Requires
    # a ring-family allreduce_method — psum/tree hops are XLA-native or
    # full-buffer patterns the codec cannot ride.
    wire_dtype: Optional[str] = None
    fsdp: bool = False  # ZeRO-3: params/opt-state also sharded over 'data'
    # backward-overlapped bucketed reduce-scatter: the grad fn stages
    # backprop (Model.overlap_stages) and issues each schedule bucket's
    # ring reduce-scatter leg as soon as that bucket's grads exist —
    # while earlier layers are still differentiating — so the wire leg
    # hides behind backward compute; the fused update then consumes the
    # bucket-major shard and runs ONE trailing allgather. Requires the
    # fused flat path + a ring-family method (see validate).
    overlap: bool = False
    overlap_buckets: int = 4  # schedule buckets == backward stages

    def validate(self, mesh: Optional[Mesh] = None) -> None:
        """Check the config against a mesh BEFORE any step is traced.

        Called by ``launch.train.make_train_step`` /
        ``launch.shard_driver`` so a client-count/mesh mismatch fails
        here with an actionable message instead of surfacing deep inside
        shard_map as an opaque reshape/shape error. ``mesh=None`` (the
        single-process vmap-emulation drivers) skips the axis checks.
        """
        if self.mode not in ("mpi_sgd", "mpi_esgd"):
            raise ValueError(f"lowerable modes are mpi_sgd/mpi_esgd, got {self.mode}")
        from repro.core.collectives import _METHODS

        if self.allreduce_method not in _METHODS:
            raise ValueError(
                f"allreduce_method={self.allreduce_method!r} is not one of "
                f"{_METHODS} — SyncConfig is the construction recipe for "
                "core.comm.Communicator, which only dispatches these")
        from repro.core.collectives import (
            RING_METHODS,
            check_wire_dtype,
        )

        wire = check_wire_dtype(self.wire_dtype, where="SyncConfig")
        if wire is not None and self.allreduce_method not in RING_METHODS:
            raise ValueError(
                f"wire_dtype={self.wire_dtype!r} rides the explicit ring "
                f"hops, but allreduce_method={self.allreduce_method!r} is "
                f"not one of {RING_METHODS} — set e.g. "
                "allreduce_method='ring' (psum is XLA-native and tree "
                "moves full buffers; neither carries the int8/bf16 codec)")
        if self.overlap:
            if self.allreduce_method not in RING_METHODS:
                raise ValueError(
                    f"overlap=True issues per-bucket ring reduce-scatter "
                    f"legs mid-backward, but allreduce_method="
                    f"{self.allreduce_method!r} is not one of "
                    f"{RING_METHODS} — set e.g. allreduce_method='ring' "
                    "(psum is one XLA-chosen collective and tree moves "
                    "full buffers; neither can be split at the schedule-"
                    "bucket boundaries the backward stages produce)")
            if not self.fused_update:
                raise ValueError(
                    "overlap=True rides the fused flat path — the staged "
                    "grad fn hands the update ONE bucket-major shard "
                    "buffer, which only the fused Pallas kernel consumes; "
                    "set fused_update=True (per-leaf updates would need "
                    "the full gradient pytree the overlapped step never "
                    "materializes)")
            if self.mode != "mpi_sgd":
                raise ValueError(
                    f"overlap=True is the mpi_sgd (C=1) gradient leg — "
                    f"mode={self.mode!r} runs per-client local updates "
                    "(p=1 geometry, no ring leg to hide); drop overlap "
                    "or use mode='mpi_sgd'")
            if self.overlap_buckets < 1:
                raise ValueError(
                    f"overlap_buckets={self.overlap_buckets} — need >= 1 "
                    "(1 = single degenerate bucket, the non-overlapped "
                    "schedule)")
            if self.bucket_bytes:
                raise ValueError(
                    "overlap=True derives its bucket partition from the "
                    "backward stages (overlap_buckets), not from byte "
                    "counts — bucket_bytes splits one monolithic leg into "
                    "ring schedules and would fight the stage boundaries; "
                    "set bucket_bytes=None")
            if self.num_rings > 1:
                raise ValueError(
                    f"overlap=True runs each schedule bucket as its own "
                    f"single-ring leg — the buckets ARE the independent "
                    f"schedules, so num_rings={self.num_rings} has no "
                    "slot to ride; set num_rings=1 (TrainSettings."
                    "sync_config does this automatically)")
            if self.fsdp:
                raise ValueError(
                    "overlap=True assumes replicated params (the staged "
                    "grad fn re-stages the full param tree per device); "
                    "fsdp=True shards them over 'data' — pick one")
            if mesh is not None:
                raise ValueError(
                    "overlap=True is collective-explicit (the per-bucket "
                    "ppermute legs are issued by the traced backward, "
                    "vmap emulation or shard_map worker programs) — with "
                    "an ambient mesh GSPMD owns the gradient collectives "
                    "and would not interleave them; drop the mesh or "
                    "overlap")
        if mesh is None or self.num_clients <= 1:
            return
        C = self.num_clients
        if "pod" not in mesh.shape:
            raise ValueError(
                f"SyncConfig(num_clients={C}) needs a 'pod' mesh axis to "
                f"shard the client dim over, but the mesh only has axes "
                f"{dict(mesh.shape)} — build it with a pod axis of size "
                f"{C}, e.g. compat.make_mesh(({C}, D), ('pod', 'data')) "
                "or launch.mesh.make_production_mesh(multi_pod=True); "
                "without it the client dim cannot be laid out and the "
                "failure would otherwise surface inside shard_map as a "
                "shape error")
        if mesh.shape["pod"] != C:
            raise ValueError(
                f"SyncConfig(num_clients={C}) != 'pod' axis size "
                f"{mesh.shape['pod']} (mesh axes {dict(mesh.shape)}) — "
                "one client per pod: set num_clients to the pod axis "
                "size or rebuild the mesh with a pod axis of size "
                f"{C}")


def clientize(params: Any, num_clients: int) -> Any:
    """Give every client its own replica: leading dim C on every leaf."""
    if num_clients <= 1:
        return params
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_clients,) + p.shape).copy(),
        params,
    )


def clientize_specs(specs: Any, num_clients: int) -> Any:
    """Prepend the 'pod' axis to every PartitionSpec."""
    if num_clients <= 1:
        return specs
    return jax.tree.map(
        lambda s: P("pod", *tuple(s)), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def declientize(params: Any, num_clients: int) -> Any:
    """Consensus model: mean over the client dim (end of training)."""
    if num_clients <= 1:
        return params
    return jax.tree.map(lambda p: jnp.mean(p, axis=0), params)


def grad_sync_axes(mesh: Mesh, num_clients: int) -> tuple[str, ...]:
    """Axes a client's gradient allreduce runs over."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if num_clients > 1:
        axes = tuple(a for a in axes if a != "pod")
    return axes


def should_elastic_sync(step: jax.Array, interval: int) -> jax.Array:
    return (step % interval) == 0


def pod_mean(tree: Any) -> Any:
    """Cross-client average over the leading client dim (the ESGD server
    interaction, lowered as an all-reduce over the 'pod' axis)."""
    return jax.tree.map(lambda p: jnp.mean(p, axis=0, keepdims=True), tree)
