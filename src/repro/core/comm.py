"""Communicator: the paper's MPI-groups-in-KVStore model as an object.

MXNET-MPI's central design is an API, not an algorithm: MPI communicators
embedded as *groups* inside the PS task model (§3-4), so ``kv.pushpull``
runs an MPI collective within a group while the PS tier spans groups.
This module is that object for the JAX reproduction. A ``Communicator``
owns

  * its **group**: a tuple of named mesh/vmap axes (``()`` is the
    trivial size-1 group — MPI_COMM_SELF),
  * its **collective policy**: bucket algorithm (``method``), ring count,
    byte-sized bucketing, and the low-precision wire protocol
    (``wire_dtype``: f32 / bf16 / int8 ring hops) — what used to travel
    as loose ``allreduce_method`` / ``num_rings`` / ``bucket_bytes``
    knobs,
  * its **backend**: the named-axis substrate. The same
    ``lax.ppermute`` programs run inside ``shard_map`` on a real mesh
    AND under ``jax.vmap(..., axis_name=...)`` emulation, so the backend
    is fully determined by the group: ``()`` short-circuits every
    collective to the identity ("trivial"); otherwise the collective is
    traced against the named axes ("named_axis") and the mapping
    machinery (shard_map vs vmap) supplies the devices.

``Communicator.world(...)`` builds the top-level group over a mesh (or
an emulated geometry) and ``world.split("pod" | "data")`` carves
sub-communicators the way ``MPI_Comm_split`` carves the paper's groups:
``split("data")`` is the intra-pod gradient group (one per pod — the
color is the pod rank), ``split("pod")`` is the cross-pod PS tier.

Multi-axis groups compose collectives hierarchically: a reduce-scatter
over ``("pod", "data")`` ring-reduce-scatters over ``pod`` first, then
over ``data`` on the shard — (p-1)/p·n total wire bytes, exactly the
single-axis geometry, with the same final shard size — so one
``Communicator`` spanning both axes IS the C=1 pure-MPI mode on a 2-axis
mesh.

Everything below the config layer speaks ``Communicator``. The
collective policy itself is one value type — ``CollectivePolicy`` —
that Communicator, SyncConfig, TrainSettings, AlgoConfig and JobSpec
all carry as a single field: one definition of validity
(``CollectivePolicy.validate``), one inheritance path (``replace`` on
axes/sizes keeps the policy, so split/complement/local/resized inherit
it for free). The old flat kwargs (``method=`` / ``num_rings=`` /
``bucket_bytes=`` / ``wire_dtype=`` / ``overlap=``) survive for one
release behind the single ``resolve_policy`` shim. Bare ``axis_name=``
string signatures on the old entry points were removed — build the
group with ``Communicator.from_axis_name`` and pass ``comm=``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import flatbuf
from repro.core.compat import axis_size as _axis_size


def _axis_name_removed(where: str) -> None:
    raise ValueError(
        f"{where}: the deprecated axis_name= string form was removed — "
        "build the group explicitly with Communicator.from_axis_name("
        "axis_name) (or Communicator.world(axes, sizes).split(...)) and "
        "pass comm= instead")


#: the one set of policy knob names, in canonical order — the flat-kwarg
#: shim and the config-layer mirrors both key off this tuple
_POLICY_FIELDS = ("method", "num_rings", "bucket_bytes", "wire_dtype",
                  "overlap", "overlap_buckets")


@dataclass(frozen=True)
class CollectivePolicy:
    """One point in the collective-policy space, as a value.

    Every layer that used to carry the five loose knobs — Communicator,
    SyncConfig, TrainSettings, AlgoConfig, JobSpec — carries ONE of
    these instead. ``validate()`` is the single definition of which
    points are legal (the autotuner's pruner calls it too), and because
    the policy rides ``Communicator.policy`` as one field, every
    ``split``/``complement``/``local``/``resized`` inherits it through
    a single ``dataclasses.replace`` path.

    Frozen and hashable: Communicator is a jit static argument
    (``_emulated_reduce``), so the policy must hash with it.
    """

    method: str = "ring"
    num_rings: int = 1
    bucket_bytes: Optional[int] = None
    # low-precision wire protocol: None/"f32" (full precision), "bf16"
    # (cast per hop), "int8" (codes + per-bucket scales per hop)
    wire_dtype: Optional[str] = None
    # backward-overlapped bucketed reduce-scatter (PR 7): schedule the
    # gradient leg per layer-keyed bucket inside the backward DAG
    overlap: bool = False
    overlap_buckets: int = 4

    @property
    def wire(self) -> Optional[str]:
        """Normalized wire dtype (None for the full-precision "f32")."""
        from repro.core import collectives as C

        return C.check_wire_dtype(self.wire_dtype, where="CollectivePolicy")

    def replace(self, **kw) -> "CollectivePolicy":
        return replace(self, **kw)

    def validate(self, *, where: str = "CollectivePolicy"
                 ) -> "CollectivePolicy":
        """THE definition of a valid policy point. Every config layer's
        ``validate`` delegates the policy-level checks here, and the
        autotuner prunes its search space by calling this per candidate."""
        from repro.core import collectives as C

        if self.method not in C._METHODS:
            raise ValueError(
                f"{where}: allreduce_method (policy.method) must be one "
                f"of {C._METHODS}, got {self.method!r}")
        wire = C.check_wire_dtype(self.wire_dtype, where=where)
        if wire is not None and self.method not in C.RING_METHODS:
            raise ValueError(
                f"{where}: wire_dtype={self.wire_dtype!r} rides the "
                f"explicit ring hops of {C.RING_METHODS}; "
                f"method={self.method!r} has no wire to quantize")
        if self.num_rings < 1:
            raise ValueError(
                f"{where}: num_rings must be >= 1, got {self.num_rings}")
        if self.bucket_bytes is not None and self.bucket_bytes <= 0:
            raise ValueError(
                f"{where}: bucket_bytes must be positive, "
                f"got {self.bucket_bytes}")
        if self.overlap_buckets < 1:
            raise ValueError(
                f"{where}: overlap_buckets must be >= 1, "
                f"got {self.overlap_buckets}")
        if self.overlap:
            if self.method not in C.RING_METHODS:
                raise ValueError(
                    f"{where}: overlap schedules per-bucket ring "
                    f"reduce-scatters — method must be one of "
                    f"{C.RING_METHODS}, got {self.method!r}")
            if self.bucket_bytes is not None:
                raise ValueError(
                    f"{where}: overlap buckets come from the layer-keyed "
                    "schedule — bucket_bytes does not compose with "
                    "overlap (byte-budget bucketing is a ROADMAP item)")
            if self.num_rings != 1:
                raise ValueError(
                    f"{where}: overlap already pipelines the buckets — "
                    f"num_rings must be 1, got {self.num_rings}")
        return self

    def require_plain_wire(self, what: str) -> None:
        """Raise if this policy quantizes the wire but the dispatched
        collective has no explicit ring hops to carry the codec."""
        from repro.core import collectives as C

        if self.wire is not None:
            raise ValueError(
                f"wire_dtype={self.wire_dtype!r} only rides the explicit "
                f"ring hops (methods {C.RING_METHODS}), "
                f"but this group dispatches {what} — drop the wire_dtype "
                "or pick a ring-family method")

    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in _POLICY_FIELDS}

    @classmethod
    def from_dict(cls, d: dict) -> "CollectivePolicy":
        unknown = set(d) - set(_POLICY_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown CollectivePolicy fields {sorted(unknown)}; "
                f"valid: {_POLICY_FIELDS}")
        return cls(**d)


def _norm_flat(key: str, value):
    # the config layers' string spelling of "no wire protocol"
    if key == "wire_dtype" and value == "f32":
        return None
    # JobSpec's flag spelling of "no byte-bucketing"
    if key == "bucket_bytes" and value == 0:
        return None
    return value


def filter_mirrors(flat: dict, *, defaults: dict,
                   prior: Optional["CollectivePolicy"]) -> dict:
    """Drop mirror-field values that are NOT caller input.

    The config layers keep the old flat knobs as real fields mirroring
    ``policy``, so ``dataclasses.replace`` re-inits with every mirror
    populated. ``prior`` is the policy the mirrors were backfilled from
    (the layer's ``policy_src`` bookkeeping field, which ``replace``
    passes back): entries restating it are derived state — only entries
    the caller moved off it are policy input. On fresh construction
    (``prior`` is None) the reference point is the layer's field
    ``defaults`` instead."""
    ref = ({k: getattr(prior, k) for k in flat} if prior is not None
           else defaults)
    return {k: v for k, v in flat.items()
            if _norm_flat(k, v) != _norm_flat(k, ref[k])}


def resolve_policy(policy: Optional[CollectivePolicy], flat: dict, *,
                   base: Optional[CollectivePolicy] = None,
                   where: str = "CollectivePolicy") -> CollectivePolicy:
    """THE flat-kwargs deprecation shim — the one place the old loose
    knobs (``method=`` / ``num_rings=`` / ``bucket_bytes=`` /
    ``wire_dtype=`` / ``overlap=`` / ``overlap_buckets=``) still turn
    into a policy, for one release.

    ``flat`` holds the knobs a caller passed explicitly. Entries that
    merely restate the resolved policy (``base`` overridden by
    ``policy``) pass silently — that keeps mirror fields and
    ``dataclasses.replace`` round-trips quiet. Entries that CHANGE the
    policy emit one ``DeprecationWarning`` naming ``CollectivePolicy``.
    """
    unknown = set(flat) - set(_POLICY_FIELDS)
    if unknown:
        raise TypeError(
            f"{where}: unknown policy kwargs {sorted(unknown)}; "
            f"valid: {_POLICY_FIELDS} (or policy=CollectivePolicy(...))")
    pol = policy if policy is not None else (
        base if base is not None else CollectivePolicy())
    changed = {k: _norm_flat(k, v) for k, v in flat.items()
               if _norm_flat(k, v) != _norm_flat(k, getattr(pol, k))}
    if not changed:
        return pol
    warnings.warn(
        f"{where}: flat policy kwargs ({', '.join(sorted(changed))}) are "
        "deprecated — pass policy=repro.core.comm.CollectivePolicy(...) "
        "(one field, one validate()) instead",
        DeprecationWarning, stacklevel=3)
    return replace(pol, **changed)


@dataclass(frozen=True)
class Communicator:
    """One MPI-style group + its collective policy.

    ``axes`` are the named axes the group spans (order = hierarchy order
    for nested collectives: ``axes[0]`` is the outermost level).
    ``sizes`` are the static axis sizes when construction-site geometry
    is known (a mesh, an emulated ``p``); ``None`` means "resolve at
    trace time via ``lax.axis_size``" — the adapter path for legacy
    axis-name callers.
    """

    axes: tuple[str, ...] = ()
    sizes: Optional[tuple[int, ...]] = None
    # the whole collective policy as ONE field — splits/complements/
    # locals/resizes inherit it through replace(axes=..., sizes=...), and
    # every level of a hierarchical collective quantizes its own hops
    policy: CollectivePolicy = CollectivePolicy()

    # -- policy views (the old flat fields, read-only) ----------------------
    @property
    def method(self) -> str:
        return self.policy.method

    @property
    def num_rings(self) -> int:
        return self.policy.num_rings

    @property
    def bucket_bytes(self) -> Optional[int]:
        return self.policy.bucket_bytes

    @property
    def wire_dtype(self) -> Optional[str]:
        return self.policy.wire_dtype

    # -- construction -------------------------------------------------------
    @classmethod
    def world(cls, axes, sizes=None, *, mesh=None,
              policy: Optional[CollectivePolicy] = None,
              **flat) -> "Communicator":
        """The top-level group. Pass explicit ``sizes`` (emulation) or a
        ``mesh`` whose ``mesh.shape`` carries them; the collective
        policy rides ``policy=`` (flat knobs shim through
        ``resolve_policy`` for one release)."""
        axes = tuple(axes)
        if mesh is not None:
            missing = [a for a in axes if a not in mesh.shape]
            if missing:
                raise ValueError(
                    f"mesh axes {tuple(mesh.shape)} lack {missing}; build "
                    f"the mesh with the communicator's axes {axes}")
            sizes = tuple(mesh.shape[a] for a in axes)
        elif sizes is not None:
            sizes = tuple(int(s) for s in sizes)
            if len(sizes) != len(axes):
                raise ValueError(f"{len(axes)} axes but {len(sizes)} sizes")
        pol = resolve_policy(policy, flat, where="Communicator.world")
        return cls(axes=axes, sizes=sizes, policy=pol)

    @classmethod
    def from_axis_name(cls, axis_name, *,
                       policy: Optional[CollectivePolicy] = None,
                       **flat) -> "Communicator":
        """Build a group from a bare axis name: ``None`` is the trivial
        group, a string (or tuple of strings) is a group with
        trace-time-resolved sizes. This is the named replacement for the
        removed ``axis_name=`` string signatures."""
        pol = resolve_policy(policy, flat, where="Communicator.from_axis_name")
        if axis_name is None:
            return cls(axes=(), sizes=(), policy=pol)
        axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
        return cls(axes=axes, sizes=None, policy=pol)

    def split(self, *axes: str) -> "Communicator":
        """Carve the sub-communicator spanning ``axes`` — the
        ``MPI_Comm_split`` of the paper's group model: ``split("data")``
        yields the intra-pod gradient group (the implicit color is each
        device's rank along every *other* axis), ``split("pod")`` the
        cross-pod PS-tier group. Policy is inherited."""
        unknown = [a for a in axes if a not in self.axes]
        if unknown:
            raise ValueError(
                f"cannot split {unknown} out of communicator over "
                f"{self.axes}; valid axes: {self.axes}")
        keep = tuple(a for a in self.axes if a in axes)
        sizes = (None if self.sizes is None
                 else tuple(s for a, s in zip(self.axes, self.sizes)
                            if a in axes))
        return replace(self, axes=keep, sizes=sizes)

    def complement(self, *axes: str) -> "Communicator":
        """The sub-communicator over every axis NOT named (the other half
        of a split): ``world.complement("pod") == world.split(*data_axes)``."""
        keep = tuple(a for a in self.axes if a not in axes)
        return self.split(*keep)

    def local(self) -> "Communicator":
        """The trivial (size-1, MPI_COMM_SELF) group with this policy."""
        return replace(self, axes=(), sizes=())

    def resized(self, size: int, axis: Optional[str] = None) -> "Communicator":
        """The SAME group with one axis re-sized — the re-split an
        elastic membership change performs (core/membership.py): a
        member failed/left/joined, so the axis it lived on shrinks or
        grows while the policy (method / rings / buckets / wire) is
        inherited unchanged. Needs static sizes (there is nothing to
        re-split on the trace-time-resolved adapter path); multi-axis
        groups must name which ``axis`` the membership rides."""
        if self.is_trivial:
            raise ValueError("cannot resize the trivial group")
        if self.sizes is None:
            raise ValueError(
                "resized() needs static sizes — build the communicator "
                "with Communicator.world(axes, sizes)")
        if size < 1:
            raise ValueError(f"resized group must keep >= 1 member, "
                             f"got {size}")
        if axis is None:
            if len(self.axes) > 1:
                raise ValueError(
                    f"communicator spans {self.axes}; name the membership "
                    "axis: resized(size, axis=...)")
            axis = self.axes[0]
        if axis not in self.axes:
            raise ValueError(f"no axis {axis!r} in {self.axes}")
        sizes = tuple(int(size) if a == axis else s
                      for a, s in zip(self.axes, self.sizes))
        return replace(self, sizes=sizes)

    def with_policy(self, policy: Optional[CollectivePolicy] = None,
                    **kw) -> "Communicator":
        """Same group, new policy: a whole ``CollectivePolicy`` or field
        overrides (canonical sugar, e.g. ``with_policy(wire_dtype="int8")``)."""
        if policy is not None:
            if kw:
                raise TypeError(
                    "with_policy: pass policy= or field overrides, not both")
            return replace(self, policy=policy)
        return replace(self, policy=self.policy.replace(**kw))

    # -- geometry -----------------------------------------------------------
    @property
    def is_trivial(self) -> bool:
        return not self.axes

    @property
    def backend(self) -> str:
        """"trivial" (size-1 short circuit) or "named_axis" (the shared
        shard_map / vmap-emulation substrate)."""
        return "trivial" if self.is_trivial else "named_axis"

    @property
    def static_size(self) -> Optional[int]:
        """Product of axis sizes when statically known, else None."""
        if self.is_trivial:
            return 1
        if self.sizes is None:
            return None
        p = 1
        for s in self.sizes:
            p *= s
        return p

    def resolve_size(self) -> int:
        """Group size. Static when known; otherwise resolved from the
        ambient named-axis context (so it must run under the map)."""
        if self.static_size is not None:
            return self.static_size
        p = 1
        for a in self.axes:
            p *= _axis_size(a)
        return p

    def _axis_sizes(self) -> tuple[int, ...]:
        if self.sizes is not None:
            return self.sizes
        return tuple(_axis_size(a) for a in self.axes)

    @property
    def wire(self) -> Optional[str]:
        """Normalized wire dtype (None for the full-precision "f32")."""
        from repro.core import collectives as C

        return C.check_wire_dtype(self.policy.wire_dtype, where="Communicator")

    def _require_plain_wire(self, what: str) -> None:
        self.policy.require_plain_wire(what)

    def rings_for(self, nbytes: int) -> int:
        """The policy's effective ring count for an ``nbytes`` buffer
        (``num_rings`` composed with ``bucket_bytes`` chunking)."""
        return flatbuf.effective_rings(nbytes, self.num_rings,
                                       self.bucket_bytes)

    def shard_geometry(self, n: int, num_rings: Optional[int] = None,
                       *, itemsize: int = 4) -> tuple[int, int]:
        """(per-device shard length, padded total) for a length-``n``
        buffer sharded over the whole group, under the full ring policy
        (``rings_for`` — so it agrees with what ``reduce_scatter`` /
        ``optstate_shard_init`` lay out when ``bucket_bytes`` is set)."""
        p = self.resolve_size()
        nr = (self.rings_for(n * itemsize) if num_rings is None
              else num_rings)
        _, total = flatbuf.shard_geometry(n, p, nr)
        return total // p, total

    # -- collectives (run inside shard_map / vmap named-axis context) -------
    def allreduce(self, x: jax.Array, *, mean: bool = False) -> jax.Array:
        """Policy-dispatched allreduce (sum) over the whole group.

        Multi-axis ring-family groups run the hierarchical
        reduce-scatter + allgather composition, which telescopes to
        exactly the 1-axis ring's wire bytes (a per-axis allreduce loop
        would cost Σ 2(p_k-1)/p_k·n instead of 2(Πp_k-1)/(Πp_k)·n);
        ``tree`` — the PS push/pull baseline pattern — reduces one axis
        at a time. The FULL ring policy applies: ``bucket_bytes``
        composes with ``num_rings`` exactly like on the sharded legs."""
        from repro.core import collectives as C

        out = x
        if not self.axes:
            pass
        elif self.method == "psum":
            self._require_plain_wire("XLA's native psum")
            out = lax.psum(out, self.axes)
        elif self.method == "tree":
            self._require_plain_wire("full-buffer binomial-tree hops")
            nr = self.rings_for(x.size * x.dtype.itemsize)
            for a in self.axes:
                out = C.allreduce(out, a, self.method, num_rings=nr)
        elif len(self.axes) == 1 and self.wire is None:
            nr = self.rings_for(x.size * x.dtype.itemsize)
            for a in self.axes:
                out = C.allreduce(out, a, self.method, num_rings=nr)
        else:
            # hierarchical RS + AG composition — also the 1-axis form of
            # every quantized ring-family allreduce (the halves carry the
            # wire protocol; an overlapped in-place quantized ring would
            # re-encode the same partials for no byte win)
            if self.method == "per_leaf":
                self._require_plain_wire("the per-leaf baseline")
            shape, n = x.shape, x.size
            nr = self.rings_for(x.size * x.dtype.itemsize)
            _, total = flatbuf.shard_geometry(n, self.resolve_size(), nr)
            flat = jnp.pad(x.reshape(-1), (0, total - n))
            shard = self.reduce_scatter(flat, num_rings=nr)
            out = self.allgather(shard, num_rings=nr)[:n].reshape(shape)
            out = out.astype(x.dtype)
        if mean:
            out = out / self.resolve_size()
        return out

    def pmean(self, x: jax.Array) -> jax.Array:
        """Mean over the group (metrics leg): native psum — cheap scalar
        traffic, not part of any byte-accounted data leg."""
        if self.is_trivial:
            return x
        return lax.pmean(x, self.axes)

    def reduce_scatter(self, buf: jax.Array, *,
                       num_rings: Optional[int] = None) -> jax.Array:
        """Hierarchical ring reduce-scatter of a flat buffer: level k
        reduce-scatters level k-1's shard over ``axes[k]``. The final
        shard is 1/(prod sizes) of the padded buffer and the total wire
        bytes telescope to the single-axis (p-1)/p·n. With no explicit
        ``num_rings`` the FULL ring policy applies (``rings_for`` of the
        buffer — so the layout agrees with ``shard_geometry`` even when
        ``bucket_bytes`` is set)."""
        from repro.core import collectives as C

        out = buf.reshape(-1)
        nr = (self.rings_for(out.size * out.dtype.itemsize)
              if num_rings is None else num_rings)
        for a in self.axes:
            out = C.ring_reduce_scatter(out, a, num_rings=nr,
                                        wire_dtype=self.wire)
        return out

    def allgather(self, shard: jax.Array, *,
                  num_rings: Optional[int] = None) -> jax.Array:
        """Inverse of ``reduce_scatter``: gather level by level, innermost
        axis first. The default ring count resolves from the FULL
        (gathered) buffer's bytes, matching ``reduce_scatter``'s."""
        from repro.core import collectives as C

        out = shard.reshape(-1)
        nr = (self.rings_for(out.size * self.resolve_size()
                             * out.dtype.itemsize)
              if num_rings is None else num_rings)
        for a in reversed(self.axes):
            out = C.ring_allgather(out, a, num_rings=nr,
                                   wire_dtype=self.wire)
        return out

    def shard_select(self, buf: jax.Array, *,
                     num_rings: Optional[int] = None) -> jax.Array:
        """This device's shard of a *replicated* flat buffer — exactly
        the slice ``reduce_scatter`` with the same geometry (and the
        same default ring-policy resolution) leaves here."""
        from repro.core import collectives as C

        out = buf.reshape(-1)
        nr = (self.rings_for(out.size * out.dtype.itemsize)
              if num_rings is None else num_rings)
        for a in self.axes:
            out = C.shard_select(out, a, num_rings=nr)
        return out

    # -- schedule-bucketed legs (backward overlap) ---------------------------
    def reduce_scatter_bucket(self, seg: jax.Array, schedule,
                              b: int) -> jax.Array:
        """One schedule bucket's reduce-scatter leg over the whole group,
        nested per axis (pod-level first, then data-level on the shard —
        the same hierarchy as ``reduce_scatter``, at the same telescoped
        (p-1)/p·size_b wire bytes). Single-ring per bucket: the schedule
        buckets ARE the overlap units. Returns this device's
        ``(chunks[b],)`` fully-reduced chunk."""
        from repro.core import collectives as C

        padded = schedule.bucket_padded(b)
        out = seg.reshape(-1)
        if out.size < padded:
            out = jnp.pad(out, (0, padded - out.size))
        for a in self.axes:
            out = C.ring_reduce_scatter(out, a, num_rings=1,
                                        wire_dtype=self.wire)
        return out

    def allgather_sched(self, shard: jax.Array, schedule) -> jax.Array:
        """The ONE trailing allgather of the overlapped step: gather the
        whole per-device schedule shard (bucket-major concat of chunks,
        length ``schedule.shard_size``) level by level, innermost axis
        first, then statically re-stitch the device-major result into
        the ``(spec.size,)`` packed layout."""
        from repro.core import collectives as C

        out = shard.reshape(-1)
        for a in reversed(self.axes):
            out = C.ring_allgather(out, a, num_rings=1,
                                   wire_dtype=self.wire)
        return C.sched_reassemble(out, schedule)

    def shard_select_sched(self, buf: jax.Array, schedule) -> jax.Array:
        """This device's schedule shard of a *replicated* packed buffer —
        per bucket, exactly the chunk ``reduce_scatter_bucket`` leaves
        here; concatenated bucket-major to pair with the reduced grads.
        Static slices + per-axis selection, no communication."""
        from repro.core import collectives as C

        flat = buf.reshape(-1)
        parts = []
        for b in range(schedule.num_buckets):
            s, n = schedule.starts[b], schedule.sizes[b]
            seg = flat[s:s + n]
            pad = schedule.bucket_padded(b) - n
            if pad:
                seg = jnp.pad(seg, (0, pad))
            for a in self.axes:
                seg = C.shard_select(seg, a, num_rings=1)
            parts.append(seg)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    # -- tensor (fused-pytree) collectives ----------------------------------
    def tensor_allreduce(self, tree: Any, *, mean: bool = False,
                         spec: Optional[flatbuf.FlatBuffer] = None) -> Any:
        """Allreduce a whole pytree as ONE fused flat buffer (the paper's
        group-of-vectors object), under this group's policy."""
        p = self.resolve_size()
        if self.method == "per_leaf":  # single-vector-at-a-time baseline
            from repro.core import collectives as C

            self._require_plain_wire("the per-leaf baseline")
            out = tree
            for a in self.axes:
                out = jax.tree.map(
                    lambda l: C.allreduce(
                        l.astype(jnp.float32), a, "ring").astype(l.dtype),
                    out)
            return jax.tree.map(lambda l: l / p, out) if mean else out
        spec = spec or flatbuf.spec_for(tree)
        buf = self.allreduce(spec.pack(tree), mean=mean)
        return spec.unpack(buf)

    def pushpull(self, tree: Any, *, fused: bool = True,
                 spec: Optional[flatbuf.FlatBuffer] = None) -> Any:
        """The KVStore.pushpull comm pattern inside this group (§4.2.4
        with #servers = 0): ``fused=True`` is one tensor allreduce (mean)
        under the group's bucket algorithm; ``fused=False`` is the
        push-then-pull pattern — binomial tree reduce + broadcast."""
        from repro.core import collectives as C

        if fused:
            return self.tensor_allreduce(tree, mean=True, spec=spec)
        self._require_plain_wire("the tree push + tree pull pattern")
        p = self.resolve_size()
        spec = spec or flatbuf.spec_for(tree)
        buf = spec.pack(tree)
        for a in self.axes:
            buf = C.tree_allreduce(buf, a)
        return spec.unpack(buf / p)

    # -- single-process emulation (the in-process PS simulation) ------------
    def emulate_reduce(self, stacked: Any, *, mean: bool = False) -> Any:
        """Group collective over a *stacked* member dim (leading axis =
        group size) via vmap emulation — how the in-process KVStore /
        six-mode simulation runs the intra-group leg. Multi-axis groups
        nest one vmap per axis over a matching leading shape."""
        if self.is_trivial:
            return stacked
        return _emulated_reduce(self, mean, stacked)


@partial(jax.jit, static_argnums=(0, 1))
def _emulated_reduce(comm: Communicator, mean: bool, stacked: Any) -> Any:
    """Jitted so the FlatBuffer pack traces ONCE per (communicator,
    structure, shapes) — eager drivers don't pay a re-flatten per step."""
    fn = lambda t: comm.tensor_allreduce(t, mean=mean)
    for a in reversed(comm.axes):
        fn = jax.vmap(fn, axis_name=a)
    return fn(stacked)


#: module-level trivial group (MPI_COMM_SELF with the default policy)
LOCAL = Communicator()


def from_sync(sync, axes=(), sizes=None, *, mesh=None) -> Communicator:
    """Build a communicator from a ``SyncConfig`` recipe: the config's
    resolved ``CollectivePolicy`` becomes the group's policy verbatim —
    ONE inheritance path from config through every split/complement/
    local below it."""
    pol = getattr(sync, "policy", None)
    if pol is None:  # duck-typed recipe without the resolved field
        pol = CollectivePolicy(
            method=sync.allreduce_method, num_rings=sync.num_rings,
            bucket_bytes=sync.bucket_bytes,
            wire_dtype=getattr(sync, "wire_dtype", None))
    return Communicator.world(axes, sizes, mesh=mesh, policy=pol)


def sync_comms(sync, world: Communicator
               ) -> tuple[Communicator, Optional[Communicator]]:
    """Resolve a SyncConfig's (gradient group, exchange group) over a
    world communicator — the paper's mode table as group algebra:

      mpi_sgd   one communicator spanning every axis (C = 1 pure-MPI
                mode): gradients fully reduced each step, no exchange
      mpi_esgd  the 'pod' axis is the PS tier: the gradient group is
                everything BUT 'pod' (intra-client), the elastic
                exchange group IS 'pod'. A world without a 'pod' axis
                maps device == client (the 1-axis shard driver): the
                whole world is the exchange group and the gradient
                group is trivial.
    """
    if sync.mode == "mpi_sgd":
        return world, None
    if sync.mode != "mpi_esgd":
        raise ValueError(f"lowerable modes are mpi_sgd/mpi_esgd, "
                         f"got {sync.mode!r}")
    if "pod" in world.axes:
        return world.complement("pod"), world.split("pod")
    return world.local(), world
