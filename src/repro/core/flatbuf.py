"""Persistent flat-buffer substrate for fused ("tensor") collectives.

The paper's core object is the *group of vectors treated as one*: the
whole gradient pytree rides a single bucket algorithm. The seed code
rebuilt that object every step with ``jnp.concatenate`` (a fresh flatten
+ f32 upcast per call). This module replaces that with a ``FlatBuffer``
spec computed ONCE per model: static per-leaf offsets, shapes and dtypes,
with every leaf padded to a lane-aligned boundary so

  * any bucket boundary is a valid Pallas block start, and
  * the total length divides cleanly into ring chunks,

and ``pack``/``unpack`` are pure static-slice scatter/gathers (no
concatenate, no per-step spec recomputation — XLA fuses the copies).

``spec_for`` memoizes specs by tree structure + leaf avals, so eager
drivers (core/algorithms.py, the KVStore barrier) pay the spec cost once
per model, and jitted steps build it at trace time only.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

# the single source of truth for tile geometry lives with the kernels:
# pick_block rounds Pallas blocks to the same LANE these offsets align to,
# so shard/bucket boundaries stay valid block starts by construction
from repro.kernels.common import LANE, SUBLANE


def _align(n: int, a: int) -> int:
    return -(-n // a) * a


@dataclass(frozen=True)
class FlatBuffer:
    """Static packing spec for one pytree: the fused tensor object."""

    treedef: Any
    shapes: tuple
    dtypes: tuple
    sizes: tuple      # true element count per leaf
    offsets: tuple    # lane-aligned start of each leaf in the buffer
    size: int         # padded total length (multiple of LANE*SUBLANE)
    dtype: Any = jnp.float32

    @property
    def num_leaves(self) -> int:
        return len(self.sizes)

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    @property
    def payload(self) -> int:
        """True (unpadded) element count across leaves."""
        return sum(self.sizes)

    def pack(self, tree: Any) -> jax.Array:
        """Pytree -> one ``(size,)`` buffer. Static slices only."""
        leaves = self.treedef.flatten_up_to(tree)
        buf = jnp.zeros((self.size,), self.dtype)
        for off, n, leaf in zip(self.offsets, self.sizes, leaves):
            buf = buf.at[off:off + n].set(
                leaf.reshape(-1).astype(self.dtype))
        return buf

    def unpack(self, buf: jax.Array) -> Any:
        """Inverse of ``pack``: restore leaf shapes and dtypes."""
        leaves = [
            buf[off:off + n].reshape(shape).astype(dt)
            for off, n, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def leaf_view(self, buf: jax.Array, index: int) -> jax.Array:
        """Leaf ``index`` of a packed buffer, reshaped (buffer dtype —
        no cast, so it stays a cheap view under XLA)."""
        off, n = self.offsets[index], self.sizes[index]
        return buf[off:off + n].reshape(self.shapes[index])

    def zeros(self) -> jax.Array:
        return jnp.zeros((self.size,), self.dtype)


def make_flatbuf(tree: Any, dtype=jnp.float32, *, align: int = LANE) -> FlatBuffer:
    """Build the spec from a concrete or abstract (eval_shape'd) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(math.prod(s) if s else 1 for s in shapes)
    offsets, off = [], 0
    for n in sizes:
        offsets.append(off)
        off += _align(max(n, 1), align)
    total = _align(max(off, align), LANE * SUBLANE)
    return FlatBuffer(treedef, shapes, dtypes, sizes, tuple(offsets), total,
                      jnp.dtype(dtype))


_SPEC_CACHE: dict = {}


def spec_for(tree: Any, dtype=jnp.float32) -> FlatBuffer:
    """Memoized ``make_flatbuf``: one spec per (structure, leaf avals).

    Safe under tracing (keys off static shape/dtype metadata only), and
    the reason eager drivers stop paying a re-flatten every step.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = (treedef, tuple((tuple(l.shape), str(l.dtype)) for l in leaves),
           str(jnp.dtype(dtype)))
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        spec = _SPEC_CACHE[key] = make_flatbuf(tree, dtype)
    return spec


# --------------------------------------------------------------------------
# Shard geometry: how a flat buffer splits across p devices × R rings
# --------------------------------------------------------------------------

def shard_geometry(n: int, p: int, num_rings: int = 1,
                   *, align: int = LANE) -> tuple[int, int]:
    """(per-ring chunk, padded total) for a length-``n`` buffer split over
    ``p`` devices × ``num_rings`` independent ring schedules. The chunk is
    lane-aligned so every shard boundary is a valid Pallas block start."""
    r = max(num_rings, 1)
    chunk = _align(-(-n // (p * r * align)) * align if n else align, align)
    chunk = max(chunk, align)
    return chunk, p * r * chunk


def effective_rings(nbytes: int, num_rings: int = 1,
                    bucket_bytes: int | None = None, *,
                    max_rings: int = 32) -> int:
    """Compose the two overlap knobs: explicit ring count and byte-sized
    bucketing. ``bucket_bytes`` asks for ceil(nbytes/bucket_bytes)
    independent schedules; the larger of the two wins (each ring is one
    bucket chain XLA can overlap with its neighbours).

    The result is capped at ``max_rings`` (default 32): each ring is a
    fully unrolled ppermute chain, so very large buffers with tiny
    ``bucket_bytes`` would otherwise explode trace size — past ~32
    in-flight chains the scheduler has nothing left to overlap anyway.
    Callers asking for more get buckets of ~nbytes/max_rings instead of
    the requested size.
    """
    r = max(num_rings, 1)
    if bucket_bytes:
        r = max(r, -(-int(nbytes) // int(bucket_bytes)))
    return min(r, max_rings)


def pack_padded(spec: FlatBuffer, tree: Any, total: int) -> jax.Array:
    """``spec.pack`` zero-extended to a ring geometry's ``total`` length
    (the shared prologue of every sharded flat-buffer leg)."""
    buf = spec.pack(tree)
    if total > spec.size:
        buf = jnp.pad(buf, (0, total - spec.size))
    return buf


def shard_size(spec: FlatBuffer, p: int = 1, num_rings: int = 1,
               bucket_bytes: int | None = None) -> int:
    """Per-device shard length (= momentum-state length) for a spec."""
    r = effective_rings(spec.nbytes, num_rings, bucket_bytes)
    chunk, total = shard_geometry(spec.size, p, r)
    return total // p
