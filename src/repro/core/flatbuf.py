"""Persistent flat-buffer substrate for fused ("tensor") collectives.

The paper's core object is the *group of vectors treated as one*: the
whole gradient pytree rides a single bucket algorithm. The seed code
rebuilt that object every step with ``jnp.concatenate`` (a fresh flatten
+ f32 upcast per call). This module replaces that with a ``FlatBuffer``
spec computed ONCE per model: static per-leaf offsets, shapes and dtypes,
with every leaf padded to a lane-aligned boundary so

  * any bucket boundary is a valid Pallas block start, and
  * the total length divides cleanly into ring chunks,

and ``pack``/``unpack`` are pure static-slice scatter/gathers (no
concatenate, no per-step spec recomputation — XLA fuses the copies).

``spec_for`` memoizes specs by tree structure + leaf avals, so eager
drivers (core/algorithms.py, the KVStore barrier) pay the spec cost once
per model, and jitted steps build it at trace time only.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

# the single source of truth for tile geometry lives with the kernels:
# pick_block rounds Pallas blocks to the same LANE these offsets align to,
# so shard/bucket boundaries stay valid block starts by construction
from repro.kernels.common import LANE, SUBLANE


def _align(n: int, a: int) -> int:
    return -(-n // a) * a


@dataclass(frozen=True)
class FlatBuffer:
    """Static packing spec for one pytree: the fused tensor object."""

    treedef: Any
    shapes: tuple
    dtypes: tuple
    sizes: tuple      # true element count per leaf
    offsets: tuple    # lane-aligned start of each leaf in the buffer
    size: int         # padded total length (multiple of LANE*SUBLANE)
    dtype: Any = jnp.float32

    @property
    def num_leaves(self) -> int:
        return len(self.sizes)

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    @property
    def payload(self) -> int:
        """True (unpadded) element count across leaves."""
        return sum(self.sizes)

    def pack(self, tree: Any) -> jax.Array:
        """Pytree -> one ``(size,)`` buffer. Static slices only."""
        leaves = self.treedef.flatten_up_to(tree)
        buf = jnp.zeros((self.size,), self.dtype)
        for off, n, leaf in zip(self.offsets, self.sizes, leaves):
            buf = buf.at[off:off + n].set(
                leaf.reshape(-1).astype(self.dtype))
        return buf

    def unpack(self, buf: jax.Array) -> Any:
        """Inverse of ``pack``: restore leaf shapes and dtypes."""
        leaves = [
            buf[off:off + n].reshape(shape).astype(dt)
            for off, n, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def leaf_view(self, buf: jax.Array, index: int) -> jax.Array:
        """Leaf ``index`` of a packed buffer, reshaped (buffer dtype —
        no cast, so it stays a cheap view under XLA)."""
        off, n = self.offsets[index], self.sizes[index]
        return buf[off:off + n].reshape(self.shapes[index])

    def zeros(self) -> jax.Array:
        return jnp.zeros((self.size,), self.dtype)


def make_flatbuf(tree: Any, dtype=jnp.float32, *, align: int = LANE) -> FlatBuffer:
    """Build the spec from a concrete or abstract (eval_shape'd) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(math.prod(s) if s else 1 for s in shapes)
    offsets, off = [], 0
    for n in sizes:
        offsets.append(off)
        off += _align(max(n, 1), align)
    total = _align(max(off, align), LANE * SUBLANE)
    return FlatBuffer(treedef, shapes, dtypes, sizes, tuple(offsets), total,
                      jnp.dtype(dtype))


_SPEC_CACHE: dict = {}


def spec_for(tree: Any, dtype=jnp.float32) -> FlatBuffer:
    """Memoized ``make_flatbuf``: one spec per (structure, leaf avals).

    Safe under tracing (keys off static shape/dtype metadata only), and
    the reason eager drivers stop paying a re-flatten every step.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = (treedef, tuple((tuple(l.shape), str(l.dtype)) for l in leaves),
           str(jnp.dtype(dtype)))
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        spec = _SPEC_CACHE[key] = make_flatbuf(tree, dtype)
    return spec


# --------------------------------------------------------------------------
# Shard geometry: how a flat buffer splits across p devices × R rings
# --------------------------------------------------------------------------

def edge_grid() -> int:
    """The grid every schedule-bucket edge must sit on: a common multiple
    of the Pallas LANE and the int8 wire codec's WIRE_BLOCK, so a bucket
    boundary is simultaneously a valid block start and never splits a
    per-128-value scale group between two buckets."""
    from repro.kernels.quant_bucket.quant_bucket import WIRE_BLOCK

    return LANE * WIRE_BLOCK // math.gcd(LANE, WIRE_BLOCK)


def align_edge(n: int, *, align: int | None = None) -> int:
    """Round a schedule-bucket edge (or shard chunk) up to the LANE ×
    WIRE_BLOCK grid. Shared by ``shard_geometry`` and ``bucket_schedule``
    so ring-chunk boundaries and schedule-bucket boundaries live on the
    same grid — an int8 per-bucket scale group can never straddle either.
    """
    a = align if align is not None else edge_grid()
    if n < 0:
        raise ValueError(f"bucket edge must be >= 0, got {n}")
    return _align(n, a)


def shard_geometry(n: int, p: int, num_rings: int = 1,
                   *, align: int = LANE) -> tuple[int, int]:
    """(per-ring chunk, padded total) for a length-``n`` buffer split over
    ``p`` devices × ``num_rings`` independent ring schedules. The chunk is
    lane-aligned so every shard boundary is a valid Pallas block start."""
    r = max(num_rings, 1)
    chunk = align_edge(-(-n // (p * r * align)) * align if n else align,
                       align=align)
    chunk = max(chunk, align)
    return chunk, p * r * chunk


def effective_rings(nbytes: int, num_rings: int = 1,
                    bucket_bytes: int | None = None, *,
                    max_rings: int = 32) -> int:
    """Compose the two overlap knobs: explicit ring count and byte-sized
    bucketing. ``bucket_bytes`` asks for ceil(nbytes/bucket_bytes)
    independent schedules; the larger of the two wins (each ring is one
    bucket chain XLA can overlap with its neighbours).

    The result is capped at ``max_rings`` (default 32): each ring is a
    fully unrolled ppermute chain, so very large buffers with tiny
    ``bucket_bytes`` would otherwise explode trace size — past ~32
    in-flight chains the scheduler has nothing left to overlap anyway.
    Callers asking for more get buckets of ~nbytes/max_rings instead of
    the requested size.
    """
    r = max(num_rings, 1)
    if bucket_bytes:
        r = max(r, -(-int(nbytes) // int(bucket_bytes)))
    return min(r, max_rings)


def pack_padded(spec: FlatBuffer, tree: Any, total: int) -> jax.Array:
    """``spec.pack`` zero-extended to a ring geometry's ``total`` length
    (the shared prologue of every sharded flat-buffer leg)."""
    buf = spec.pack(tree)
    if total > spec.size:
        buf = jnp.pad(buf, (0, total - spec.size))
    return buf


def shard_size(spec: FlatBuffer, p: int = 1, num_rings: int = 1,
               bucket_bytes: int | None = None) -> int:
    """Per-device shard length (= momentum-state length) for a spec."""
    r = effective_rings(spec.nbytes, num_rings, bucket_bytes)
    chunk, total = shard_geometry(spec.size, p, r)
    return total // p


# --------------------------------------------------------------------------
# Schedule buckets: the backward-overlap partition of a packed buffer
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BucketSchedule:
    """Leaf-boundary-keyed partition of a packed buffer into schedule
    buckets, one per backward stage.

    Bucket ``b`` spans ``[starts[b], starts[b] + sizes[b])`` of the packed
    buffer and owns leaves ``[leaf_starts[b], leaf_starts[b+1])`` of the
    spec. Every edge sits on the LANE × WIRE_BLOCK grid (``align_edge``),
    so per-bucket int8 wire scales never straddle a bucket and every
    boundary is a valid Pallas block start. The buckets tile the spec
    exactly: ``starts[0] == 0`` and ``sum(sizes) == spec.size`` (the last
    bucket absorbs the spec's tail padding).

    ``chunks[b]`` is the per-device ring chunk of bucket ``b``'s
    reduce-scatter leg at ``p`` total shards (single-ring — the schedule
    buckets ARE the overlap units, extra rings inside one would fight
    them). A device's shard of the whole schedule is the concatenation of
    its per-bucket chunks: length ``shard_size = sum(chunks)``, bucket
    ``b``'s chunk at ``shard_offsets[b]``.
    """

    spec: FlatBuffer
    starts: tuple      # bucket start offsets in the packed buffer
    sizes: tuple       # bucket extents; sum == spec.size
    leaf_starts: tuple  # first spec-leaf index of each bucket, + sentinel
    p: int             # total shard count the per-bucket legs run at
    chunks: tuple      # per-device chunk of each bucket's ring leg

    @property
    def num_buckets(self) -> int:
        return len(self.sizes)

    @property
    def shard_size(self) -> int:
        """Per-device shard length (= overlapped optimizer-state length)."""
        return sum(self.chunks)

    @property
    def shard_offsets(self) -> tuple:
        offs, off = [], 0
        for c in self.chunks:
            offs.append(off)
            off += c
        return tuple(offs)

    def bucket_padded(self, b: int) -> int:
        """Padded length of bucket ``b``'s ring leg (p × chunk)."""
        return self.p * self.chunks[b]

    def pack_bucket(self, b: int, tree_b: Any) -> jax.Array:
        """Pack bucket ``b``'s leaves (a stage's grad subtree, in spec
        leaf order) into its ``(sizes[b],)`` segment of the buffer."""
        leaves = jax.tree_util.tree_leaves(tree_b)
        lo, hi = self.leaf_starts[b], self.leaf_starts[b + 1]
        if len(leaves) != hi - lo:
            raise ValueError(
                f"bucket {b} owns {hi - lo} leaves but the stage tree has "
                f"{len(leaves)} — the stage partition and the schedule "
                f"must come from the same overlap_stages split")
        buf = jnp.zeros((self.sizes[b],), self.spec.dtype)
        base = self.starts[b]
        for i, leaf in zip(range(lo, hi), leaves):
            off = self.spec.offsets[i] - base
            n = self.spec.sizes[i]
            buf = buf.at[off:off + n].set(
                leaf.reshape(-1).astype(self.spec.dtype))
        return buf

    def with_p(self, p: int) -> "BucketSchedule":
        """The same stage partition re-laid-out for ``p`` shards (e.g. the
        local p=1 state geometry vs a device-sharded driver's p)."""
        if p == self.p:
            return self
        counts = tuple(self.leaf_starts[b + 1] - self.leaf_starts[b]
                       for b in range(self.num_buckets))
        return bucket_schedule(self.spec, counts, p)


def bucket_schedule(spec: FlatBuffer, leaf_counts, p: int) -> BucketSchedule:
    """Build the backward-overlap schedule for ``spec`` split at leaf
    boundaries: ``leaf_counts[b]`` spec leaves go to bucket ``b`` (stage
    order — the packing order of the spec). ``p`` is the total shard
    count the per-bucket reduce-scatter legs will run at."""
    from repro.kernels.quant_bucket.quant_bucket import WIRE_BLOCK

    counts = tuple(int(c) for c in leaf_counts)
    if any(c <= 0 for c in counts):
        raise ValueError(
            f"every schedule bucket needs at least one leaf, got "
            f"leaf_counts={counts} — merge empty stages before building "
            f"the schedule (lower overlap_buckets)")
    if sum(counts) != spec.num_leaves:
        raise ValueError(
            f"leaf_counts {counts} sum to {sum(counts)} but the spec has "
            f"{spec.num_leaves} leaves — the schedule must tile the "
            f"packed buffer exactly")
    leaf_starts, li = [], 0
    for c in counts:
        leaf_starts.append(li)
        li += c
    leaf_starts.append(li)
    starts = [spec.offsets[leaf_starts[b]] for b in range(len(counts))]
    ends = starts[1:] + [spec.size]
    sizes = [e - s for s, e in zip(starts, ends)]
    grid = edge_grid()
    for b, (s, n) in enumerate(zip(starts, sizes)):
        if s % grid or (s + n) % grid:
            raise ValueError(
                f"bucket {b} edge [{s}, {s + n}) is off the LANE×"
                f"WIRE_BLOCK grid ({grid}) — pack with make_flatbuf's "
                f"default LANE alignment so leaf boundaries are valid "
                f"bucket edges")
        if n < WIRE_BLOCK:
            raise ValueError(
                f"bucket {b} spans {n} elements < one WIRE_BLOCK "
                f"({WIRE_BLOCK}) — an int8 wire scale group would "
                f"straddle buckets; merge stages (lower overlap_buckets) "
                f"until every bucket holds at least one wire block")
    chunks = tuple(shard_geometry(n, p, 1)[0] for n in sizes)
    return BucketSchedule(spec, tuple(starts), tuple(sizes),
                          tuple(leaf_starts), int(p), chunks)
