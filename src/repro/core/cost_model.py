"""α-β-γ communication cost model (paper §6.2 + Fig. 12/15 reproduction).

Bucket allreduce cost (Patarasuk & Yuan):  (p−1)α + 2·(p−1)/p·nβ + (p−1)/p·nγ
Multi-ring overlaps the γ (reduction) term with the β (transfer) term.
PS push/pull: a server's ingress link is shared by every concurrent pusher
(the network hot-spot of §2.3).

Two hardware presets:
  * ``testbed()`` — the paper's IB ConnectX-4 cluster (for Fig 12 numbers)
  * ``tpu_v5e()`` — our target (ICI links), used by the roofline tooling
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class NetParams:
    alpha: float   # per-step latency (s)
    beta: float    # seconds per byte (link bandwidth⁻¹)
    gamma: float   # seconds per byte of local reduction


def testbed() -> NetParams:
    # IB CX-4 ~ 12.5 GB/s; host reduction ~30 GB/s (paper's IBMGpu number)
    return NetParams(alpha=5e-6, beta=1 / 12.5e9, gamma=1 / 30e9)


def tpu_v5e() -> NetParams:
    # ~50 GB/s/link ICI; on-chip reduction at HBM bw 819 GB/s
    return NetParams(alpha=1e-6, beta=1 / 45e9, gamma=1 / 819e9)


# --------------------------------------------------------------------------
# Low-precision wire protocol: bytes-on-wire per f32 payload byte
# --------------------------------------------------------------------------

#: f32 -> wire byte ratio per wire dtype. int8 counts the codes (1 byte
#: per value) PLUS one f32 scale per WIRE_BLOCK=128 bucket, matching
#: kernels/quant_bucket.wire_encode exactly: (1 + 4/128)/4 = 0.2578125.
WIRE_RATIO = {
    None: 1.0,
    "f32": 1.0,
    "bf16": 0.5,
    "int8": (1 + 4 / 128) / 4,
}


def wire_ratio(wire_dtype: "str | None" = None) -> float:
    try:
        return WIRE_RATIO[wire_dtype]
    except KeyError:
        raise ValueError(
            f"wire_dtype must be one of {tuple(WIRE_RATIO)}, "
            f"got {wire_dtype!r}") from None


def wire_bytes(nbytes: float, wire_dtype: "str | None" = None) -> float:
    """f32 payload bytes -> bytes that actually cross the wire."""
    return nbytes * wire_ratio(wire_dtype)


def grad_leg_bytes(nbytes: float, p: int,
                   wire_dtype: "str | None" = None) -> float:
    """Per-device gradient-leg wire bytes of the sharded fused step: the
    ring reduce-scatter's (p−1)/p·n, scaled by the wire dtype."""
    if p <= 1:
        return 0.0
    return (p - 1) / p * wire_bytes(nbytes, wire_dtype)


def param_leg_bytes(nbytes: float, p: int,
                    wire_dtype: "str | None" = None) -> float:
    """Per-device param-allgather wire bytes (the second half)."""
    return grad_leg_bytes(nbytes, p, wire_dtype)


def elastic_leg_bytes(nbytes: float, p: int,
                      wire_dtype: "str | None" = None) -> float:
    """Per-device wire bytes of one sharded elastic exchange: the packed
    diff reduce-scatter + the center-shard allgather."""
    return 2 * grad_leg_bytes(nbytes, p, wire_dtype)


def ps_push_bytes(nbytes: float, wire_dtype: "str | None" = None) -> float:
    """PS-leg wire bytes of one push (the KVStore's compressed form)."""
    return wire_bytes(nbytes, wire_dtype)


def ps_wire_nbytes(n_values: int, wire_dtype: "str | None" = None) -> int:
    """EXACT PS-leg payload bytes of one push/pull of ``n_values`` f32
    values over the socket transport (net/wire.py's encode_buffer):

      f32   4n
      bf16  2n
      int8  n_pad + n_pad/128 * 4   (codes + one f32 scale per
                                     WIRE_BLOCK=128 bucket, n padded up
                                     to whole buckets)

    For WIRE_BLOCK-aligned n — every FlatBuffer spec.size is, since
    specs pad to LANE*SUBLANE — this equals ``ps_push_bytes(4n, wd)``
    exactly; BENCH_transport gates measured socket bytes against it."""
    if wire_dtype in (None, "f32"):
        return 4 * n_values
    if wire_dtype == "bf16":
        return 2 * n_values
    if wire_dtype == "int8":
        from repro.kernels.quant_bucket.quant_bucket import WIRE_BLOCK

        n_pad = -(-n_values // WIRE_BLOCK) * WIRE_BLOCK
        return n_pad + (n_pad // WIRE_BLOCK) * 4
    raise ValueError(f"wire_dtype must be None/f32/bf16/int8, "
                     f"got {wire_dtype!r}")


def reshard_leg_bytes(state_nbytes: float, p_old: int,
                      survivors: "int | None" = None,
                      wire_dtype: "str | None" = None) -> float:
    """Per-survivor wire bytes of re-laying-out 1/p_old-sharded state
    after a membership change: an allgather among the ``s`` survivors of
    their old shards — each receives the other s−1 shards of
    ``state_nbytes / p_old`` bytes. This is EXACTLY the ``moved_bytes``
    core/membership.py's ``reshard_optstate`` reports (bench_faults.py
    gates on the match)."""
    if p_old <= 1:
        return 0.0
    s = p_old if survivors is None else int(survivors)
    if s <= 1:
        return 0.0
    return (s - 1) * wire_bytes(state_nbytes / p_old, wire_dtype)


def resplit_time(p_new: int, net: NetParams) -> float:
    """Communicator re-split (MPI_Comm_split over the survivor group):
    an agreement round — ceil(log2(p_new)) latency-bound hops, no
    payload to speak of."""
    import math

    if p_new <= 1:
        return net.alpha
    return math.ceil(math.log2(p_new)) * net.alpha


def reconfig_time(state_nbytes: float, p_old: int, p_new: int,
                  net: NetParams, survivors: "int | None" = None,
                  wire_dtype: "str | None" = None) -> float:
    """Total recovery overhead of one membership change: the re-split
    agreement plus the survivor allgather realizing the new state
    layout (per-survivor bytes × β; the shards move in parallel)."""
    moved = reshard_leg_bytes(state_nbytes, p_old, survivors, wire_dtype)
    return resplit_time(p_new, net) + moved * net.beta


def restore_leg_bytes(n_values: int) -> int:
    """EXACT payload bytes of one parked-state restore leg: a respawned
    worker's ``get_state`` pull of ``n_values`` f32 values. Resume must
    be bit-identical, so state parking bypasses the wire codec (always
    4 bytes/value, no bf16/int8 option — net/remote_kv.py counts the
    pull as ``state_bytes_in``). BENCH_recovery gates the measured
    counter against this."""
    return 4 * int(n_values)


def join_reshard_bytes(state_nbytes: float, p_old: int,
                       survivors: "int | None" = None,
                       wire_dtype: "str | None" = None) -> float:
    """Per-survivor wire bytes of admitting a joiner into
    1/p_old-sharded optimizer state: a grow is a reshard in which EVERY
    old shard survives — reconstruct from the s = p_old shards, then
    re-slice at the grown count. This is exactly the ``moved_bytes``
    ``membership.reshard_optstate`` reports for the join
    (bench_recovery.py gates the match)."""
    return reshard_leg_bytes(state_nbytes, p_old, survivors, wire_dtype)


def recovery_time(restore_nbytes: float, respawn_delay: float,
                  p_old: int, p_new: int, net: NetParams,
                  state_nbytes: float = 0.0,
                  survivors: "int | None" = None,
                  wire_dtype: "str | None" = None) -> float:
    """Wall-clock overhead of one crash recovery: the supervisor's
    respawn gap, the respawn's state-restore pull (exact-f32 bytes ×
    β), and — when sharded state must re-lay-out (a join/eviction, or
    any nonzero ``state_nbytes``) — the re-split agreement plus the
    survivor allgather (``reconfig_time``)."""
    t = float(respawn_delay) + restore_nbytes * net.beta
    if p_old != p_new or state_nbytes:
        t += reconfig_time(state_nbytes, p_old, p_new, net,
                           survivors=survivors, wire_dtype=wire_dtype)
    return t


def reduce_scatter_time(nbytes: float, p: int, net: NetParams,
                        wire_dtype: "str | None" = None) -> float:
    """One ring reduce-scatter leg: the allreduce's first half — (p−1)
    latency hops, (p−1)/p·n transfer (wire-scaled) and reduction."""
    if p <= 1:
        return 0.0
    return (
        (p - 1) * net.alpha
        + (p - 1) / p * wire_bytes(nbytes, wire_dtype) * net.beta
        + (p - 1) / p * nbytes * net.gamma
    )


def allgather_time(nbytes: float, p: int, net: NetParams,
                   wire_dtype: "str | None" = None) -> float:
    """One ring allgather leg: the allreduce's second half (no γ)."""
    if p <= 1:
        return 0.0
    return (
        (p - 1) * net.alpha
        + (p - 1) / p * wire_bytes(nbytes, wire_dtype) * net.beta
    )


def overlap_fraction(bucket_bytes: "list[float] | tuple",
                     p: int) -> float:
    """STRUCTURAL fraction of the gradient reduce-scatter's wire bytes
    issued while backward compute remains.

    The staged backward issues bucket legs last-stage-first, so bucket 0
    (the embedding stage, differentiated last) is the final leg — the
    only one with no backward compute left to hide behind. Wire-dtype
    scaling applies to every bucket alike, so it cancels:
    ``1 − bucket_bytes[0] / sum(bucket_bytes)``. 0.0 for a single bucket
    or p ≤ 1 (no wire leg at all). This is exactly what the jaxpr
    measures: ppermute bytes BEFORE the last backward-compute equation
    over total reduce-scatter ppermute bytes (bench_overlap.py gates the
    match)."""
    total = sum(bucket_bytes)
    if p <= 1 or len(bucket_bytes) <= 1 or total <= 0:
        return 0.0
    return 1.0 - bucket_bytes[0] / total


def overlapped_step_time(compute_time: float,
                         bucket_bytes: "list[float] | tuple", p: int,
                         net: NetParams,
                         wire_dtype: "str | None" = None) -> float:
    """Modeled wall time of one backward-overlapped step.

    The hidden ``overlap_fraction`` of the reduce-scatter leg rides
    behind backward compute (bounded by the compute itself); the exposed
    remainder, the trailing allgather, and the extra per-bucket ring
    latencies pay in full. With one bucket (or p ≤ 1) this reduces to
    ``compute + reduce_scatter_time + allgather_time`` — the
    non-overlapped fused step."""
    nbytes = sum(bucket_bytes)
    rs = reduce_scatter_time(nbytes, p, net, wire_dtype)
    ag = allgather_time(nbytes, p, net, wire_dtype)
    extra_alpha = max(len(bucket_bytes) - 1, 0) * max(p - 1, 0) * net.alpha
    hidden = min(overlap_fraction(bucket_bytes, p) * rs, compute_time)
    return compute_time + (rs - hidden) + ag + extra_alpha


def ring_allreduce_time(nbytes: float, p: int, net: NetParams,
                        wire_dtype: "str | None" = None) -> float:
    """β (transfer) pays the wire-dtype ratio; γ (local reduction) stays
    full-precision — hops dequantize before accumulating."""
    if p <= 1:
        return 0.0
    return (
        (p - 1) * net.alpha
        + 2 * (p - 1) / p * wire_bytes(nbytes, wire_dtype) * net.beta
        + (p - 1) / p * nbytes * net.gamma
    )


def multi_ring_allreduce_time(nbytes: float, p: int, net: NetParams,
                              num_rings: int = 2,
                              wire_dtype: "str | None" = None) -> float:
    """γ of ring i overlaps β of ring i+1 → pay max(β, γ) instead of β+γ
    on the steady-state term (plus one non-overlapped γ pipeline fill)."""
    if p <= 1:
        return 0.0
    beta_term = 2 * (p - 1) / p * wire_bytes(nbytes, wire_dtype) * net.beta
    gamma_term = (p - 1) / p * nbytes * net.gamma
    fill = gamma_term / max(num_rings, 1)
    return (p - 1) * net.alpha * num_rings + max(beta_term, gamma_term) + fill


def tree_allreduce_time(nbytes: float, p: int, net: NetParams) -> float:
    """Binomial reduce+bcast (`reg`): 2·log2(p) full-buffer hops."""
    import math

    if p <= 1:
        return 0.0
    steps = 2 * math.ceil(math.log2(p))
    return steps * (net.alpha + nbytes * net.beta) + nbytes * net.gamma * math.log2(p)


def ps_pushpull_time(nbytes: float, num_pushers: int, num_servers: int,
                     net: NetParams,
                     wire_dtype: "str | None" = None) -> float:
    """Server ingress shared by every concurrent pusher + egress for
    pulls. Each server holds 1/num_servers of the keys. A low-precision
    ``wire_dtype`` shrinks the ingress/egress bytes (the hot-spot of
    §2.3); the server reduces on dequantized values, so γ is unscaled."""
    per_server = nbytes / max(num_servers, 1)
    on_wire = per_server * wire_ratio(wire_dtype)
    ingress = on_wire * num_pushers * net.beta  # serialized hot-spot
    egress = on_wire * num_pushers * net.beta
    reduce_cost = per_server * num_pushers * net.gamma
    return 2 * net.alpha + ingress + egress + reduce_cost


def allreduce_time(nbytes: float, p: int, net: NetParams, method: str,
                   num_rings: int = 2,
                   wire_dtype: "str | None" = None) -> float:
    return {
        "ring": lambda: ring_allreduce_time(nbytes, p, net, wire_dtype),
        "multi_ring": lambda: multi_ring_allreduce_time(
            nbytes, p, net, num_rings, wire_dtype),
        "scatter_gather": lambda: ring_allreduce_time(
            nbytes, p, net, wire_dtype),  # same wire bytes, separable halves
        "tree": lambda: tree_allreduce_time(nbytes, p, net),
        "psum": lambda: ring_allreduce_time(nbytes, p, net),  # XLA uses rings
    }[method]()


def epoch_time(
    *,
    model_bytes: float,
    num_workers: int,
    num_clients: int,
    num_servers: int,
    steps_per_epoch: int,
    compute_time_per_step: float,
    net: NetParams,
    mode: str,  # "dist" (pure PS) or "mpi" (hierarchical)
    sync_every: int = 1,  # ESGD INTERVAL communicates every k steps
) -> float:
    """Fig. 12's quantity: average epoch wall time for one worker."""
    per_client = num_workers // num_clients
    if mode == "dist":
        comm = ps_pushpull_time(model_bytes, num_workers, num_servers, net)
    elif mode == "mpi":
        intra = ring_allreduce_time(model_bytes, per_client, net)
        to_ps = (
            ps_pushpull_time(model_bytes, num_clients, num_servers, net)
            if num_servers > 0
            else 0.0
        )
        comm = intra + to_ps
    else:
        raise ValueError(mode)
    return steps_per_epoch * (compute_time_per_step + comm / sync_every)
