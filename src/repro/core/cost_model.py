"""α-β-γ communication cost model (paper §6.2 + Fig. 12/15 reproduction).

Bucket allreduce cost (Patarasuk & Yuan):  (p−1)α + 2·(p−1)/p·nβ + (p−1)/p·nγ
Multi-ring overlaps the γ (reduction) term with the β (transfer) term.
PS push/pull: a server's ingress link is shared by every concurrent pusher
(the network hot-spot of §2.3).

Two hardware presets:
  * ``testbed()`` — the paper's IB ConnectX-4 cluster (for Fig 12 numbers)
  * ``tpu_v5e()`` — our target (ICI links), used by the roofline tooling
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class NetParams:
    alpha: float   # per-step latency (s)
    beta: float    # seconds per byte (link bandwidth⁻¹)
    gamma: float   # seconds per byte of local reduction


def testbed() -> NetParams:
    # IB CX-4 ~ 12.5 GB/s; host reduction ~30 GB/s (paper's IBMGpu number)
    return NetParams(alpha=5e-6, beta=1 / 12.5e9, gamma=1 / 30e9)


def tpu_v5e() -> NetParams:
    # ~50 GB/s/link ICI; on-chip reduction at HBM bw 819 GB/s
    return NetParams(alpha=1e-6, beta=1 / 45e9, gamma=1 / 819e9)


def ring_allreduce_time(nbytes: float, p: int, net: NetParams) -> float:
    if p <= 1:
        return 0.0
    return (
        (p - 1) * net.alpha
        + 2 * (p - 1) / p * nbytes * net.beta
        + (p - 1) / p * nbytes * net.gamma
    )


def multi_ring_allreduce_time(nbytes: float, p: int, net: NetParams,
                              num_rings: int = 2) -> float:
    """γ of ring i overlaps β of ring i+1 → pay max(β, γ) instead of β+γ
    on the steady-state term (plus one non-overlapped γ pipeline fill)."""
    if p <= 1:
        return 0.0
    beta_term = 2 * (p - 1) / p * nbytes * net.beta
    gamma_term = (p - 1) / p * nbytes * net.gamma
    fill = gamma_term / max(num_rings, 1)
    return (p - 1) * net.alpha * num_rings + max(beta_term, gamma_term) + fill


def tree_allreduce_time(nbytes: float, p: int, net: NetParams) -> float:
    """Binomial reduce+bcast (`reg`): 2·log2(p) full-buffer hops."""
    import math

    if p <= 1:
        return 0.0
    steps = 2 * math.ceil(math.log2(p))
    return steps * (net.alpha + nbytes * net.beta) + nbytes * net.gamma * math.log2(p)


def ps_pushpull_time(nbytes: float, num_pushers: int, num_servers: int,
                     net: NetParams) -> float:
    """Server ingress shared by concurrent pushers + egress for pulls.
    Each server holds 1/num_servers of the keys."""
    per_server = nbytes / max(num_servers, 1)
    ingress = per_server * num_pushers * net.beta  # serialized hot-spot
    egress = per_server * num_pushers * net.beta
    reduce_cost = per_server * num_pushers * net.gamma
    return 2 * net.alpha + ingress + egress + reduce_cost


def allreduce_time(nbytes: float, p: int, net: NetParams, method: str,
                   num_rings: int = 2) -> float:
    return {
        "ring": lambda: ring_allreduce_time(nbytes, p, net),
        "multi_ring": lambda: multi_ring_allreduce_time(nbytes, p, net, num_rings),
        "tree": lambda: tree_allreduce_time(nbytes, p, net),
        "psum": lambda: ring_allreduce_time(nbytes, p, net),  # XLA uses rings
    }[method]()


def epoch_time(
    *,
    model_bytes: float,
    num_workers: int,
    num_clients: int,
    num_servers: int,
    steps_per_epoch: int,
    compute_time_per_step: float,
    net: NetParams,
    mode: str,  # "dist" (pure PS) or "mpi" (hierarchical)
    sync_every: int = 1,  # ESGD INTERVAL communicates every k steps
) -> float:
    """Fig. 12's quantity: average epoch wall time for one worker."""
    per_client = num_workers // num_clients
    if mode == "dist":
        comm = ps_pushpull_time(model_bytes, num_workers, num_servers, net)
    elif mode == "mpi":
        intra = ring_allreduce_time(model_bytes, per_client, net)
        to_ps = (
            ps_pushpull_time(model_bytes, num_clients, num_servers, net)
            if num_servers > 0
            else 0.0
        )
        comm = intra + to_ps
    else:
        raise ValueError(mode)
    return steps_per_epoch * (compute_time_per_step + comm / sync_every)
