"""Deterministic synthetic data pipeline.

The paper trains on ImageNet 1K sharded across workers; here the substrate
is a seeded, shardable token/image stream with the same *semantics*:
- the epoch is a fixed set of mini-batches,
- each worker (client, rank) sees a disjoint deterministic shard,
- batches are reproducible from (seed, epoch, step) alone — no state.

Token batches follow a learnable synthetic language (a fixed random
bigram automaton) so that losses actually *descend* in convergence
experiments rather than saturating at log(V).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 1024
    seq_len: int = 128
    batch_size: int = 8          # per-worker batch (paper's scheduling unit)
    steps_per_epoch: int = 50
    num_shards: int = 1          # total workers
    shard: int = 0               # this worker's rank


def _bigram_table(seed: int, vocab: int) -> np.ndarray:
    """Row-stochastic transition logits of the synthetic language."""
    rng = np.random.default_rng(seed ^ 0x5EED)
    # each token has a few likely successors -> learnable structure
    table = rng.normal(size=(vocab, vocab)).astype(np.float32)
    hot = rng.integers(0, vocab, size=(vocab, 4))
    for i in range(vocab):
        table[i, hot[i]] += 4.0
    return table


class TokenPipeline:
    """Iterable of {"tokens","labels"} batches; indexable by (epoch, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._table = _bigram_table(cfg.seed, cfg.vocab_size)
        self._probs = _softmax_rows(self._table)

    def batch_at(self, epoch: int, step: int) -> dict:
        cfg = self.cfg
        key = np.random.default_rng(
            (cfg.seed, epoch, step, cfg.shard, 0xDA7A)
        )
        B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = key.integers(0, V, size=B)
        # vectorized ancestral sampling from the bigram automaton
        for t in range(1, S + 1):
            p = self._probs[toks[:, t - 1]]
            cum = np.cumsum(p, axis=1)
            u = key.random(B)[:, None]
            toks[:, t] = np.argmax(cum > u, axis=1)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def epoch(self, epoch: int) -> Iterator[dict]:
        for step in range(self.cfg.steps_per_epoch):
            yield self.batch_at(epoch, step)

    def optimal_xent(self, n_mc: int = 4096) -> float:
        """Entropy rate of the automaton = the loss floor."""
        rng = np.random.default_rng(self.cfg.seed + 1)
        rows = rng.integers(0, self.cfg.vocab_size, size=n_mc)
        p = self._probs[rows]
        return float(-np.mean(np.sum(p * np.log(p + 1e-20), axis=1)))


def _softmax_rows(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=1, keepdims=True)


class ImagePipeline:
    """Synthetic image classification: class-dependent Gaussian blobs +
    noise. Linearly separable enough that SGD converges, hard enough that
    convergence *rates* differ across algorithms."""

    def __init__(self, cfg: DataConfig, image_size: int = 16,
                 num_classes: int = 10, noise: float = 1.5):
        self.cfg = cfg
        self.image_size = image_size
        self.num_classes = num_classes
        self.noise = noise
        rng = np.random.default_rng(cfg.seed ^ 0x1333)
        self._proto = rng.normal(
            size=(num_classes, image_size, image_size, 3)
        ).astype(np.float32)

    def batch_at(self, epoch: int, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, epoch, step, cfg.shard, 0x13))
        B = cfg.batch_size
        labels = rng.integers(0, self.num_classes, size=B)
        noise = rng.normal(size=(B, self.image_size, self.image_size, 3))
        images = self._proto[labels] + self.noise * noise.astype(np.float32)
        return {"images": jnp.asarray(images), "labels": jnp.asarray(labels)}

    def epoch(self, epoch: int) -> Iterator[dict]:
        for step in range(self.cfg.steps_per_epoch):
            yield self.batch_at(epoch, step)


def shard_config(cfg: DataConfig, num_shards: int, shard: int) -> DataConfig:
    return dataclasses.replace(cfg, num_shards=num_shards, shard=shard)
