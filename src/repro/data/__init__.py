from repro.data.pipeline import DataConfig, ImagePipeline, TokenPipeline
