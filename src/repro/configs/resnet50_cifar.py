"""Compact ResNet (paper's own model family, He et al. 2015) for the
convergence experiments on CPU — the paper trains ResNet-50/ImageNet;
we train a narrow ResNet on synthetic image data for Figs 11/13/14."""
from dataclasses import dataclass


@dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet-tiny"
    stage_sizes: tuple = (1, 1, 1)
    width: int = 16
    num_classes: int = 10
    image_size: int = 16
    citation: str = "arXiv:1512.03385 (paper trains ResNet-50)"


CONFIG = ResNetConfig()
