"""Qwen3-4B: GQA with qk_norm. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    arch_type="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    citation="hf:Qwen/Qwen3-8B",
)
