"""Qwen2-0.5B: GQA kv=2, QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    citation="arXiv:2407.10671",
)
