"""Qwen2.5-3B: GQA kv=2, QKV bias. [hf:Qwen/Qwen2.5 family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    citation="hf:Qwen/Qwen2.5-0.5B",
)
