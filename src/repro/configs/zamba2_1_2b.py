"""Zamba2-1.2B: Mamba2 backbone + shared attention block with
per-invocation LoRA. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    use_rope=True,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_period=6,
    shared_lora_rank=128,
    citation="arXiv:2411.15242",
)
