"""PaliGemma-3B language backbone: SigLIP frontend is a STUB (patch
embeddings supplied by input_specs). [arXiv:2407.07726]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    use_rope=True,
    num_image_tokens=256,
    tie_embeddings=True,
    citation="arXiv:2407.07726 (SigLIP + Gemma)",
)
