"""Whisper-base: enc-dec; mel+conv frontend is a STUB (frame embeddings
supplied by input_specs). 6 encoder + 6 decoder layers. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    use_rope=False,  # learned absolute positions
    enc_layers=6,
    enc_seq_len=1500,
    citation="arXiv:2212.04356",
)
