from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    list_configs,
    reduced,
)
