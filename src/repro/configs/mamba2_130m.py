"""Mamba2-130m: pure SSM, SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    use_rope=False,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    citation="arXiv:2405.21060",
)
