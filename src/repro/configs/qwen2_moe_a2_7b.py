"""Qwen1.5-MoE-A2.7B: 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    qkv_bias=True,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
