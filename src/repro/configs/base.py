"""Config system: model architecture + input shapes + run settings.

Every assigned architecture gets one ``configs/<id>.py`` exporting CONFIG.
``get_config(name)`` resolves by module name; ``reduced(cfg)`` produces the
CPU smoke-test variant of the same family (<=2 layers, d_model<=512,
<=4 experts) required by the brief.

``TrainSettings`` is the run-settings half: optimizer hyperparams + the
gradient-sync knobs (fused_update / bucket_bytes / num_rings), lowered to
a ``core.hierarchy.SyncConfig`` + ``optim.sgd`` optimizer pair. The
worker entry point (``repro.launch.train`` main — what the launcher's
emitted ``mpirun`` commands run) builds its sync/optimizer through it, so
the JobSpec flags and the in-process config cannot drift.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import InitVar, dataclass, field
from typing import Optional, Tuple

from repro.core.comm import CollectivePolicy, filter_mirrors, resolve_policy

#: the flat-field defaults TrainSettings historically shipped (wire_dtype
#: "f32" is the flag-spelling of the plain wire) — the base point the
#: deprecation shim resolves non-default flat kwargs against
_TRAIN_BASE = CollectivePolicy(method="psum", num_rings=2)

VOCAB_PAD = 256  # pad vocab so 16-way model axis always divides embeddings


def pad_vocab(v: int, multiple: int = VOCAB_PAD) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Frozen: derive variants with replace()."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention flavour ---
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 10000.0
    use_rope: bool = True
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert FFN width (d_ff is dense width if mixed)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (zamba2-style) ---
    attn_period: int = 0  # shared attention block every N backbone layers
    shared_lora_rank: int = 0
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0  # >0 => enc-dec; num_layers is decoder depth
    enc_seq_len: int = 1500  # stub audio frame count
    # --- VLM ---
    num_image_tokens: int = 0  # stub patch-embedding count
    # --- misc ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    citation: str = ""
    # --- lowering/perf knobs (not architecture) ---
    unroll_layers: bool = False    # python-unroll layer stacks (dry-run: exact
                                   # HLO op counts; XLA cost_analysis ignores
                                   # while-loop trip counts)
    seq_shard_activations: bool = False  # Megatron-style sequence parallelism:
                                   # shard the residual stream's seq dim over
                                   # 'model' between blocks (memory-term lever)
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def is_enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or sliding-window attention."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks). Used for roofline
        MODEL_FLOPS = 6*N*D; matches init to within tying/bias noise."""
        d, v = self.d_model, self.padded_vocab
        h = self.resolved_head_dim
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        def attn_params() -> int:
            q = d * self.num_heads * h
            kv = 2 * d * self.num_kv_heads * h
            o = self.num_heads * h * d
            b = (self.num_heads + 2 * self.num_kv_heads) * h if self.qkv_bias else 0
            return q + kv + o + b
        def dense_ffn(width: int) -> int:
            return 3 * d * width  # SwiGLU/GeGLU: gate+up+down
        def moe_ffn() -> int:
            routed = self.num_experts * 3 * d * self.moe_d_ff
            shared = self.num_shared_experts * 3 * d * self.moe_d_ff
            router = d * self.num_experts
            return routed + shared + router
        def mamba_params() -> int:
            di = self.ssm_expand * d
            heads = di // self.ssm_head_dim
            in_proj = d * (2 * di + 2 * self.ssm_state + heads)
            conv = self.ssm_conv_width * (di + 2 * self.ssm_state)
            out = di * d
            return in_proj + conv + out + 2 * heads + di
        if self.arch_type == "ssm":
            n += self.num_layers * (mamba_params() + d)
        elif self.arch_type == "hybrid":
            shared_blocks = 1
            n += shared_blocks * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
            n += self.num_layers * (mamba_params() + d)
            if self.attn_period:
                n_inv = self.num_layers // self.attn_period
                r = self.shared_lora_rank
                if r:
                    n += n_inv * 3 * (d * r + r * d)
        elif self.arch_type == "moe":
            per = attn_params() + moe_ffn() + 2 * d
            n += self.num_layers * per
        else:  # dense / vlm / audio backbones
            per = attn_params() + dense_ffn(self.d_ff) + 2 * d
            n += self.num_layers * per
            if self.is_enc_dec:
                # cross-attention + encoder stack (whisper MLP has no gate)
                n -= self.num_layers * d * self.d_ff  # dec ffn: 2dw not 3dw
                n += self.num_layers * attn_params()
                n += self.enc_layers * (attn_params() + 2 * d * self.d_ff + 2 * d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k routed)."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * (
            (self.num_experts - 0) * 3 * d * self.moe_d_ff
        )
        active_routed = self.num_layers * self.top_k * 3 * d * self.moe_d_ff
        return dense + active_routed


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


@dataclass(frozen=True)
class TrainSettings:
    """Run settings: what a job spec ships alongside the architecture.

    The collective policy — allreduce method, ring count, bucketing, wire
    protocol, overlap — is ONE ``CollectivePolicy``: pass ``policy=`` and
    read ``.policy``. The flat fields remain as mirrors of the resolved
    policy for one release (the ``comm.resolve_policy`` shim warns when
    they change it); ``sync_config()`` lowers the policy object straight
    into ``SyncConfig(policy=...)`` so the two layers cannot drift.
    """

    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    # optimizer family: all three lower onto the fused flat path
    # (core/sync_engine.flat_update_supported) when fused_update is set
    optimizer_name: str = "sgd"     # "sgd" | "adagrad" | "adamw"
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    adagrad_eps: float = 1e-10
    sync_mode: str = "mpi_sgd"      # "mpi_sgd" | "mpi_esgd"
    num_clients: int = 1
    esgd_alpha: float = 0.5
    esgd_interval: int = 64
    allreduce_method: str = "psum"
    num_rings: int = 2
    # sharded fused step: reduce-scatter -> shard-local fused momentum-SGD
    # Pallas kernel (sharded momentum) -> allgather (launch/train.py)
    fused_update: bool = True
    # flat elastic leg: the ESGD exchange packed through the FlatBuffer
    # + ONE fused Pallas kernel instead of per-leaf tree.maps
    flat_exchange: bool = True
    bucket_bytes: Optional[int] = None
    # low-precision wire protocol on the explicit ring hops ("f32" off,
    # "bf16" cast per hop, "int8" codes + per-bucket scales); requires a
    # ring-family allreduce_method (SyncConfig.validate enforces it)
    wire_dtype: str = "f32"
    # flat optimizer-state stream dtype ("f32" | "bf16"): bf16 halves the
    # AdaGrad accumulator / AdamW m+v bytes per device on top of the 1/p
    # sharding (the fused kernels compute f32 per tile either way). For
    # SGD a bf16 momentum keeps the per-leaf path that honors it.
    state_dtype: str = "f32"
    fsdp: bool = False
    # backward-overlapped bucketed reduce-scatter: issue each schedule
    # bucket's ring leg mid-backward (SyncConfig.overlap); forces
    # num_rings=1 in the lowered config — the buckets are the schedules
    overlap: bool = False
    overlap_buckets: int = 4
    microbatch: int = 1
    # deterministic fault schedule (core/faults.py compact string form,
    # e.g. "kill@12:unit=1;straggle@0:unit=3:factor=4"); "" = clean run
    faults: str = ""
    # sync-barrier graceful degradation: seconds past a round's first
    # arrival before the PS barrier releases with the survivor group
    # (None blocks forever — required for kill/drop fault schedules)
    barrier_timeout: Optional[float] = None
    # crash recovery: durable checkpoint cadence in steps (0 = none)
    # and the checkpoint path to restore params/opt-state/step from
    # before stepping ("" = fresh init) — launch/train.py threads both
    checkpoint_every: int = 0
    restore: str = ""
    # internal bookkeeping: the policy the mirror knobs were backfilled
    # from (dataclasses.replace passes it back so __post_init__ can tell
    # an explicitly changed mirror from one restating the previous
    # policy). Never pass it yourself.
    policy_src: Optional[CollectivePolicy] = field(
        default=None, repr=False, compare=False)
    # -- the ONE policy field (canonical; the flat knobs above mirror it) --
    policy: InitVar[Optional[CollectivePolicy]] = None

    def __post_init__(self, policy: Optional[CollectivePolicy]) -> None:
        defaults = {"method": "psum", "num_rings": 2, "bucket_bytes": None,
                    "wire_dtype": "f32", "overlap": False,
                    "overlap_buckets": 4}
        flat = {
            "method": self.allreduce_method, "num_rings": self.num_rings,
            "bucket_bytes": self.bucket_bytes, "wire_dtype": self.wire_dtype,
            "overlap": self.overlap, "overlap_buckets": self.overlap_buckets,
        }
        # only knobs the caller moved off the field defaults (or, on a
        # replace() round-trip, off the previous policy) count as "passed"
        flat = filter_mirrors(flat, defaults=defaults,
                              prior=self.policy_src)
        if policy is None and flat.get("overlap"):
            # historical lowering: overlap forces a single ring schedule
            flat["num_rings"] = 1
        pol = resolve_policy(policy, flat, base=_TRAIN_BASE,
                             where="TrainSettings")
        object.__setattr__(self, "policy", pol)
        object.__setattr__(self, "policy_src", pol)
        object.__setattr__(self, "allreduce_method", pol.method)
        object.__setattr__(self, "num_rings", pol.num_rings)
        object.__setattr__(self, "bucket_bytes", pol.bucket_bytes)
        object.__setattr__(self, "wire_dtype", pol.wire_dtype or "f32")
        object.__setattr__(self, "overlap", pol.overlap)
        object.__setattr__(self, "overlap_buckets", pol.overlap_buckets)

    def fault_schedule(self, seed: int = 0):
        """The parsed core.faults.FaultSchedule (None when clean)."""
        from repro.core.faults import as_schedule

        return as_schedule(self.faults or None, seed)

    def sync_config(self):
        from repro.core.hierarchy import SyncConfig

        return SyncConfig(
            mode=self.sync_mode, num_clients=self.num_clients,
            esgd_alpha=self.esgd_alpha, esgd_interval=self.esgd_interval,
            fused_update=self.fused_update, flat_exchange=self.flat_exchange,
            fsdp=self.fsdp, policy=self.policy,
        )

    def _state_dtype(self):
        import jax.numpy as jnp

        if self.state_dtype not in ("f32", "bf16"):
            raise ValueError(
                f"state_dtype must be f32/bf16, got {self.state_dtype!r}")
        return None if self.state_dtype == "f32" else jnp.bfloat16

    def optimizer(self):
        from repro.optim.sgd import adagrad, adamw, sgd

        sd = self._state_dtype()
        if self.optimizer_name == "adagrad":
            if self.weight_decay:
                raise ValueError(
                    "adagrad has no weight-decay form here; drop "
                    "--weight-decay or pick sgd/adamw")
            return adagrad(self.lr, eps=self.adagrad_eps, state_dtype=sd)
        if self.optimizer_name == "adamw":
            return adamw(self.lr, b1=self.adam_b1, b2=self.adam_b2,
                         eps=self.adam_eps, weight_decay=self.weight_decay,
                         state_dtype=sd)
        if self.optimizer_name != "sgd":
            raise ValueError(
                f"optimizer_name must be sgd/adagrad/adamw, "
                f"got {self.optimizer_name!r}")
        return sgd(self.lr, momentum=self.momentum,
                   weight_decay=self.weight_decay, state_dtype=sd)


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "paligemma_3b",
    "qwen3_4b",
    "qwen2_moe_a2_7b",
    "mamba2_130m",
    "qwen2_0_5b",
    "whisper_base",
    "mixtral_8x7b",
    "zamba2_1_2b",
    "phi3_medium_14b",
    "qwen2_5_3b",
]

# CLI ids with dashes map to module names with underscores.
def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def list_configs() -> list[str]:
    return list(ARCH_IDS)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family, <=2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    hd = max(16, d // heads)
    upd = dict(
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        dtype="float32",
    )
    if cfg.arch_type == "moe":
        upd.update(num_experts=4, top_k=min(cfg.top_k, 2),
                   num_shared_experts=min(cfg.num_shared_experts, 1),
                   moe_d_ff=min(cfg.moe_d_ff, 128))
    if cfg.arch_type in ("ssm", "hybrid"):
        upd.update(ssm_state=min(cfg.ssm_state, 32), ssm_head_dim=32,
                   ssm_chunk=64)
    if cfg.arch_type == "hybrid":
        upd.update(attn_period=2, num_layers=4, shared_lora_rank=min(cfg.shared_lora_rank, 8))
    if cfg.is_enc_dec:
        upd.update(enc_layers=2, enc_seq_len=64)
    if cfg.num_image_tokens:
        upd.update(num_image_tokens=16)
    if cfg.sliding_window:
        upd.update(sliding_window=64)
    return dataclasses.replace(cfg, **upd)
