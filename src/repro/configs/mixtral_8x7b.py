"""Mixtral-8x7B: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1000000.0,
    num_experts=8,
    num_shared_experts=0,
    top_k=2,
    moe_d_ff=14336,
    citation="arXiv:2401.04088",
)
