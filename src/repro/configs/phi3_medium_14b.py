"""Phi-3-medium-14B: RoPE + SwiGLU + GQA. [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    citation="arXiv:2404.14219",
)
