"""Shared building blocks: norms, init helpers, RoPE, SwiGLU FFN."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(kg, d_model, (d_model, d_ff), dtype),
        "w_up": dense_init(ku, d_model, (d_model, d_ff), dtype),
        "w_down": dense_init(kd, d_ff, (d_ff, d_model), dtype),
    }


def ffn(params: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, params["w_down"])


def gelu_ffn(params: dict, x: jax.Array) -> jax.Array:
    """GeGLU variant (gemma/paligemma)."""
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u, params["w_down"])


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    """2-layer MLP params (whisper): up + down, no gate."""
    ku, kd = jax.random.split(key)
    return {
        "w_up": dense_init(ku, d_model, (d_model, d_ff), dtype),
        "w_down": dense_init(kd, d_ff, (d_ff, d_model), dtype),
    }


def mlp_ffn(params: dict, x: jax.Array) -> jax.Array:
    """Plain 2-layer GELU MLP (whisper): w_up/w_down, no gate."""
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(u), params["w_down"])
