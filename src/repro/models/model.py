"""Unified model API: ``build_model(cfg)`` returns a ``Model`` whose
functions cover every assigned architecture family:

  init(rng)                 -> params
  loss_fn(params, batch)    -> (loss, metrics)          [train_4k]
  forward(params, batch)    -> logits                    [prefill_32k]
  init_cache(batch, seq)    -> decode cache/state        [decode shapes]
  serve_step(params, cache, tokens) -> (logits, cache)   [one new token]
  input_specs(shape)        -> ShapeDtypeStruct batch stand-ins

Modality frontends (SigLIP patches, mel+conv frames) are stubs per the
brief: ``input_specs`` supplies embeddings of the right shape.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import ssm, transformer as tfm
from repro.models.layers import layer_norm, rms_norm
from repro.models.transformer import (
    apply_dec_layer,
    layer_scan,
    apply_enc_layer,
    apply_hybrid,
    apply_stack,
    decode_dec_layer,
    decode_hybrid,
    decode_stack,
    init_dec_layer,
    init_enc_layer,
    init_hybrid,
    init_hybrid_cache,
    init_mamba_layer,
    init_stack,
    init_stack_cache,
)

MAX_WHISPER_POSITIONS = 32768


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    forward: Callable
    init_cache: Callable
    serve_step: Callable
    input_specs: Callable
    # backward-overlap staging (optional): overlap_stages(num_buckets) ->
    # OverlapStages splitting loss_fn into a chain of stages whose param
    # subtrees become the reduce-scatter schedule buckets. None = the
    # family has no staged form yet (overlap is rejected with a pointer).
    overlap_stages: "Callable | None" = None


@dataclass(frozen=True)
class OverlapStages:
    """``loss_fn`` as a chain of stages for backward-overlapped sync.

    ``stage(params)`` splits the param tree into per-stage subtrees
    (tuple, forward order); ``fns[0](p0, batch)`` produces the first
    carry and ``fns[s](ps, carry, batch)`` the next, with the LAST stage
    returning ``(loss, metrics)`` — composing all stages reproduces
    ``loss_fn`` exactly (same ops, same order). ``unstage(tuple)``
    inverts ``stage``. A leaf used by several stages (the tied embedding:
    token lookup in stage 0, the logits einsum in the head) is a stage
    param of ONLY its earliest stage and its VALUE rides the carry to
    later stages — so each leaf lives in exactly one schedule bucket,
    and its full gradient is complete exactly when its owning stage's
    vjp runs (the carried value's cotangent flows back through the
    intermediate stages' pass-throughs).
    """

    stage: Callable
    fns: tuple
    unstage: Callable

    @property
    def num_stages(self) -> int:
        return len(self.fns)


def _embed_init(key, cfg: ModelConfig, dtype):
    v, d = cfg.padded_vocab, cfg.d_model
    emb = jax.random.normal(key, (v, d), jnp.float32).astype(dtype) * 0.02
    p = {"embedding": emb, "final_norm": jnp.zeros((d,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(jax.random.fold_in(key, 1), (d, v), jnp.float32)
            * (d ** -0.5)
        ).astype(dtype)
    return p


def _logits(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    # mask padded vocab ids
    pad = cfg.padded_vocab - cfg.vocab_size
    if pad:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _embed(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = p["embedding"][tokens]
    if cfg.arch_type == "vlm":  # gemma-style embedding scale
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    # one-hot contraction instead of take_along_axis: a gather over the
    # vocab dim would force GSPMD to all-gather vocab-sharded logits; the
    # masked-sum keeps every op elementwise/reduction over the sharded dim.
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    vocab = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(vocab)).astype(jnp.float32)
    gold = jnp.sum(logits.astype(jnp.float32) * onehot, axis=-1)
    return jnp.mean(lse - gold)


XENT_CHUNK = 512


def _sequence_xent(p: dict, h: jax.Array, labels: jax.Array,
                   cfg: ModelConfig) -> jax.Array:
    """Next-token xent from hidden states WITHOUT materializing the full
    (B, S, V) logits: scan over sequence chunks, rematerializing each
    chunk's logits in fwd and bwd. The vocab-path temps (logits, one-hot,
    dlogits — all f32) dominate train-step memory for big-vocab models
    (~11 GB/dev layer-independent on qwen3-4b × train_4k)."""
    B, S, _ = h.shape
    if S % XENT_CHUNK or S <= XENT_CHUNK:
        return _xent(_logits(p, h, cfg), labels)
    nc = S // XENT_CHUNK
    hs = jnp.moveaxis(h.reshape(B, nc, XENT_CHUNK, -1), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, XENT_CHUNK), 1, 0)

    def body(acc, inp):
        hc, lc = inp
        logits = _logits(p, hc, cfg)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        onehot = (lc[..., None] == jnp.arange(cfg.padded_vocab)
                  ).astype(jnp.float32)
        gold = jnp.sum(logits.astype(jnp.float32) * onehot, axis=-1)
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (hs, ls))
    return total / (B * S)


def build_model(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)
    builder = {
        "dense": _build_decoder,
        "vlm": _build_decoder,
        "moe": _build_decoder,
        "ssm": _build_ssm,
        "hybrid": _build_hybrid,
        "audio": _build_enc_dec,
    }[cfg.arch_type]
    return builder(cfg, dtype)


# --------------------------------------------------------------------------
# decoder-only (dense / moe / vlm)
# --------------------------------------------------------------------------

def _build_decoder(cfg: ModelConfig, dtype) -> Model:
    n_img = cfg.num_image_tokens

    def init(rng):
        k1, k2 = jax.random.split(rng)
        p = _embed_init(k1, cfg, dtype)
        p["layers"] = init_stack(k2, cfg, dtype)
        return p

    def backbone(p, x, prefix_len=0):
        x, aux = apply_stack(p["layers"], x, cfg, prefix_len=prefix_len)
        return rms_norm(x, p["final_norm"], cfg.norm_eps), aux

    def forward(p, batch):
        x = _embed(p, batch["tokens"], cfg)
        prefix = 0
        if n_img:
            x = jnp.concatenate(
                [batch["image_embeds"].astype(x.dtype), x], axis=1
            )
            prefix = n_img
        h, _ = backbone(p, x, prefix)
        return _logits(p, h, cfg)

    def loss_fn(p, batch):
        x = _embed(p, batch["tokens"], cfg)
        prefix = 0
        if n_img:
            x = jnp.concatenate(
                [batch["image_embeds"].astype(x.dtype), x], axis=1
            )
            prefix = n_img
        h, aux = backbone(p, x, prefix)
        if n_img:
            h = h[:, n_img:]
        xent = _sequence_xent(p, h, batch["labels"], cfg)
        loss = xent + aux
        return loss, {"xent": xent, "aux": aux}

    def init_cache(batch, max_seq):
        return init_stack_cache(batch, max_seq, cfg, dtype)

    def serve_step(p, cache, tokens):
        x = _embed(p, tokens, cfg)  # (B, 1, d)
        x, cache = decode_stack(p["layers"], x, cache, cfg)
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        return _logits(p, x, cfg), cache

    def input_specs(shape: InputShape):
        return _decoder_specs(cfg, shape, dtype)

    return Model(cfg, init, loss_fn, forward, init_cache, serve_step,
                 input_specs,
                 overlap_stages=_decoder_overlap_stages(cfg, loss_fn))


def _decoder_overlap_stages(cfg: ModelConfig, loss_fn) -> Callable:
    """Stage factory for the decoder family (dense / moe / vlm):
    [embed] + k layer slices + [head], where k = num_buckets - 2 clamped
    to [1, num_layers] (uneven last slice allowed — ceil split). Each
    stage replays exactly the ops ``loss_fn`` runs over that span, so
    the composed chain is bit-identical to the monolithic loss. With
    tied embeddings the embedding is stage 0's param and its VALUE rides
    the carry to the head's logits einsum (see ``OverlapStages``)."""
    n_img = cfg.num_image_tokens

    def factory(num_buckets: int) -> OverlapStages:
        if num_buckets <= 1:
            # degenerate single-bucket schedule: the whole loss is one
            # stage, the one reduce-scatter leg simply trails backward
            return OverlapStages(stage=lambda p: (p,),
                                 fns=(lambda p0, batch: loss_fn(p0, batch),),
                                 unstage=lambda parts: parts[0])
        k = min(cfg.num_layers, max(1, int(num_buckets) - 2))
        base, rem = divmod(cfg.num_layers, k)
        slices, lo = [], 0
        for i in range(k):
            hi = lo + base + (1 if i < rem else 0)
            slices.append((lo, hi))
            lo = hi

        def stage(p):
            head = {"final_norm": p["final_norm"]}
            if not cfg.tie_embeddings:
                head["lm_head"] = p["lm_head"]
            return (({"embedding": p["embedding"]},)
                    + tuple(jax.tree.map(lambda a: a[lo:hi], p["layers"])
                            for lo, hi in slices)
                    + (head,))

        def unstage(parts):
            p = {"embedding": parts[0]["embedding"],
                 "layers": jax.tree.map(
                     lambda *xs: jnp.concatenate(xs, axis=0)
                     if len(xs) > 1 else xs[0], *parts[1:-1]),
                 "final_norm": parts[-1]["final_norm"]}
            if not cfg.tie_embeddings:
                p["lm_head"] = parts[-1]["lm_head"]
            return p

        def embed_fn(p0, batch):
            x = _embed(p0, batch["tokens"], cfg)
            if n_img:
                x = jnp.concatenate(
                    [batch["image_embeds"].astype(x.dtype), x], axis=1)
            carry = {"x": x, "aux": jnp.zeros((), jnp.float32)}
            if cfg.tie_embeddings:
                carry["emb"] = p0["embedding"]
            return carry

        def layer_fn(ps, carry, batch):
            h, a = apply_stack(ps, carry["x"], cfg, prefix_len=n_img)
            out = dict(carry)
            out["x"] = h
            out["aux"] = carry["aux"] + a
            return out

        def head_fn(ph, carry, batch):
            h = rms_norm(carry["x"], ph["final_norm"], cfg.norm_eps)
            if n_img:
                h = h[:, n_img:]
            pl = ({"embedding": carry["emb"]} if cfg.tie_embeddings
                  else {"lm_head": ph["lm_head"]})
            xent = _sequence_xent(pl, h, batch["labels"], cfg)
            return xent + carry["aux"], {"xent": xent, "aux": carry["aux"]}

        fns = (embed_fn,) + (layer_fn,) * k + (head_fn,)
        return OverlapStages(stage=stage, fns=fns, unstage=unstage)

    return factory


def _decoder_specs(cfg: ModelConfig, shape: InputShape, dtype):
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": tok((B, 1), jnp.int32)}
    n_img = cfg.num_image_tokens
    text = S - n_img if n_img else S
    batch = {"tokens": tok((B, text), jnp.int32)}
    if n_img:
        batch["image_embeds"] = tok((B, n_img, cfg.d_model), dtype)
    if shape.kind == "train":
        batch["labels"] = tok((B, text), jnp.int32)
    return batch


# --------------------------------------------------------------------------
# pure SSM (mamba2)
# --------------------------------------------------------------------------

def _build_ssm(cfg: ModelConfig, dtype) -> Model:
    def init(rng):
        k1, k2 = jax.random.split(rng)
        p = _embed_init(k1, cfg, dtype)
        keys = jax.random.split(k2, cfg.num_layers)
        p["layers"] = jax.vmap(
            lambda k: {
                "norm": jnp.zeros((cfg.d_model,), dtype),
                **init_mamba_layer(k, cfg, dtype),
            }
        )(keys)
        return p

    def backbone(p, x):
        from repro.sharding.rules import maybe_seq_shard

        def body(h, layer_params):
            h = maybe_seq_shard(h, cfg.seq_shard_activations)
            norm = layer_params["norm"]
            lp = {k: v for k, v in layer_params.items() if k != "norm"}
            y, _ = ssm.mamba_block(
                lp, rms_norm(h, norm, cfg.norm_eps),
                expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                state=cfg.ssm_state, chunk=cfg.ssm_chunk,
            )
            return h + y, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = layer_scan(body_fn, x, p["layers"], cfg)
        return rms_norm(x, p["final_norm"], cfg.norm_eps)

    def forward(p, batch):
        return _logits(p, backbone(p, _embed(p, batch["tokens"], cfg)), cfg)

    def loss_fn(p, batch):
        h = backbone(p, _embed(p, batch["tokens"], cfg))
        loss = _sequence_xent(p, h, batch["labels"], cfg)
        return loss, {"xent": loss}

    def init_cache(batch, max_seq):
        h, conv = ssm.init_mamba_state(
            batch, cfg.d_model, expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
            conv_width=cfg.ssm_conv_width, dtype=dtype,
        )
        return {
            "h": jnp.broadcast_to(h, (cfg.num_layers,) + h.shape).copy(),
            "conv": jnp.broadcast_to(conv, (cfg.num_layers,) + conv.shape).copy(),
        }

    def serve_step(p, cache, tokens):
        x = _embed(p, tokens, cfg)

        def body(h, inp):
            layer_params, st = inp
            norm = layer_params["norm"]
            lp = {k: v for k, v in layer_params.items() if k != "norm"}
            y, (hs, cs) = ssm.mamba_decode(
                lp, rms_norm(h, norm, cfg.norm_eps), st["h"], st["conv"],
                expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                state=cfg.ssm_state,
            )
            return h + y, {"h": hs, "conv": cs}

        x, cache = layer_scan(body, x, (p["layers"], cache), cfg,
                              with_out=True)
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        return _logits(p, x, cfg), cache

    def input_specs(shape: InputShape):
        return _decoder_specs(cfg, shape, dtype)

    return Model(cfg, init, loss_fn, forward, init_cache, serve_step, input_specs)


# --------------------------------------------------------------------------
# hybrid (zamba2)
# --------------------------------------------------------------------------

def _build_hybrid(cfg: ModelConfig, dtype) -> Model:
    def init(rng):
        k1, k2 = jax.random.split(rng)
        p = _embed_init(k1, cfg, dtype)
        p.update(init_hybrid(k2, cfg, dtype))
        return p

    def forward(p, batch):
        x = _embed(p, batch["tokens"], cfg)
        x, _ = apply_hybrid(p, x, cfg)
        return _logits(p, rms_norm(x, p["final_norm"], cfg.norm_eps), cfg)

    def loss_fn(p, batch):
        x = _embed(p, batch["tokens"], cfg)
        x, _ = apply_hybrid(p, x, cfg)
        h = rms_norm(x, p["final_norm"], cfg.norm_eps)
        loss = _sequence_xent(p, h, batch["labels"], cfg)
        return loss, {"xent": loss}

    def init_cache(batch, max_seq):
        return init_hybrid_cache(batch, max_seq, cfg, dtype)

    def serve_step(p, cache, tokens):
        x = _embed(p, tokens, cfg)
        x, cache = decode_hybrid(p, x, cache, cfg)
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        return _logits(p, x, cfg), cache

    def input_specs(shape: InputShape):
        return _decoder_specs(cfg, shape, dtype)

    return Model(cfg, init, loss_fn, forward, init_cache, serve_step, input_specs)


# --------------------------------------------------------------------------
# encoder-decoder (whisper): conv/mel frontend stubbed as frame embeddings
# --------------------------------------------------------------------------

def _build_enc_dec(cfg: ModelConfig, dtype) -> Model:
    def init(rng):
        ks = jax.random.split(rng, 4)
        p = _embed_init(ks[0], cfg, dtype)
        p["final_norm_b"] = jnp.zeros((cfg.d_model,), dtype)
        p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["enc_pos"] = {
            "pos_embedding": jax.random.normal(
                ks[1], (cfg.enc_seq_len, cfg.d_model), jnp.float32
            ).astype(dtype) * 0.02
        }
        p["dec_pos"] = {
            "pos_embedding": jax.random.normal(
                ks[2], (MAX_WHISPER_POSITIONS, cfg.d_model), jnp.float32
            ).astype(dtype) * 0.02
        }
        enc_keys = jax.random.split(ks[3], cfg.enc_layers)
        p["encoder"] = jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(enc_keys)
        dec_keys = jax.random.split(jax.random.fold_in(ks[3], 7), cfg.num_layers)
        p["decoder"] = jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(dec_keys)
        p["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["enc_final_norm_b"] = jnp.zeros((cfg.d_model,), dtype)
        return p

    def encode(p, frames):
        x = frames.astype(dtype) + p["enc_pos"]["pos_embedding"][: frames.shape[1]]

        def body(h, lp):
            return apply_enc_layer(lp, h, cfg), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = layer_scan(body_fn, x, p["encoder"], cfg)
        return layer_norm(x, p["enc_final_norm"], p["enc_final_norm_b"])

    def decode_full(p, enc, tokens):
        x = p["embedding"][tokens]
        x = x + p["dec_pos"]["pos_embedding"][: tokens.shape[1]]

        def body(h, lp):
            return apply_dec_layer(lp, h, enc, cfg), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = layer_scan(body_fn, x, p["decoder"], cfg)
        return layer_norm(x, p["final_norm"], p["final_norm_b"])

    def forward(p, batch):
        enc = encode(p, batch["audio_frames"])
        return _logits(p, decode_full(p, enc, batch["tokens"]), cfg)

    def loss_fn(p, batch):
        enc = encode(p, batch["audio_frames"])
        h = decode_full(p, enc, batch["tokens"])
        loss = _sequence_xent(p, h, batch["labels"], cfg)
        return loss, {"xent": loss}

    def init_cache(batch, max_seq):
        cache = init_stack_cache(batch, max_seq, cfg, dtype)
        cache = jax.tree.map(lambda a: a, cache)
        return {
            "self": cache,
            "enc": jnp.zeros((batch, cfg.enc_seq_len, cfg.d_model), dtype),
        }

    def serve_step(p, cache, tokens):
        idx = cache["self"]["index"][0]
        x = p["embedding"][tokens]
        x = x + jax.lax.dynamic_slice_in_dim(
            p["dec_pos"]["pos_embedding"], idx, 1, axis=0
        )

        def body(h, inp):
            lp, c = inp
            h, c = decode_dec_layer(lp, h, cache["enc"], c, cfg)
            return h, c

        x, new_self = layer_scan(body, x, (p["decoder"], cache["self"]), cfg,
                                 with_out=True)
        x = layer_norm(x, p["final_norm"], p["final_norm_b"])
        return _logits(p, x, cfg), {"self": new_self, "enc": cache["enc"]}

    def input_specs(shape: InputShape):
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct
        if shape.kind == "decode":
            return {"tokens": tok((B, 1), jnp.int32)}
        batch = {
            "tokens": tok((B, S), jnp.int32),
            "audio_frames": tok((B, cfg.enc_seq_len, cfg.d_model), dtype),
        }
        if shape.kind == "train":
            batch["labels"] = tok((B, S), jnp.int32)
        return batch

    return Model(cfg, init, loss_fn, forward, init_cache, serve_step, input_specs)
