"""Compact ResNet (He et al. 2015) — the paper's model family (it trains
ResNet-50 on ImageNet 1K). Used by the convergence benchmarks (Figs 11/13/14)
at laptop scale on synthetic image data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.resnet50_cifar import ResNetConfig


def _conv_init(key, shape):
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _gn(x, scale, bias, groups=8):
    """GroupNorm: batch-independent (async workers see different batches)."""
    B, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xg.reshape(B, H, W, C) * scale + bias).astype(x.dtype)


def _block_plan(cfg: ResNetConfig):
    """Static (stride, c_in, c_out) per block — kept out of the param tree."""
    plan, c_in = [], cfg.width
    for stage, n in enumerate(cfg.stage_sizes):
        c_out = cfg.width * (2 ** stage)
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            plan.append((stride, c_in, c_out))
            c_in = c_out
    return plan, c_in


def init_resnet(key, cfg: ResNetConfig) -> dict:
    keys = iter(jax.random.split(key, 64))
    w = cfg.width
    p = {"stem": _conv_init(next(keys), (3, 3, 3, w)),
         "stem_s": jnp.ones((w,)), "stem_b": jnp.zeros((w,))}
    plan, c_final = _block_plan(cfg)
    blocks = []
    for stride, c_in, c_out in plan:
        blk = {
            "c1": _conv_init(next(keys), (3, 3, c_in, c_out)),
            "s1": jnp.ones((c_out,)), "b1": jnp.zeros((c_out,)),
            "c2": _conv_init(next(keys), (3, 3, c_out, c_out)),
            "s2": jnp.ones((c_out,)), "b2": jnp.zeros((c_out,)),
        }
        if stride != 1 or c_in != c_out:
            blk["proj"] = _conv_init(next(keys), (1, 1, c_in, c_out))
        blocks.append(blk)
    p["blocks"] = blocks
    p["head"] = jax.random.normal(next(keys), (c_final, cfg.num_classes)) * 0.01
    p["head_b"] = jnp.zeros((cfg.num_classes,))
    return p


def resnet_apply(p: dict, images: jax.Array, cfg: ResNetConfig) -> jax.Array:
    x = jax.nn.relu(_gn(_conv(images, p["stem"]), p["stem_s"], p["stem_b"]))
    plan, _ = _block_plan(cfg)
    for blk, (stride, _, _) in zip(p["blocks"], plan):
        h = jax.nn.relu(_gn(_conv(x, blk["c1"], stride), blk["s1"], blk["b1"]))
        h = _gn(_conv(h, blk["c2"]), blk["s2"], blk["b2"])
        sc = _conv(x, blk["proj"], stride) if "proj" in blk else x
        x = jax.nn.relu(h + sc)
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head"] + p["head_b"]


def resnet_loss(p: dict, batch: dict, cfg: ResNetConfig) -> tuple[jax.Array, dict]:
    logits = resnet_apply(p, batch["images"], cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}
