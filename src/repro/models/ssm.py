"""Mamba2 / SSD (state-space duality) blocks. [arXiv:2405.21060]

Train/prefill uses the chunked SSD algorithm (quadratic inside chunks of
``ssm_chunk`` tokens, linear recurrence across chunk states); decode is the
O(1)-per-token recurrent update. ``ssd_recurrent_ref`` is the sequential
oracle used by tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q, H) -> (..., H, Q, Q) lower-tri pairwise sums
    S[i,j] = sum_{j < s <= i} dA[s]."""
    q = dA.shape[-2]
    cs = jnp.cumsum(dA, axis=-2)  # (..., Q, H)
    cs = jnp.moveaxis(cs, -1, -2)  # (..., H, Q)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # (B, L, H, P)
    dt: jax.Array,     # (B, L, H)  already softplus'ed
    A: jax.Array,      # (H,) negative
    Bm: jax.Array,     # (B, L, N)
    Cm: jax.Array,     # (B, L, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
):
    """Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    dA = dtc * A.astype(f32)  # (b,c,q,h)
    dAcs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk

    # 1) diagonal (intra-chunk) blocks
    Ltri = jnp.exp(_segsum(dA))  # (b,c,h,q,s)
    xdt = xc * dtc[..., None]  # (b,c,s,h,p)
    y_diag = jnp.einsum("bcqn,bcsn,bchqs,bcshp->bcqhp", Cc, Bc, Ltri, xdt)

    # 2) per-chunk output states
    decay = jnp.exp(dAcs[:, :, -1:, :] - dAcs)  # (b,c,q,h)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay * dtc, xc)

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(dAcs[:, :, -1, :])  # (b,c,h)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)
    else:
        h0 = h0.astype(f32)

    def body(h, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        prev = h
        h = h * dec[..., None, None] + st
        return h, prev

    h_final, prev_states = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,h,p,n)

    # 4) state -> output contribution
    y_off = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(dAcs), prev_states
    )
    y = (y_diag + y_off).reshape(Bsz, Lp, H, P)[:, :L]
    return y.astype(x.dtype), h_final


def ssd_recurrent_ref(x, dt, A, Bm, Cm, h0=None):
    """Sequential oracle: one recurrent step per token."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (b,h,p), (b,h), (b,n), (b,n)
        dec = jnp.exp(dtt * A.astype(f32))  # (b,h)
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt.astype(f32), bt.astype(f32)
        )
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(f32))
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """One token: x (B,H,P), dt (B,H), Bm/Cm (B,N), h (B,H,P,N)."""
    f32 = jnp.float32
    dec = jnp.exp(dt.astype(f32) * A.astype(f32))
    h = h * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt.astype(f32), x.astype(f32), Bm.astype(f32)
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(f32))
    return y.astype(x.dtype), h


# --------------------------------------------------------------------------
# Full Mamba2 block (in_proj -> causal conv -> SSD -> gated norm -> out_proj)
# --------------------------------------------------------------------------

def mamba_dims(d_model: int, expand: int, head_dim: int, state: int):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    conv_dim = d_inner + 2 * state
    return d_inner, nheads, conv_dim


def init_mamba(key, d_model: int, *, expand: int, head_dim: int, state: int,
               conv_width: int, dtype) -> dict:
    d_inner, nheads, conv_dim = mamba_dims(d_model, expand, head_dim, state)
    k1, k2, k3 = jax.random.split(key, 3)
    proj_out = 2 * d_inner + 2 * state + nheads  # z, x, B, C, dt
    return {
        "in_proj": dense_init(k1, d_model, (d_model, proj_out), dtype),
        "conv_w": dense_init(k2, conv_width, (conv_width, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "ssm_norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(k3, d_inner, (d_inner, d_model), dtype),
    }


def _split_proj(proj, d_inner, state, nheads):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : 2 * d_inner + 2 * state]
    dt = proj[..., 2 * d_inner + 2 * state :]
    return z, xbc, dt


def causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """xbc: (B, L, C); depthwise causal conv, width K."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba_block(params: dict, x: jax.Array, *, expand: int, head_dim: int,
                state: int, chunk: int, h0=None, conv0=None):
    """x: (B, L, d). Returns (out, (h_final, conv_state))."""
    B, L, d = x.shape
    d_inner, nheads, conv_dim = mamba_dims(d, expand, head_dim, state)
    proj = jnp.einsum("bld,dp->blp", x, params["in_proj"])
    z, xbc, dt = _split_proj(proj, d_inner, state, nheads)
    if conv0 is not None:
        xbc_in = jnp.concatenate([conv0, xbc], axis=1)
        conv_out = causal_conv(xbc_in, params["conv_w"], params["conv_b"])
        conv_out = conv_out[:, conv0.shape[1] :]
    else:
        conv_out = causal_conv(xbc, params["conv_w"], params["conv_b"])
    K = params["conv_w"].shape[0]
    conv_state = (
        jnp.concatenate([conv0, xbc], axis=1)[:, -(K - 1) :]
        if conv0 is not None
        else jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1) :]
    )
    xs = conv_out[..., :d_inner].reshape(B, L, nheads, head_dim)
    Bm = conv_out[..., d_inner : d_inner + state]
    Cm = conv_out[..., d_inner + state :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h = ssd_chunked(xs, dt, A, Bm, Cm, chunk, h0=h0)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(B, L, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["ssm_norm"])
    out = jnp.einsum("bli,id->bld", y, params["out_proj"])
    return out, (h, conv_state)


def mamba_decode(params: dict, x: jax.Array, ssm_state, conv_state, *,
                 expand: int, head_dim: int, state: int):
    """x: (B, 1, d). conv_state: (B, K-1, conv_dim). Returns (out, states)."""
    B, _, d = x.shape
    d_inner, nheads, conv_dim = mamba_dims(d, expand, head_dim, state)
    proj = jnp.einsum("bld,dp->blp", x, params["in_proj"])
    z, xbc, dt = _split_proj(proj, d_inner, state, nheads)
    window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, K, conv)
    w = params["conv_w"]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"]
    )[:, None]
    new_conv_state = window[:, 1:]
    xs = conv_out[..., :d_inner].reshape(B, nheads, head_dim)
    Bm = conv_out[:, 0, d_inner : d_inner + state]
    Cm = conv_out[:, 0, d_inner + state :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h = ssd_decode_step(ssm_state, xs, dt, A, Bm, Cm)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xs
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["ssm_norm"])
    out = jnp.einsum("bli,id->bld", y, params["out_proj"])
    return out, (h, new_conv_state)


def init_mamba_state(batch: int, d_model: int, *, expand: int, head_dim: int,
                     state: int, conv_width: int, dtype):
    d_inner, nheads, conv_dim = mamba_dims(d_model, expand, head_dim, state)
    h = jnp.zeros((batch, nheads, head_dim, state), jnp.float32)
    conv = jnp.zeros((batch, conv_width - 1, conv_dim), dtype)
    return h, conv
