"""Attention: GQA with RoPE / qk-norm / QKV-bias / sliding-window / prefix-LM,
memory-efficient chunked softmax for long sequences, and KV-cache decode.

The train/prefill path unrolls query chunks at the Python level so each
chunk attends to a *statically truncated* KV range (triangular skipping —
no FLOPs spent on fully-masked blocks), and scans over KV blocks inside a
chunk with a running (max, sum, acc) — flash-attention structure in pure
jnp, which both bounds memory and lowers on any backend.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


@dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0
    use_rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    prefix_len: int = 0  # prefix-LM: first N positions attend bidirectionally


def init_attention(key, d_model: int, spec: AttnSpec, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    p = {
        "wq": dense_init(kq, d_model, (d_model, h * hd), dtype),
        "wk": dense_init(kk, d_model, (d_model, kvh * hd), dtype),
        "wv": dense_init(kv, d_model, (d_model, kvh * hd), dtype),
        "wo": dense_init(ko, h * hd, (h * hd, d_model), dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(params, x, x_kv, spec: AttnSpec, positions, kv_positions):
    B = x.shape[0]
    h, kvh, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x_kv, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x_kv, params["wv"])
    if spec.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, -1, h, hd)
    k = k.reshape(B, -1, kvh, hd)
    v = v.reshape(B, -1, kvh, hd)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, kv_positions, spec.rope_theta)
    return q, k, v


def _block_mask(qpos, kpos, spec: AttnSpec):
    """(qc, kc) bool mask of allowed attention."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if spec.causal:
        causal = kpos[None, :] <= qpos[:, None]
        if spec.prefix_len > 0:
            causal = causal | (kpos[None, :] < spec.prefix_len)
        m = m & causal
    if spec.sliding_window > 0:
        m = m & (kpos[None, :] > qpos[:, None] - spec.sliding_window)
    return m


def _chunk_attend(q, k, v, qpos0: int, spec: AttnSpec, kv_chunk: int,
                  kv_valid: Optional[jax.Array] = None):
    """Flash-style scan over KV blocks for one query chunk.

    q: (B, qc, KV, G, D); k/v: (B, Sk, KV, D). Returns (B, qc, KV, G, D).
    """
    B, qc, KV, G, D = q.shape
    Sk = k.shape[1]
    nkv = max(1, math.ceil(Sk / kv_chunk))
    pad = nkv * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nkv, kv_chunk, KV, D)
    vb = v.reshape(B, nkv, kv_chunk, KV, D)
    kb = jnp.moveaxis(kb, 1, 0)  # (nkv, B, kc, KV, D)
    vb = jnp.moveaxis(vb, 1, 0)
    qpos = qpos0 + jnp.arange(qc)
    scale = 1.0 / math.sqrt(D)

    def body(carry, blk):
        m_run, l_run, acc = carry
        kblk, vblk, bidx = blk
        kpos = bidx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kblk).astype(jnp.float32) * scale
        mask = _block_mask(qpos, kpos, spec)
        mask = mask & (kpos[None, :] < Sk)
        if kv_valid is not None:
            mask = mask & (kpos[None, :] < kv_valid)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
    a0 = jnp.zeros((B, KV, G, qc, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nkv))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B, qc, KV, G, D)


def multi_head_attention(
    params: dict,
    x: jax.Array,
    spec: AttnSpec,
    *,
    x_kv: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, Sq, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    Sk = x_kv.shape[1]
    if positions is None:
        positions = jnp.arange(Sq)[None, :]
    kv_positions = jnp.arange(Sk)[None, :]
    q, k, v = _project_qkv(params, x, x_kv, spec, positions, kv_positions)
    KV, G = spec.num_kv_heads, spec.num_heads // spec.num_kv_heads
    q = q.reshape(B, Sq, KV, G, spec.head_dim)

    nq = max(1, math.ceil(Sq / q_chunk))
    outs = []
    # checkpoint each q-chunk: the inner scan's per-step (m, l, acc) f32
    # carries are otherwise saved for the backward pass — measured
    # ~4.3 GB/layer on qwen3-4b × train_4k; recomputing them per chunk
    # bounds the residuals to one chunk's worth
    attend = jax.checkpoint(
        lambda qi, ki, vi, off, sp: _chunk_attend(qi, ki, vi, off, sp, kv_chunk),
        static_argnums=(3, 4),
    )
    for i in range(nq):  # python unroll: static triangular KV truncation
        lo, hi = i * q_chunk, min((i + 1) * q_chunk, Sq)
        qi = q[:, lo:hi]
        if spec.causal and spec.prefix_len == 0:
            k_hi = hi  # blocks past the diagonal are statically skipped
            k_lo = 0
            if spec.sliding_window > 0:
                k_lo = max(0, (lo - spec.sliding_window) // kv_chunk * kv_chunk)
        else:
            k_lo, k_hi = 0, Sk
        sub = attend(
            qi, k[:, k_lo:k_hi], v[:, k_lo:k_hi], lo - k_lo,
            _shift_spec(spec, k_lo),
        )
        outs.append(sub)
    out = jnp.concatenate(outs, axis=1).reshape(B, Sq, spec.num_heads * spec.head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"])


def _shift_spec(spec: AttnSpec, k_lo: int) -> AttnSpec:
    if k_lo == 0 or spec.prefix_len == 0:
        return spec
    import dataclasses

    return dataclasses.replace(spec, prefix_len=max(0, spec.prefix_len - k_lo))


# --------------------------------------------------------------------------
# KV-cache decode
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, max_seq: int, spec: AttnSpec, dtype) -> dict:
    """Sliding-window specs allocate only a window-sized rolling buffer."""
    size = min(max_seq, spec.sliding_window) if spec.sliding_window else max_seq
    shape = (batch, size, spec.num_kv_heads, spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def decode_attention(
    params: dict, x: jax.Array, cache: dict, spec: AttnSpec
) -> tuple[jax.Array, dict]:
    """One-token decode: x (B, 1, d). Returns (out (B, 1, d), new cache)."""
    B = x.shape[0]
    idx = cache["index"]
    pos = idx[None, None]  # (1,1) broadcast position of the new token
    q, k_new, v_new = _project_qkv(params, x, x, spec, pos, pos)
    size = cache["k"].shape[1]
    slot = jnp.where(spec.sliding_window > 0, idx % size, jnp.minimum(idx, size - 1))
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    KV, G, D = spec.num_kv_heads, spec.num_heads // spec.num_kv_heads, spec.head_dim
    q = q.reshape(B, 1, KV, G, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k_cache).astype(jnp.float32) * scale
    slots = jnp.arange(size)
    if spec.sliding_window > 0:
        # rolling buffer: a slot is valid if written within the last `size`
        # steps (including the token just inserted at `slot`).
        age = (slot - slots) % size
        valid = age <= jnp.minimum(idx, size - 1)
    else:
        valid = slots <= idx
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(x.dtype), v_cache)
    out = out.reshape(B, 1, spec.num_heads * D)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return out, {"k": k_cache, "v": v_cache, "index": idx + 1}


def reference_attention(params, x, spec: AttnSpec, x_kv=None) -> jax.Array:
    """O(S^2) oracle used by tests."""
    B, Sq, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    Sk = x_kv.shape[1]
    q, k, v = _project_qkv(
        params, x, x_kv, spec,
        jnp.arange(Sq)[None, :], jnp.arange(Sk)[None, :],
    )
    KV, G, D = spec.num_kv_heads, spec.num_heads // spec.num_kv_heads, spec.head_dim
    q = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) / math.sqrt(D)
    mask = _block_mask(jnp.arange(Sq), jnp.arange(Sk), spec)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(x.dtype), v)
    return jnp.einsum(
        "bsh,hd->bsd", out.reshape(B, Sq, spec.num_heads * D), params["wo"]
    )
