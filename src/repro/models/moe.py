"""Mixture-of-Experts block: top-k router, shared + routed experts,
capacity-based sort/scatter dispatch (exact active FLOPs — no dense
all-experts compute), load-balance auxiliary loss.

Expert weights are stacked ``(E, d, f)`` and tensor-parallel on the ``f``
dim (the 16-way `model` axis divides neither 60 nor 8 experts — see
DESIGN.md §4), so the grouped einsums shard without an all-to-all.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, ffn


def init_moe(key, d_model: int, num_experts: int, num_shared: int,
             moe_d_ff: int, dtype) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, d_model, (d_model, num_experts), jnp.float32),
        "moe_gate": dense_init(kg, d_model, (num_experts, d_model, moe_d_ff), dtype),
        "moe_up": dense_init(ku, d_model, (num_experts, d_model, moe_d_ff), dtype),
        "moe_down": dense_init(kd, moe_d_ff, (num_experts, moe_d_ff, d_model), dtype),
    }
    if num_shared:
        # shared experts fused into one wide always-on FFN
        from repro.models.layers import init_ffn

        p["shared"] = init_ffn(ks, d_model, num_shared * moe_d_ff, dtype)
    return p


def _dispatch_indices(expert_idx: jax.Array, num_experts: int, capacity: int):
    """expert_idx: (T*K,) flat expert assignment. Returns (slot, keep)."""
    tk = expert_idx.shape[0]
    order = jnp.argsort(expert_idx, stable=True)
    sorted_e = expert_idx[order]
    # position of each entry within its expert's contiguous run
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    pos_in_e = jnp.arange(tk) - starts[sorted_e]
    keep = pos_in_e < capacity
    # invert the sort to get per-(token,k) slot assignments
    slot = jnp.zeros((tk,), jnp.int32).at[order].set(pos_in_e.astype(jnp.int32))
    keep = jnp.zeros((tk,), bool).at[order].set(keep)
    return slot, keep


def _expert_extra(E: int) -> tuple:
    """On an expert-parallel mesh, also pin the E dim of the dispatch
    buffers to the 'expert' axis: the scatter->einsum reshard lowers to
    an all-to-all (token routing) instead of TP psums."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh and "expert" in mesh.shape and E % mesh.shape["expert"] == 0:
            return ("expert",)
    except Exception:
        pass
    return ()


def moe_block(params: dict, x: jax.Array, *, num_experts: int, top_k: int,
              capacity_factor: float, aux_weight: float,
              deterministic_capacity: Optional[int] = None):
    """x: (B, S, d). Returns (out, aux_loss).

    Dispatch is per batch row (capacity ∝ S) with all heavy tensors
    carrying an explicit leading B dim constrained to the data axes
    (sharding/rules.shard_batch_dim): scatter/gather are *batched* ops the
    partitioner keeps sharded. A global sort/scatter over B·S tokens, or
    the same logic hidden under vmap, makes GSPMD replicate 40+GB dispatch
    buffers per layer (measured on qwen2-moe × train_4k).
    """
    from repro.sharding.rules import shard_batch_dim

    B, S, d = x.shape
    E, K = num_experts, top_k
    capacity = deterministic_capacity or max(
        K, int(math.ceil(S * K * capacity_factor / E))
    )
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (B, S, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(B, S * K)
    slot, keep = jax.vmap(
        lambda fe: _dispatch_indices(fe, E, capacity))(flat_e)
    safe_slot = jnp.where(keep, slot, capacity - 1)

    # batched scatter into (B, E, C, d) expert buffers (drops skipped)
    tok_ids = jnp.repeat(jnp.arange(S), K)  # (S*K,) same for every row
    b_idx = jnp.arange(B)[:, None]
    vals = jnp.where(keep[..., None], x[:, tok_ids], 0).astype(x.dtype)
    buf = jnp.zeros((B, E, capacity, d), x.dtype)
    buf = buf.at[b_idx, flat_e, safe_slot].add(vals)
    buf = shard_batch_dim(buf, extra=_expert_extra(E))

    # grouped expert FFN: (B,E,C,d) x (E,d,f)
    g = jnp.einsum("becd,edf->becf", buf, params["moe_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["moe_up"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, params["moe_down"])
    y = shard_batch_dim(y, extra=_expert_extra(E))

    # batched gather back, weight by router prob, sum over k
    gathered = y[b_idx, flat_e, safe_slot]  # (B, S*K, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    w = top_p.reshape(B, S * K)[..., None].astype(x.dtype)
    out = jnp.zeros((B, S, d), x.dtype).at[b_idx, tok_ids].add(gathered * w)
    out = shard_batch_dim(out)

    if "shared" in params:
        out = out + ffn(params["shared"], x)

    frac_tokens = jnp.mean(
        (jax.nn.one_hot(top_e, E).sum(2) > 0).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = aux_weight * E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def reference_moe(params: dict, x: jax.Array, *, num_experts: int,
                  top_k: int) -> jax.Array:
    """Dense oracle: every expert on every token, no capacity drops."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    g = jnp.einsum("td,edf->etf", xt, params["moe_gate"])
    u = jnp.einsum("td,edf->etf", xt, params["moe_up"])
    y = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, params["moe_down"])  # (E,T,d)
    w = jnp.zeros((xt.shape[0], num_experts), jnp.float32)
    w = w.at[jnp.arange(xt.shape[0])[:, None], top_e].add(top_p)
    out = jnp.einsum("te,etd->td", w.astype(x.dtype), y)
    if "shared" in params:
        out = out + ffn(params["shared"], xt)
    return out.reshape(B, S, d)
