"""Block composition: pre-norm transformer blocks (dense FFN or MoE),
scan-over-layers stacking, encoder-decoder (whisper), and the zamba2-style
hybrid backbone (Mamba2 layers + one shared attention block with
per-invocation LoRA adapters).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import (
    AttnSpec,
    decode_attention,
    init_attention,
    init_kv_cache,
    multi_head_attention,
)
from repro.models.layers import dense_init, ffn, gelu_ffn, init_ffn, init_mlp, layer_norm, mlp_ffn, rms_norm
from repro.models.moe import init_moe, moe_block


def attn_spec(cfg: ModelConfig, *, causal: bool = True, prefix_len: int = 0,
              cross: bool = False) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qk_norm=cfg.qk_norm and not cross,
        qkv_bias=cfg.qkv_bias,
        sliding_window=cfg.sliding_window if causal and not cross else 0,
        use_rope=cfg.use_rope and not cross,
        rope_theta=cfg.rope_theta,
        causal=causal and not cross,
        prefix_len=prefix_len,
    )


# --------------------------------------------------------------------------
# Standard decoder block (dense or MoE FFN)
# --------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> dict:
    ka, kf = jax.random.split(key)
    spec = attn_spec(cfg)
    p = {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(ka, cfg.d_model, spec, dtype),
        "ffn_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.arch_type == "moe":
        p["moe"] = init_moe(kf, cfg.d_model, cfg.num_experts,
                            cfg.num_shared_experts, cfg.moe_d_ff, dtype)
    else:
        p["mlp"] = init_ffn(kf, cfg.d_model, cfg.d_ff, dtype)
    return p


def apply_block(params: dict, x: jax.Array, cfg: ModelConfig, *,
                prefix_len: int = 0) -> tuple[jax.Array, jax.Array]:
    spec = attn_spec(cfg, prefix_len=prefix_len)
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    x = x + multi_head_attention(params["attn"], h, spec)
    h = rms_norm(x, params["ffn_norm"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y, aux = moe_block(
            params["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            aux_weight=cfg.router_aux_weight,
        )
    else:
        mlp = gelu_ffn if cfg.arch_type == "vlm" else ffn
        y, aux = mlp(params["mlp"], h), jnp.zeros((), jnp.float32)
    return x + y, aux


def decode_block(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    spec = attn_spec(cfg)
    h = rms_norm(x, params["attn_norm"], cfg.norm_eps)
    a, cache = decode_attention(params["attn"], h, cache, spec)
    x = x + a
    h = rms_norm(x, params["ffn_norm"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y, _ = moe_block(
            params["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            aux_weight=cfg.router_aux_weight,
            deterministic_capacity=max(
                cfg.top_k,
                (x.shape[0] * cfg.top_k + cfg.num_experts - 1) // cfg.num_experts + 1,
            ),
        )
    else:
        mlp = gelu_ffn if cfg.arch_type == "vlm" else ffn
        y = mlp(params["mlp"], h)
    return x + y, cache


# --------------------------------------------------------------------------
# Scanned decoder stack
# --------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, dtype) -> dict:
    keys = jax.random.split(key, cfg.num_layers)
    return jax.vmap(lambda k: init_block(k, cfg, dtype))(keys)


def layer_scan(body, carry, xs, cfg: ModelConfig, *, with_out: bool = False):
    """scan-over-layers, or a python unroll of the same (dry-run lowers
    unrolled because XLA cost_analysis ignores while trip counts)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    outs = []
    for i in range(length):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, out = body(carry, sl)
        if with_out:
            outs.append(out)
    if with_out:
        stacked = jax.tree.map(lambda *xs_: jnp.stack(xs_), *outs)
        return carry, stacked
    return carry, None


def apply_stack(stacked: dict, x: jax.Array, cfg: ModelConfig, *,
                prefix_len: int = 0) -> tuple[jax.Array, jax.Array]:
    from repro.sharding.rules import maybe_seq_shard

    def body(carry, layer_params):
        h, aux = carry
        h = maybe_seq_shard(h, cfg.seq_shard_activations)
        h, a = apply_block(layer_params, h, cfg, prefix_len=prefix_len)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = layer_scan(body_fn, (x, jnp.zeros((), jnp.float32)), stacked, cfg)
    return x, aux


def decode_stack(stacked: dict, x: jax.Array, caches: dict, cfg: ModelConfig):
    def body(h, inp):
        layer_params, cache = inp
        h, cache = decode_block(layer_params, h, cache, cfg)
        return h, cache

    x, caches = layer_scan(body, x, (stacked, caches), cfg, with_out=True)
    return x, caches


def init_stack_cache(batch: int, max_seq: int, cfg: ModelConfig, dtype) -> dict:
    spec = attn_spec(cfg)
    one = init_kv_cache(batch, max_seq, spec, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one
    )


# --------------------------------------------------------------------------
# Encoder-decoder (whisper): encoder self-attn + decoder self/cross-attn
# --------------------------------------------------------------------------

def init_enc_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kf = jax.random.split(key)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(ka, cfg.d_model, attn_spec(cfg, causal=False), dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), dtype),
        "ffn_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def apply_enc_layer(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    spec = attn_spec(cfg, causal=False)
    h = layer_norm(x, p["attn_norm"], p["attn_norm_b"])
    x = x + multi_head_attention(p["attn"], h, spec)
    h = layer_norm(x, p["ffn_norm"], p["ffn_norm_b"])
    return x + mlp_ffn(p["mlp"], h)


def init_dec_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(ka, cfg.d_model, attn_spec(cfg), dtype),
        "cross_norm": jnp.ones((cfg.d_model,), dtype),
        "cross_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "cross": init_attention(kc, cfg.d_model, attn_spec(cfg, cross=True), dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), dtype),
        "ffn_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def apply_dec_layer(p: dict, x: jax.Array, enc: jax.Array, cfg: ModelConfig):
    h = layer_norm(x, p["attn_norm"], p["attn_norm_b"])
    x = x + multi_head_attention(p["attn"], h, attn_spec(cfg))
    h = layer_norm(x, p["cross_norm"], p["cross_norm_b"])
    x = x + multi_head_attention(p["cross"], h, attn_spec(cfg, cross=True), x_kv=enc)
    h = layer_norm(x, p["ffn_norm"], p["ffn_norm_b"])
    return x + mlp_ffn(p["mlp"], h)


def decode_dec_layer(p: dict, x: jax.Array, enc: jax.Array, cache: dict,
                     cfg: ModelConfig):
    h = layer_norm(x, p["attn_norm"], p["attn_norm_b"])
    a, cache = decode_attention(p["attn"], h, cache, attn_spec(cfg))
    x = x + a
    h = layer_norm(x, p["cross_norm"], p["cross_norm_b"])
    x = x + multi_head_attention(p["cross"], h, attn_spec(cfg, cross=True), x_kv=enc)
    h = layer_norm(x, p["ffn_norm"], p["ffn_norm_b"])
    return x + mlp_ffn(p["mlp"], h), cache


# --------------------------------------------------------------------------
# Hybrid (zamba2): Mamba2 backbone + ONE shared attention block, invoked
# every ``attn_period`` layers with per-invocation LoRA deltas on qkv.
# --------------------------------------------------------------------------

def n_shared_invocations(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_period if cfg.attn_period else 0


def init_hybrid(key, cfg: ModelConfig, dtype) -> dict:
    km, ks, kl, kf = jax.random.split(key, 4)
    mamba_keys = jax.random.split(km, cfg.num_layers)
    mamba = jax.vmap(
        lambda k: {
            "norm": jnp.zeros((cfg.d_model,), dtype),
            **init_mamba_layer(k, cfg, dtype),
        }
    )(mamba_keys)
    spec = attn_spec(cfg)
    shared = {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(ks, cfg.d_model, spec, dtype),
        "ffn_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_ffn(kf, cfg.d_model, cfg.d_ff, dtype),
    }
    n_inv = n_shared_invocations(cfg)
    r = cfg.shared_lora_rank
    h = cfg.num_heads * cfg.resolved_head_dim
    lkeys = jax.random.split(kl, max(n_inv, 1))
    lora = jax.vmap(
        lambda k: {
            "lora_a_q": dense_init(jax.random.fold_in(k, 0), cfg.d_model,
                                   (cfg.d_model, r), dtype),
            "lora_b_q": jnp.zeros((r, h), dtype),
        }
    )(lkeys)
    return {"mamba": mamba, "shared": shared, "lora": lora}


def init_mamba_layer(key, cfg: ModelConfig, dtype) -> dict:
    return ssm.init_mamba(
        key, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
        state=cfg.ssm_state, conv_width=cfg.ssm_conv_width, dtype=dtype,
    )


def _shared_attn(shared: dict, lora_i: dict, x: jax.Array, cfg: ModelConfig):
    """Shared block with LoRA delta on the q projection for this invocation."""
    spec = attn_spec(cfg)
    h = rms_norm(x, shared["attn_norm"], cfg.norm_eps)
    params = dict(shared["attn"])
    params["wq"] = params["wq"] + lora_i["lora_a_q"] @ lora_i["lora_b_q"]
    x = x + multi_head_attention(params, h, spec)
    h = rms_norm(x, shared["ffn_norm"], cfg.norm_eps)
    return x + ffn(shared["mlp"], h)


def apply_hybrid(params: dict, x: jax.Array, cfg: ModelConfig):
    """Groups of ``attn_period`` scanned mamba layers + shared attn."""
    from repro.sharding.rules import maybe_seq_shard

    period = cfg.attn_period or cfg.num_layers
    n_inv = n_shared_invocations(cfg)

    def mamba_body(h, layer_params):
        h = maybe_seq_shard(h, cfg.seq_shard_activations)
        norm = layer_params["norm"]
        lp = {k: v for k, v in layer_params.items() if k != "norm"}
        y, _ = ssm.mamba_block(
            lp, rms_norm(h, norm, cfg.norm_eps),
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            state=cfg.ssm_state, chunk=cfg.ssm_chunk,
        )
        return h + y, None

    body = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
    done = 0
    for i in range(n_inv):
        group = jax.tree.map(lambda a: a[done : done + period], params["mamba"])
        x, _ = layer_scan(body, x, group, cfg)
        lora_i = jax.tree.map(lambda a: a[i], params["lora"])
        x = _shared_attn(params["shared"], lora_i, x, cfg)
        done += period
    if done < cfg.num_layers:
        group = jax.tree.map(lambda a: a[done:], params["mamba"])
        x, _ = layer_scan(body, x, group, cfg)
    return x, jnp.zeros((), jnp.float32)


def init_hybrid_cache(batch: int, max_seq: int, cfg: ModelConfig, dtype):
    h, conv = ssm.init_mamba_state(
        batch, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
        state=cfg.ssm_state, conv_width=cfg.ssm_conv_width, dtype=dtype,
    )
    stacked = {
        "h": jnp.broadcast_to(h, (cfg.num_layers,) + h.shape).copy(),
        "conv": jnp.broadcast_to(conv, (cfg.num_layers,) + conv.shape).copy(),
    }
    n_inv = n_shared_invocations(cfg)
    spec = attn_spec(cfg)
    one = init_kv_cache(batch, max_seq, spec, dtype)
    attn_caches = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (max(n_inv, 1),) + a.shape).copy(), one
    )
    return {"mamba": stacked, "attn": attn_caches}


def decode_hybrid(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    period = cfg.attn_period or cfg.num_layers
    n_inv = n_shared_invocations(cfg)
    spec = attn_spec(cfg)

    def mamba_body(h, inp):
        layer_params, st = inp
        norm = layer_params["norm"]
        lp = {k: v for k, v in layer_params.items() if k != "norm"}
        y, (hs, cs) = ssm.mamba_decode(
            lp, rms_norm(h, norm, cfg.norm_eps), st["h"], st["conv"],
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
        )
        return h + y, {"h": hs, "conv": cs}

    new_mamba_states = []
    done = 0
    for i in range(n_inv):
        sl = slice(done, done + period)
        group = jax.tree.map(lambda a: a[sl], params["mamba"])
        states = jax.tree.map(lambda a: a[sl], cache["mamba"])
        x, new_states = layer_scan(mamba_body, x, (group, states), cfg,
                                   with_out=True)
        new_mamba_states.append(new_states)
        lora_i = jax.tree.map(lambda a: a[i], params["lora"])
        attn_cache_i = jax.tree.map(lambda a: a[i], cache["attn"])
        h = rms_norm(x, params["shared"]["attn_norm"], cfg.norm_eps)
        ap = dict(params["shared"]["attn"])
        ap["wq"] = ap["wq"] + lora_i["lora_a_q"] @ lora_i["lora_b_q"]
        a, attn_cache_i = decode_attention(ap, h, attn_cache_i, spec)
        x = x + a
        h = rms_norm(x, params["shared"]["ffn_norm"], cfg.norm_eps)
        x = x + ffn(params["shared"]["mlp"], h)
        cache["attn"] = jax.tree.map(
            lambda full, new: full.at[i].set(new), cache["attn"], attn_cache_i
        )
        done += period
    if done < cfg.num_layers:
        sl = slice(done, cfg.num_layers)
        group = jax.tree.map(lambda a: a[sl], params["mamba"])
        states = jax.tree.map(lambda a: a[sl], cache["mamba"])
        x, new_states = layer_scan(mamba_body, x, (group, states), cfg,
                                   with_out=True)
        new_mamba_states.append(new_states)
    new_mamba = jax.tree.map(
        lambda *parts: jnp.concatenate(parts, axis=0), *new_mamba_states
    )
    return x, {"mamba": new_mamba, "attn": cache["attn"]}
