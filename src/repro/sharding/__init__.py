from repro.sharding.rules import (
    batch_pspec,
    data_axis_names,
    param_shardings,
    param_specs,
)
