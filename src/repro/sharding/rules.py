"""Logical-axis -> mesh sharding rules.

Params are nested dicts with disciplined leaf names; ``param_specs`` walks
the tree and assigns a PartitionSpec by (path, shape). Divisibility is
always checked against the mesh: an axis that does not divide the dim is
dropped (replicated) instead of failing to lower — this is what makes e.g.
kv_heads=2 coexist with a 16-way ``model`` axis.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name regex -> logical spec (one entry per trailing dim, innermost
# last). "embed" stays replicated (activations are batch-sharded), tensor
# parallelism lives on heads/ff/vocab dims.
_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"^embedding$", ("vocab", None)),
    (r"^(lm_head|unembed)$", (None, "vocab")),
    (r"^pos_embedding$", (None, None)),
    (r"^(wq|wk|wv|wqkv)$", (None, "heads")),
    (r"^(bq|bk|bv)$", ("heads",)),
    (r"^wo$", ("heads", None)),
    (r"^(w_gate|w_up)$", (None, "ff")),
    (r"^w_down$", ("ff", None)),
    (r"^(lora_a.*)$", (None, None)),
    (r"^(lora_b.*)$", (None, "heads")),
    (r"^router$", (None, None)),
    (r"^(moe_gate|moe_up)$", ("expert", None, "ff")),
    (r"^moe_down$", ("expert", "ff", None)),
    (r"^in_proj$", (None, "ff")),      # mamba: projection dim model-sharded
    (r"^out_proj$", ("ff", None)),
    (r"^conv_w$", (None, "ff")),
    (r"^conv_b$", ("ff",)),
    (r"^(A_log|D|dt_bias)$", ("ff",)),  # per-head params follow head shards
    (r"^(scale|bias|norm.*|.*_norm)$", (None,)),
]

# logical axis -> candidate mesh axes (each candidate may be a tuple of
# axes sharded jointly); first fully-present-and-divisible candidate wins.
# On the standard mesh everything tensor-parallel lives on 'model'; the
# MoE expert-parallel mesh splits 'model' into ('expert', 'tp'): expert
# weights shard on 'expert' (all-to-all token routing) while the DENSE
# dims still shard 16-way over the combined ('expert', 'tp') axes —
# shrinking dense TP to tp=2 alone costs far more than the a2a saves
# (measured: mixtral train x 38 s -> 104 s).
_LOGICAL_TO_MESH = {
    "vocab": ("model", ("expert", "tp")),
    "heads": ("model", ("expert", "tp")),
    "ff": ("model", "tp"),
    "expert": ("expert", "model"),
    None: (),
}


def _spec_for_leaf(name: str, ndim: int) -> tuple[str | None, ...]:
    for pat, spec in _RULES:
        if re.match(pat, name):
            # scan-stacked params carry extra leading dims -> replicate them
            pad = ndim - len(spec)
            if pad < 0:
                return tuple(spec[-ndim:]) if ndim else ()
            return (None,) * pad + tuple(spec)
    return (None,) * ndim


def logical_to_pspec(
    logical: tuple[str | None, ...], shape: tuple[int, ...], mesh: Mesh
) -> P:
    axes = []
    for dim, lax_name in zip(shape, logical):
        chosen = None
        for cand in _LOGICAL_TO_MESH.get(lax_name, ()):
            parts = cand if isinstance(cand, tuple) else (cand,)
            if all(p in mesh.shape for p in parts):
                size = 1
                for p in parts:
                    size *= mesh.shape[p]
                if dim % size == 0:
                    chosen = cand
                    break
        axes.append(chosen)
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


def param_specs(params: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).

    ``fsdp=True`` additionally shards every weight over the ``data`` axis
    (ZeRO-3 on top of tensor parallelism): XLA all-gathers params at use
    and reduce-scatters gradients — trades a per-layer weight gather for a
    16x smaller resident param/optimizer footprint.
    """

    def one(path, leaf):
        name = _leaf_name(path)
        logical = _spec_for_leaf(name, len(leaf.shape))
        spec = logical_to_pspec(logical, leaf.shape, mesh)
        if fsdp and "data" in mesh.shape and len(leaf.shape) >= 2:
            axes = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for i, (dim, ax) in enumerate(zip(leaf.shape, axes)):
                if ax is None and dim % mesh.shape["data"] == 0:
                    axes[i] = "data"
                    break
            while axes and axes[-1] is None:
                axes.pop()
            spec = P(*axes)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def batch_pspec(mesh: Mesh, global_batch: int, *, extra_dims: int = 1) -> P:
    """Shard the batch dim over every data-parallel axis that divides it.

    Prefers ("pod", "data") jointly, falls back to ("data",) then replicated.
    """
    candidates = []
    if "pod" in mesh.shape and "data" in mesh.shape:
        candidates.append(("pod", "data"))
    if "data" in mesh.shape:
        candidates.append(("data",))
    for axes in candidates:
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if global_batch % size == 0:
            return P(axes if len(axes) > 1 else axes[0], *([None] * extra_dims))
    return P(None, *([None] * extra_dims))


def data_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def shard_batch_dim(x, extra: tuple = ()):
    """Constrain dim 0 of ``x`` to the data axes of the ambient mesh (plus
    ``extra`` specs for later dims). No-op without an ambient mesh or when
    the dim doesn't divide — safe inside model code on CPU."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.shape:
            return x
        axes = mesh.shape
        batch_axes = tuple(a for a in ("pod", "data") if a in axes)
        if not batch_axes:
            return x
        bsize = int(np.prod([axes[a] for a in batch_axes]))
        if x.shape[0] % bsize:
            return x
        spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], *extra)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def maybe_seq_shard(x, enabled: bool):
    """Sequence-parallel constraint on a (B, S, d) residual stream: batch on
    the data axes, seq on 'model'. No-op when no mesh context is active or
    the dims don't divide (CPU tests)."""
    if not enabled:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.shape:
            return x
        axes = mesh.shape
        batch_axes = tuple(a for a in ("pod", "data") if a in axes)
        bsize = int(np.prod([axes[a] for a in batch_axes])) if batch_axes else 1
        if "model" not in axes or x.ndim < 3:
            return x
        if x.shape[-2] % axes["model"] or (bsize and x.shape[0] % bsize):
            return x
        spec = P(batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None),
                 *([None] * (x.ndim - 3)), "model", None)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
