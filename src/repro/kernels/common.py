"""Shared kernel plumbing: interpret-mode selection and tiling helpers."""
from __future__ import annotations

import os

import jax

# TPU is the target; everywhere else the kernels run in interpret mode
# (Python evaluation of the kernel body — used for CI/correctness).
def use_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


LANE = 128          # TPU lane width: last tile dim should be a multiple
SUBLANE = 8         # f32 sublane count
VMEM_BUDGET = 8 * 1024 * 1024  # conservative half-VMEM working set


def pick_block(n: int, bytes_per_elem: int, rows: int = 1,
               max_block: int = 512 * 1024) -> int:
    """Largest lane-aligned block of a flat N-vector such that ``rows``
    copies of it fit the VMEM budget (double-buffered)."""
    budget = VMEM_BUDGET // (2 * rows * bytes_per_elem)
    blk = min(n, budget, max_block)
    if blk >= LANE:
        blk -= blk % LANE
    return max(blk, 1)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)
