"""Fused momentum-SGD update kernel.

The paper's server runs momentum SGD on pushed gradients (KVStore
``set_optimizer``, §3.2). Unfused, the update v' = µv + g; p' = p − ηv'
is two HBM round-trips over the full model; the fused kernel streams
(p, v, g) tiles through VMEM once, computing both outputs per tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, use_interpret


def _sgd_kernel(hp_ref, p_ref, v_ref, g_ref, p_out_ref, v_out_ref):
    lr, mu = hp_ref[0], hp_ref[1]
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    v_new = mu * v + g
    v_out_ref[...] = v_new.astype(v_out_ref.dtype)
    p_out_ref[...] = (p - lr * v_new).astype(p_out_ref.dtype)


def sgd_momentum_flat(p: jax.Array, v: jax.Array, g: jax.Array,
                      lr: jax.Array, mu: jax.Array, *,
                      block: int | None = None,
                      interpret: bool | None = None):
    if interpret is None:
        interpret = use_interpret()
    n = p.shape[0]
    # VMEM working set: p, v, g in + p, v out + the hp scalar vector, sized
    # by the widest stream so bf16 params with f32 momentum still fit.
    widest = max(p.dtype.itemsize, v.dtype.itemsize, g.dtype.itemsize)
    block = block or pick_block(n, widest, rows=6)
    pad = (-n) % block
    if pad:
        p, v, g = (jnp.pad(x, (0, pad)) for x in (p, v, g))
    np_ = n + pad
    hp = jnp.stack([jnp.asarray(lr, jnp.float32), jnp.asarray(mu, jnp.float32)])
    new_p, new_v = pl.pallas_call(
        _sgd_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), p.dtype),
            jax.ShapeDtypeStruct((np_,), v.dtype),
        ],
        interpret=interpret,
    )(hp, p, v, g)
    return new_p[:n], new_v[:n]
