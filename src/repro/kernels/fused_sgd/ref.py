"""Oracle for the fused momentum-SGD update."""
import jax.numpy as jnp


def sgd_momentum_ref(p, v, g, lr, mu):
    v32 = mu * v.astype(jnp.float32) + g.astype(jnp.float32)
    p32 = p.astype(jnp.float32) - lr * v32
    return p32.astype(p.dtype), v32.astype(v.dtype)
