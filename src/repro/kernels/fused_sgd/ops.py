"""jit'd pytree wrapper for the fused momentum-SGD kernel."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.fused_sgd.fused_sgd import sgd_momentum_flat


@jax.jit
def sgd_momentum_fused(params: Any, velocity: Any, grads: Any,
                       lr: jax.Array, mu: jax.Array):
    interpret = use_interpret()

    def one(p, v, g):
        np_, nv = sgd_momentum_flat(
            p.reshape(-1), v.reshape(-1), g.reshape(-1), lr, mu,
            interpret=interpret,
        )
        return np_.reshape(p.shape), nv.reshape(v.shape)

    pairs = jax.tree.map(one, params, velocity, grads)
    new_p = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_v
