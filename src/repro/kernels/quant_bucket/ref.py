"""Oracle: block absmax int8 quantization in plain jnp."""
import jax.numpy as jnp

from repro.kernels.quant_bucket.quant_bucket import QBLOCK, WIRE_BLOCK


def quantize_ref(x):
    n = x.shape[0]
    pad = (-n) % QBLOCK
    xp = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xp), axis=-1, keepdims=True), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(-1)[:n], scale[:, 0]


def dequantize_ref(codes, scales, n, dtype=jnp.float32):
    pad = (-n) % QBLOCK
    cp = jnp.pad(codes, (0, pad)).reshape(-1, QBLOCK)
    out = cp.astype(jnp.float32) * scales[:, None]
    return out.reshape(-1)[:n].astype(dtype)


def wire_encode_ref(x):
    """WIRE_BLOCK-bucket oracle of ``quant_bucket.wire_encode``."""
    n = x.shape[0]
    pad = (-n) % WIRE_BLOCK
    xp = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, WIRE_BLOCK)
    scale = jnp.maximum(
        jnp.max(jnp.abs(xp), axis=-1, keepdims=True), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(-1), scale[:, 0]


def wire_decode_ref(codes, scales, n=None):
    out = (codes.reshape(-1, WIRE_BLOCK).astype(jnp.float32)
           * scales[:, None]).reshape(-1)
    return out if n is None else out[:n]
