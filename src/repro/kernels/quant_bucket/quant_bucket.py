"""Block-quantization kernel pair (beyond-paper): int8-compress gradient
/ parameter pushes on the PS leg.

The paper's hot-spot is the server ingress link (§2.3); its remedy is
fewer pushers (MPI clients). An orthogonal, modern remedy is pushing
*smaller* tensors: block-wise absmax int8 quantization cuts the PS-leg
bytes 4x (f32) at <0.4% relative error per block. The kernels stream
(block,) tiles through VMEM: quantize emits int8 codes + one f32 scale
per block; dequantize reverses it. Grid-pipelined like the other
kernels: DMA of tile i+1 overlaps VPU quantization of tile i.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 1024  # quantization granularity (one scale per QBLOCK values)


def _quantize_kernel(x_ref, codes_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)  # (1, QBLOCK)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    codes_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def _dequantize_kernel(codes_ref, scale_ref, x_ref):
    x_ref[...] = (
        codes_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    ).astype(x_ref.dtype)


def quantize_flat(x: jax.Array, *, interpret: bool = True):
    """x: (N,) -> (codes (N,) int8, scales (N/QBLOCK,) f32). N padded."""
    n = x.shape[0]
    pad = (-n) % QBLOCK
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = (n + pad) // QBLOCK
    xb = x.reshape(nb, QBLOCK)
    codes, scales = pl.pallas_call(
        _quantize_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return codes.reshape(-1)[:n], scales[:, 0]


def dequantize_flat(codes: jax.Array, scales: jax.Array, n: int,
                    dtype=jnp.float32, *, interpret: bool = True):
    pad = (-n) % QBLOCK
    if pad:
        codes = jnp.pad(codes, (0, pad))
    nb = (n + pad) // QBLOCK
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, QBLOCK), dtype),
        interpret=interpret,
    )(codes.reshape(nb, QBLOCK), scales.reshape(nb, 1))
    return out.reshape(-1)[:n]
