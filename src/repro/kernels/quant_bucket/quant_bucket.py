"""Block-quantization kernels (beyond-paper): the int8 wire form of every
compressed leg.

The paper's hot-spot is the server ingress link (§2.3); its remedy is
fewer pushers (MPI clients). An orthogonal, modern remedy is pushing
*smaller* tensors: block-wise absmax int8 quantization cuts the wire
bytes ~4x (f32) at <0.4% relative error per block. Two granularities
live here:

  QBLOCK (1024)      the original PS-push codec: ``quantize_flat`` /
                     ``dequantize_flat`` stream (block,) tiles through
                     VMEM — one f32 scale per 1024 values (the per-leaf
                     ``ops.compress`` form)
  WIRE_BLOCK (128)   the ring-hop wire codec: one f32 scale per LANE of
                     128 values, so EVERY lane-aligned ring chunk splits
                     into whole buckets and the int8/f32 byte ratio is
                     geometry-exact ((1 + 4/128)/4 = 0.2578) at any
                     buffer size. ``wire_encode``/``wire_decode`` are the
                     plain-jnp form traced INLINE into the quantized
                     collectives (core/collectives.py) — XLA fuses them,
                     so a quantized ring hop adds ZERO extra kernel
                     launches; ``quantize_wire``/``dequantize_wire`` are
                     the streaming Pallas pair for the hop-free one-shot
                     wire (the packed PS push / elastic exchange buffer).

Grid-pipelined like the other kernels: DMA of tile i+1 overlaps VPU
quantization of tile i.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import use_interpret

QBLOCK = 1024  # PS-push quantization granularity (one scale per QBLOCK values)
WIRE_BLOCK = 128  # ring-hop wire granularity (one scale per LANE of values)


def _quantize_kernel(x_ref, codes_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)  # (1, QBLOCK)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    codes_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def _dequantize_kernel(codes_ref, scale_ref, x_ref):
    x_ref[...] = (
        codes_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    ).astype(x_ref.dtype)


def quantize_flat(x: jax.Array, *, interpret: bool | None = None):
    """x: (N,) -> (codes (N,) int8, scales (N/QBLOCK,) f32). N padded."""
    if interpret is None:
        interpret = use_interpret()
    n = x.shape[0]
    pad = (-n) % QBLOCK
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = (n + pad) // QBLOCK
    xb = x.reshape(nb, QBLOCK)
    codes, scales = pl.pallas_call(
        _quantize_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return codes.reshape(-1)[:n], scales[:, 0]


def dequantize_flat(codes: jax.Array, scales: jax.Array, n: int,
                    dtype=jnp.float32, *, interpret: bool | None = None):
    if interpret is None:
        interpret = use_interpret()
    pad = (-n) % QBLOCK
    if pad:
        codes = jnp.pad(codes, (0, pad))
    nb = (n + pad) // QBLOCK
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, QBLOCK), dtype),
        interpret=interpret,
    )(codes.reshape(nb, QBLOCK), scales.reshape(nb, 1))
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# WIRE_BLOCK codec: the int8 form a quantized ring hop puts on the wire
# ---------------------------------------------------------------------------

def wire_nbytes(n: int) -> int:
    """Wire bytes of n f32 values in the int8 wire form (codes + scales)."""
    return n + -(-n // WIRE_BLOCK) * 4


def wire_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(n,) float -> (codes (n_pad,) int8, scales (n_pad/128,) f32).

    Plain jnp on purpose: this is the form the quantized collectives
    trace INLINE per ring hop, so XLA fuses it into the surrounding
    program and the hop adds no kernel launch. Padding (to whole
    WIRE_BLOCK buckets) is zeros, which never raise a bucket's absmax —
    pad values cannot poison the scales. An all-zero bucket hits the
    ``max(absmax, 1e-12)`` guard: its scale is ~7.9e-15 and every code
    is 0, so it decodes to exactly 0.0.
    """
    n = x.shape[0]
    pad = (-n) % WIRE_BLOCK
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xb = xf.reshape(-1, WIRE_BLOCK)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(-1), scale[:, 0]


def wire_decode(codes: jax.Array, scales: jax.Array,
                n: int | None = None) -> jax.Array:
    """Inverse of ``wire_encode``: (codes, scales) -> (n,) f32 (the
    receiver's hp view; ``n`` trims the encoder's bucket padding)."""
    nb = scales.shape[0]
    out = codes.reshape(nb, WIRE_BLOCK).astype(jnp.float32) * scales[:, None]
    out = out.reshape(-1)
    return out if n is None else out[:n]


# streaming Pallas pair for the hop-free one-shot wire (the packed PS
# push / elastic exchange buffer): same math as wire_encode/wire_decode
# bucket-for-bucket, but tiled through VMEM as one grid

WIRE_TILE_ROWS = 64  # buckets per grid step (64*128 = 8K values/tile)


def _quantize_wire_kernel(x_ref, codes_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)  # (WIRE_TILE_ROWS, WIRE_BLOCK)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    codes_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def _dequantize_wire_kernel(codes_ref, scale_ref, x_ref):
    x_ref[...] = (
        codes_ref[...].astype(jnp.float32) * scale_ref[...]
    ).astype(x_ref.dtype)


def quantize_wire(x: jax.Array, *, interpret: bool | None = None):
    """x: (N,) -> (codes (N_pad,) int8, scales (N_pad/128,) f32), padded
    to whole WIRE_TILE_ROWS×WIRE_BLOCK tiles. Matches ``wire_encode``
    bucket-for-bucket on the shared length."""
    if interpret is None:
        interpret = use_interpret()
    n = x.shape[0]
    tile = WIRE_TILE_ROWS * WIRE_BLOCK
    pad = (-n) % tile
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    nb = (n + pad) // WIRE_BLOCK
    xb = xf.reshape(nb, WIRE_BLOCK)
    codes, scales = pl.pallas_call(
        _quantize_wire_kernel,
        grid=(nb // WIRE_TILE_ROWS,),
        in_specs=[pl.BlockSpec((WIRE_TILE_ROWS, WIRE_BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((WIRE_TILE_ROWS, WIRE_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((WIRE_TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, WIRE_BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return codes.reshape(-1), scales[:, 0]


def dequantize_wire(codes: jax.Array, scales: jax.Array, n: int,
                    dtype=jnp.float32, *, interpret: bool | None = None):
    """Inverse of ``quantize_wire``, trimmed back to ``n`` values."""
    if interpret is None:
        interpret = use_interpret()
    nb = scales.shape[0]
    out = pl.pallas_call(
        _dequantize_wire_kernel,
        grid=(nb // WIRE_TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((WIRE_TILE_ROWS, WIRE_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((WIRE_TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((WIRE_TILE_ROWS, WIRE_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, WIRE_BLOCK), dtype),
        interpret=interpret,
    )(codes.reshape(nb, WIRE_BLOCK), scales.reshape(nb, 1))
    return out.reshape(-1)[:n]
