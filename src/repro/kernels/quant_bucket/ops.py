"""jit'd pytree wrapper: compress/decompress a pytree for a PS push."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.quant_bucket.quant_bucket import dequantize_flat, quantize_flat


@jax.jit
def compress(tree: Any):
    """pytree -> (codes int8 pytree, scales pytree). ~4x smaller (f32)."""
    interpret = use_interpret()

    def one(x):
        return quantize_flat(x.reshape(-1).astype(jnp.float32),
                             interpret=interpret)

    pairs = jax.tree.map(one, tree)
    codes = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return codes, scales


def decompress(codes: Any, scales: Any, like: Any) -> Any:
    interpret = use_interpret()

    def one(c, s, ref):
        flat = dequantize_flat(c, s, ref.size, jnp.float32,
                               interpret=interpret)
        return flat.reshape(ref.shape).astype(ref.dtype)

    return jax.tree.map(one, codes, scales, like)


def compressed_bytes(tree: Any) -> int:
    """Wire bytes of the compressed form (codes + scales)."""
    from repro.kernels.quant_bucket.quant_bucket import QBLOCK

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += leaf.size  # int8 codes
        total += -(-leaf.size // QBLOCK) * 4  # f32 scales
    return total
