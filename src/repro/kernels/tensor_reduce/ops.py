"""jit'd public wrapper for the grouped-vector reduction kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.tensor_reduce.tensor_reduce import group_reduce_flat


@partial(jax.jit, static_argnames=("block",))
def group_reduce(x: jax.Array, *, block: int | None = None) -> jax.Array:
    """Sum a stacked group of arrays over the leading (group) dim.

    x: (G, ...) -> (...). Shape-agnostic: internally flattened to (G, N).
    """
    g = x.shape[0]
    rest = x.shape[1:]
    flat = x.reshape(g, -1)
    out = group_reduce_flat(flat, block=block, interpret=use_interpret())
    return out.reshape(rest)
