"""Pure-jnp oracle for the grouped-vector reduction."""
import jax
import jax.numpy as jnp


def group_reduce_ref(x: jax.Array) -> jax.Array:
    """x: (G, ...) -> (...): f32-accumulated sum over the group dim."""
    return jnp.sum(x.astype(jnp.float32), axis=0).astype(x.dtype)
