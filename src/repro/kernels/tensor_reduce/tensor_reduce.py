"""Grouped-vector reduction kernel — the TPU analogue of the paper's
IBMGpu fused reduction (§7.3).

The paper reduces the two per-GPU vectors of a node-tensor with CUDA
kernels using all 112 SMs and overlapping the reduction with the ring's
network transfer. On TPU the same insight maps to the Pallas grid
pipeline: the (G, block) tile of group ``i+1`` is DMA'd HBM→VMEM while
the VPU reduces tile ``i`` — double-buffered overlap of copy and compute,
with the full vector never resident in VMEM.

Layout: input is the stacked group (G, N); grid walks N in lane-aligned
blocks; each kernel invocation reduces a (G, block) tile over G in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import ceil_div, pick_block


def _group_reduce_kernel(x_ref, o_ref):
    # x_ref: (G, block) VMEM tile; o_ref: (1, block)
    acc = jnp.sum(x_ref[...].astype(jnp.float32), axis=0, keepdims=True)
    o_ref[...] = acc.astype(o_ref.dtype)


def group_reduce_flat(x: jax.Array, *, block: int | None = None,
                      interpret: bool = True) -> jax.Array:
    """x: (G, N) -> (N,) summed over G."""
    g, n = x.shape
    block = block or pick_block(n, x.dtype.itemsize, rows=g + 1)
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    np_ = n + pad
    out = pl.pallas_call(
        _group_reduce_kernel,
        grid=(np_ // block,),
        in_specs=[pl.BlockSpec((g, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), x.dtype),
        interpret=interpret,
    )(x)
    return out[0, :n]
