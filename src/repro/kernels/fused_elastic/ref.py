"""Oracles: unfused eqs. (2)/(3) — mirror core/elastic.py on flat arrays."""
import jax.numpy as jnp


def elastic_exchange_ref(w, c, alpha):
    w32, c32 = w.astype(jnp.float32), c.astype(jnp.float32)
    diff = alpha * (w32 - c32)
    return (w32 - diff).astype(w.dtype), (c32 + diff).astype(c.dtype)


def elastic_exchange_mc_ref(w, c, alpha):
    """w: (C, N) replicas, c: (N,) center — the multi-client EASGD rule."""
    w32, c32 = w.astype(jnp.float32), c.astype(jnp.float32)
    diff = w32 - c32[None]
    new_w = (w32 - alpha * diff).astype(w.dtype)
    new_c = (c32 + alpha * jnp.sum(diff, axis=0)).astype(c.dtype)
    return new_w, new_c
