"""Oracle: unfused eqs. (2)/(3) — mirrors core/elastic.py on flat arrays."""
import jax.numpy as jnp


def elastic_exchange_ref(w, c, alpha):
    w32, c32 = w.astype(jnp.float32), c.astype(jnp.float32)
    diff = alpha * (w32 - c32)
    return (w32 - diff).astype(w.dtype), (c32 + diff).astype(c.dtype)
