"""jit'd pytree-level wrapper for the fused elastic exchange.

This is the PER-LEAF kernel variant (one fused Pallas pass per leaf):
each leaf still saves the unfused path's four HBM passes, but the
exchange remains O(num_leaves) kernel launches. The packed single-launch
variants — the default elastic path since the FlatBuffer refactor — live
in ``core.elastic`` (``elastic_exchange_packed`` and friends), which
pack the whole pytree through ``core.flatbuf`` first.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.fused_elastic.fused_elastic import elastic_exchange_flat


@jax.jit
def elastic_exchange_fused(params: Any, center: Any, alpha: jax.Array):
    """Apply eqs. (2)+(3) leaf-wise with one fused pass per leaf."""

    def one(w, c):
        nw, nc = elastic_exchange_flat(w.reshape(-1), c.reshape(-1), alpha)
        return nw.reshape(w.shape), nc.reshape(c.shape)

    pairs = jax.tree.map(one, params, center)
    new_params = jax.tree.map(lambda p: p[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_center = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    return new_params, new_center
