"""Fused Elastic-SGD exchange kernels (paper eqs. (2)+(3)).

Both updates read the same difference (w − w̃); unfused they cost four
HBM passes (read w, read w̃ twice each, write both). Each kernel here
streams one tile of every operand through VMEM and writes exactly the
outputs its caller needs in a single pass — the memory-bound
optimizer-update analogue of the paper's fused GPU reduction. Variants:

  elastic_exchange_flat     one (w, c) pair -> (new_w, new_c)
  elastic_client_flat       eq. (3) only -> new_w (the client's local
                            half when the server half runs remotely)
  elastic_server_flat       eq. (2) only -> new_c (the KVStore rule)
  elastic_client_diff_flat  eq. (3) + the raw f32 difference (w − w̃):
                            the difference is what the sharded cross-pod
                            leg ring reduce-scatters
  elastic_center_flat       eq. (2) on a device's 1/p center shard with
                            the reduce-scattered difference sum
  elastic_exchange_flat_mc  C stacked client replicas against one shared
                            center: the multi-client EASGD generalization
                            w̃ += α Σ_c (w_c − w̃), w_c −= α (w_c − w̃)

``interpret`` defaults to ``kernels.common.use_interpret()`` (compiled
on TPU, interpreted elsewhere) like every other kernel in the tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, use_interpret


def _flat_call(kernel, inputs, out_dtypes, alpha, *, block=None,
               interpret=None, rows=4):
    """Shared 1D launcher: pad (N,) operands to a block multiple, grid
    the kernel over tiles with the replicated alpha scalar first."""
    if interpret is None:
        interpret = use_interpret()
    n = inputs[0].shape[0]
    block = block or pick_block(n, 4, rows=rows)
    pad = (-n) % block
    if pad:
        inputs = [jnp.pad(x, (0, pad)) for x in inputs]
    np_ = n + pad
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    outs = pl.pallas_call(
        kernel,
        grid=(np_ // block,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))]
        + [pl.BlockSpec((block,), lambda i: (i,))] * len(inputs),
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))] * len(out_dtypes),
        out_shape=[jax.ShapeDtypeStruct((np_,), dt) for dt in out_dtypes],
        interpret=interpret,
    )(alpha, *inputs)
    if len(out_dtypes) == 1:
        return outs[0][:n]
    return tuple(o[:n] for o in outs)


def _elastic_kernel(alpha_ref, w_ref, c_ref, w_out_ref, c_out_ref):
    w = w_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    diff = alpha_ref[0] * (w - c)
    w_out_ref[...] = (w - diff).astype(w_out_ref.dtype)
    c_out_ref[...] = (c + diff).astype(c_out_ref.dtype)


def elastic_exchange_flat(w: jax.Array, c: jax.Array, alpha: jax.Array, *,
                          block: int | None = None,
                          interpret: bool | None = None):
    """w, c: (N,) -> (new_w, new_c)."""
    return _flat_call(_elastic_kernel, [w, c], [w.dtype, c.dtype], alpha,
                      block=block, interpret=interpret, rows=4)


def _elastic_client_kernel(alpha_ref, w_ref, c_ref, w_out_ref):
    w = w_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    w_out_ref[...] = (w - alpha_ref[0] * (w - c)).astype(w_out_ref.dtype)


def elastic_client_flat(w: jax.Array, c: jax.Array, alpha: jax.Array, *,
                        block: int | None = None,
                        interpret: bool | None = None):
    """Eq. (3) only: -> new_w, nothing else written."""
    return _flat_call(_elastic_client_kernel, [w, c], [w.dtype], alpha,
                      block=block, interpret=interpret, rows=3)


def _elastic_server_kernel(alpha_ref, w_ref, c_ref, c_out_ref):
    w = w_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    c_out_ref[...] = (c + alpha_ref[0] * (w - c)).astype(c_out_ref.dtype)


def elastic_server_flat(w: jax.Array, c: jax.Array, alpha: jax.Array, *,
                        block: int | None = None,
                        interpret: bool | None = None):
    """Eq. (2) only: -> new_c, nothing else written."""
    return _flat_call(_elastic_server_kernel, [w, c], [c.dtype], alpha,
                      block=block, interpret=interpret, rows=3)


def _elastic_client_diff_kernel(alpha_ref, w_ref, c_ref, w_out_ref,
                                d_out_ref):
    w = w_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    diff = w - c
    w_out_ref[...] = (w - alpha_ref[0] * diff).astype(w_out_ref.dtype)
    d_out_ref[...] = diff


def elastic_client_diff_flat(w: jax.Array, c: jax.Array, alpha: jax.Array, *,
                             block: int | None = None,
                             interpret: bool | None = None):
    """Eq. (3) plus the raw f32 difference in ONE pass: returns
    (new_w, w − w̃). The difference is the sharded cross-pod leg's
    payload (ring reduce-scattered over the pod axis)."""
    return _flat_call(_elastic_client_diff_kernel, [w, c],
                      [w.dtype, jnp.float32], alpha,
                      block=block, interpret=interpret, rows=4)


def _elastic_center_kernel(alpha_ref, c_ref, ds_ref, c_out_ref):
    c = c_ref[...].astype(jnp.float32)
    ds = ds_ref[...].astype(jnp.float32)
    c_out_ref[...] = (c + alpha_ref[0] * ds).astype(c_out_ref.dtype)


def elastic_center_flat(c: jax.Array, diff_sum: jax.Array, alpha: jax.Array,
                        *, block: int | None = None,
                        interpret: bool | None = None):
    """Eq. (2) on this device's 1/p center shard, fed the ring
    reduce-scattered Σ_c (w_c − w̃) shard."""
    return _flat_call(_elastic_center_kernel, [c, diff_sum], [c.dtype],
                      alpha, block=block, interpret=interpret, rows=3)


def _elastic_mc_kernel(alpha_ref, w_ref, c_ref, w_out_ref, c_out_ref):
    w = w_ref[...].astype(jnp.float32)   # (C, block)
    c = c_ref[...].astype(jnp.float32)   # (1, block)
    alpha = alpha_ref[0]
    diff = w - c
    w_out_ref[...] = (w - alpha * diff).astype(w_out_ref.dtype)
    c_out_ref[...] = (
        c + alpha * jnp.sum(diff, axis=0, keepdims=True)
    ).astype(c_out_ref.dtype)


def elastic_exchange_flat_mc(w: jax.Array, c: jax.Array, alpha: jax.Array, *,
                             block: int | None = None,
                             interpret: bool | None = None):
    """w: (C, N) stacked client replicas, c: (N,) shared center.

    One HBM pass for the whole multi-client exchange: every client's
    eq. (3) update AND the summed eq. (2) center move, all from the same
    pre-update differences. Returns (new_w (C, N), new_c (N,)).
    """
    if interpret is None:
        interpret = use_interpret()
    C, n = w.shape
    block = block or pick_block(n, 4, rows=2 * C + 3)
    pad = (-n) % block
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        c = jnp.pad(c, (0, pad))
    np_ = n + pad
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    new_w, new_c = pl.pallas_call(
        _elastic_mc_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((C, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((C, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, np_), w.dtype),
            jax.ShapeDtypeStruct((1, np_), c.dtype),
        ],
        interpret=interpret,
    )(alpha, w, c.reshape(1, np_))
    return new_w[:, :n], new_c[0, :n]
