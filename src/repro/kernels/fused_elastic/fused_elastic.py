"""Fused Elastic-SGD exchange kernel (paper eqs. (2)+(3)).

Both updates read the same difference (w − w̃); unfused they cost four
HBM passes (read w, read w̃ twice each, write both). The fused kernel
streams one (block,) tile of each operand through VMEM and writes both
outputs in a single pass — the memory-bound optimizer-update analogue of
the paper's fused GPU reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block


def _elastic_kernel(alpha_ref, w_ref, c_ref, w_out_ref, c_out_ref):
    w = w_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    alpha = alpha_ref[0]
    diff = alpha * (w - c)
    w_out_ref[...] = (w - diff).astype(w_out_ref.dtype)
    c_out_ref[...] = (c + diff).astype(c_out_ref.dtype)


def elastic_exchange_flat(w: jax.Array, c: jax.Array, alpha: jax.Array, *,
                          block: int | None = None, interpret: bool = True):
    """w, c: (N,) -> (new_w, new_c)."""
    n = w.shape[0]
    block = block or pick_block(n, 4, rows=4)
    pad = (-n) % block
    if pad:
        w = jnp.pad(w, (0, pad))
        c = jnp.pad(c, (0, pad))
    np_ = n + pad
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    new_w, new_c = pl.pallas_call(
        _elastic_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # alpha, replicated per tile
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), w.dtype),
            jax.ShapeDtypeStruct((np_,), c.dtype),
        ],
        interpret=interpret,
    )(alpha, w, c)
    return new_w[:n], new_c[:n]
