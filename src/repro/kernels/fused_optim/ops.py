"""jit'd pytree wrappers for the fused AdaGrad / AdamW kernels."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.fused_optim.fused_optim import adagrad_flat, adamw_flat


@jax.jit
def adagrad_fused(params: Any, accum: Any, grads: Any,
                  lr: jax.Array, eps: jax.Array):
    interpret = use_interpret()

    def one(p, s, g):
        np_, ns = adagrad_flat(
            p.reshape(-1), s.reshape(-1), g.reshape(-1), lr, eps,
            interpret=interpret,
        )
        return np_.reshape(p.shape), ns.reshape(s.shape)

    pairs = jax.tree.map(one, params, accum, grads)
    is_pair = lambda x: isinstance(x, tuple)
    new_p = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_s = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return new_p, new_s


@jax.jit
def adamw_fused(params: Any, m: Any, v: Any, grads: Any, t: jax.Array,
                lr: jax.Array, b1: jax.Array, b2: jax.Array,
                eps: jax.Array, wd: jax.Array):
    """``t`` is the POST-increment step count shared by every leaf."""
    interpret = use_interpret()
    tf = jnp.asarray(t, jnp.float32)
    c1 = 1.0 - jnp.asarray(b1, jnp.float32) ** tf
    c2 = 1.0 - jnp.asarray(b2, jnp.float32) ** tf

    def one(p, m_, v_, g):
        mv = jnp.stack([m_.reshape(-1), v_.reshape(-1)])
        np_, nmv = adamw_flat(
            p.reshape(-1), mv, g.reshape(-1),
            lr, b1, b2, eps, wd, c1, c2, interpret=interpret,
        )
        return (np_.reshape(p.shape), nmv[0].reshape(m_.shape),
                nmv[1].reshape(v_.shape))

    triples = jax.tree.map(one, params, m, v, grads)
    is_triple = lambda x: isinstance(x, tuple)
    new_p = jax.tree.map(lambda t_: t_[0], triples, is_leaf=is_triple)
    new_m = jax.tree.map(lambda t_: t_[1], triples, is_leaf=is_triple)
    new_v = jax.tree.map(lambda t_: t_[2], triples, is_leaf=is_triple)
    return new_p, new_m, new_v
