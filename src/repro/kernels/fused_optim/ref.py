"""Oracles for the fused AdaGrad / AdamW updates."""
import jax.numpy as jnp


def adagrad_ref(p, s, g, lr, eps):
    g32 = g.astype(jnp.float32)
    s32 = s.astype(jnp.float32) + g32 * g32
    p32 = p.astype(jnp.float32) - lr * g32 / (jnp.sqrt(s32) + eps)
    return p32.astype(p.dtype), s32.astype(s.dtype)


def adamw_ref(p, m, v, g, t, lr, b1, b2, eps, wd):
    """``t`` is the POST-increment step count (first step: t=1)."""
    g32 = g.astype(jnp.float32)
    m32 = b1 * m.astype(jnp.float32) + (1.0 - b1) * g32
    v32 = b2 * v.astype(jnp.float32) + (1.0 - b2) * g32 * g32
    tf = jnp.asarray(t, jnp.float32)
    c1 = 1.0 - jnp.float32(b1) ** tf
    c2 = 1.0 - jnp.float32(b2) ** tf
    upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps) + wd * p.astype(jnp.float32)
    p32 = p.astype(jnp.float32) - lr * upd
    return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)
