"""Fused flat AdaGrad / AdamW update kernels.

Generalizes ``kernels/fused_sgd`` from one momentum stream to K
optimizer-state streams, the paper's "group of vectors treated as one"
applied to the optimizer itself: AdaGrad tiles (param, accum, grad) — 3
streams — and AdamW (param, m, v, grad) — 4 streams — through VMEM
together, one grid over the flat buffer, every output computed per tile.
Unfused, AdamW is four HBM round-trips over the full model (m, v, update,
decay); fused it is one pass.

Bias correction enters as the precomputed scalars c1 = 1 − β1^t and
c2 = 1 − β2^t in the hp vector (the step count t is carried by the
caller as a scalar state stream), so the kernel body stays a pure
per-element map and the grid never re-reads t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, use_interpret


def _adagrad_kernel(hp_ref, p_ref, s_ref, g_ref, p_out_ref, s_out_ref):
    lr, eps = hp_ref[0], hp_ref[1]
    g = g_ref[...].astype(jnp.float32)
    s_new = s_ref[...].astype(jnp.float32) + g * g
    s_out_ref[...] = s_new.astype(s_out_ref.dtype)
    p = p_ref[...].astype(jnp.float32)
    p_out_ref[...] = (p - lr * g / (jnp.sqrt(s_new) + eps)).astype(
        p_out_ref.dtype)


def adagrad_flat(p: jax.Array, s: jax.Array, g: jax.Array,
                 lr: jax.Array, eps: jax.Array, *,
                 block: int | None = None,
                 interpret: bool | None = None):
    """One fused AdaGrad step on flat (n,) streams: s' = s + g²;
    p' = p − η·g/(√s' + ε). Returns ``(new_p, new_s)``."""
    if interpret is None:
        interpret = use_interpret()
    n = p.shape[0]
    # 3 streams in + 2 out, sized by the widest so bf16 params with f32
    # accumulator still fit the VMEM budget
    widest = max(p.dtype.itemsize, s.dtype.itemsize, g.dtype.itemsize)
    block = block or pick_block(n, widest, rows=6)
    pad = (-n) % block
    if pad:
        p, s, g = (jnp.pad(x, (0, pad)) for x in (p, s, g))
    np_ = n + pad
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.asarray(eps, jnp.float32)])
    new_p, new_s = pl.pallas_call(
        _adagrad_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), p.dtype),
            jax.ShapeDtypeStruct((np_,), s.dtype),
        ],
        interpret=interpret,
    )(hp, p, s, g)
    return new_p[:n], new_s[:n]


def _adamw_kernel(hp_ref, p_ref, mv_ref, g_ref, p_out_ref, mv_out_ref):
    lr, b1, b2 = hp_ref[0], hp_ref[1], hp_ref[2]
    eps, wd, c1, c2 = hp_ref[3], hp_ref[4], hp_ref[5], hp_ref[6]
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * mv_ref[0, :].astype(jnp.float32) + (1.0 - b1) * g
    v_new = b2 * mv_ref[1, :].astype(jnp.float32) + (1.0 - b2) * g * g
    mv_out_ref[0, :] = m_new.astype(mv_out_ref.dtype)
    mv_out_ref[1, :] = v_new.astype(mv_out_ref.dtype)
    p = p_ref[...].astype(jnp.float32)
    upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + wd * p
    p_out_ref[...] = (p - lr * upd).astype(p_out_ref.dtype)


def adamw_flat(p: jax.Array, mv: jax.Array, g: jax.Array,
               lr: jax.Array, b1: jax.Array, b2: jax.Array,
               eps: jax.Array, wd: jax.Array, c1: jax.Array, c2: jax.Array,
               *, block: int | None = None,
               interpret: bool | None = None):
    """One fused (decoupled-weight-decay) AdamW step on a flat (n,)
    param/grad pair and the ``(2, n)`` stacked m/v buffer — carried
    whole, in and out, so the caller's state never needs re-stacking
    (no extra HBM copy of the moment streams per step). ``c1``/``c2``
    are the bias corrections 1 − β^t for the POST-increment step count.
    Returns ``(new_p, new_mv)``."""
    if interpret is None:
        interpret = use_interpret()
    n = p.shape[0]
    # 4 streams in + 3 out (mv counts twice)
    widest = max(p.dtype.itemsize, mv.dtype.itemsize, g.dtype.itemsize)
    block = block or pick_block(n, widest, rows=8)
    pad = (-n) % block
    if pad:
        p, g = jnp.pad(p, (0, pad)), jnp.pad(g, (0, pad))
        mv = jnp.pad(mv, ((0, 0), (0, pad)))
    np_ = n + pad
    hp = jnp.stack([jnp.asarray(x, jnp.float32)
                    for x in (lr, b1, b2, eps, wd, c1, c2)])
    new_p, new_mv = pl.pallas_call(
        _adamw_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((7,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), p.dtype),
            jax.ShapeDtypeStruct((2, np_), mv.dtype),
        ],
        interpret=interpret,
    )(hp, p, mv, g)
    return new_p[:n], new_mv[:, :n]
