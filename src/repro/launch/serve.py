"""Serving: one-token decode step with sharded KV/SSM caches, plus a
small batched-request driver used by the serving example.
"""
from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.sharding.rules import param_specs


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, cache, tokens):
        return model.serve_step(params, cache, tokens)

    return serve_step


def _shardable(dim: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """Name/rank-based sharding for decode state.

    Priority: batch dim -> 'data'; heads/feature dim -> 'model' (first
    divisible candidate); everything else replicated. Works for KV caches
    (L,B,S,KV,D), SSM states (L,B,H,P,N), conv states (L,B,K,C) and the
    whisper encoder output (B,F,D).
    """

    def leaf_spec(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        axes: list = [None] * len(shape)
        if name == "index" or len(shape) == 0:
            return P()
        # locate batch dim: KV/SSM/conv states are stacked (L, B, ...) if
        # rank >= 3 and first dim equals a layer count; simpler: choose the
        # first dim (after optional leading stack dims) that divides 'data'.
        # Heuristic by name:
        if name in ("k", "v"):
            # (L, B, S, KV, D) or (B, S, KV, D)
            off = len(shape) - 4
            b, s, kv, d = range(off, off + 4)
            if _shardable(shape[b], mesh, "data"):
                axes[b] = "data"
            if _shardable(shape[kv], mesh, "model"):
                # collective-free: every chip owns whole KV heads
                axes[kv] = "model"
            elif _shardable(shape[s], mesh, "model"):
                # GQA with few KV heads: shard the *sequence* dim instead —
                # decode attention becomes a sharded contraction over S
                # (small psum of scores) rather than a full cache reshard.
                # (Sharding D forces GSPMD into involuntary rematerialization
                # of the whole cache — measured 200x excess HBM traffic.)
                axes[s] = "model"
        elif name == "h":
            # (L, B, H, P, N) ssm state
            off = len(shape) - 4
            b, hh, pp, nn = range(off, off + 4)
            if _shardable(shape[b], mesh, "data"):
                axes[b] = "data"
            for cand in (hh, pp, nn):
                if _shardable(shape[cand], mesh, "model"):
                    axes[cand] = "model"
                    break
        elif name == "conv":
            # (L, B, K-1, C)
            off = len(shape) - 3
            b, kk, cc = range(off, off + 3)
            if _shardable(shape[b], mesh, "data"):
                axes[b] = "data"
            if _shardable(shape[cc], mesh, "model"):
                axes[cc] = "model"
        elif name == "enc":
            if _shardable(shape[0], mesh, "data"):
                axes[0] = "data"
            if _shardable(shape[-1], mesh, "model"):
                axes[-1] = "model"
        else:
            if len(shape) >= 2 and _shardable(shape[0], mesh, "data"):
                axes[0] = "data"
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def token_specs(tokens_shape, mesh: Mesh) -> P:
    b = tokens_shape[0]
    if _shardable(b, mesh, "data"):
        return P("data", None)
    return P(None, None)


# ---------------------------------------------------------------------------
# Batched-request serving driver (example scale)
# ---------------------------------------------------------------------------

class BatchedServer:
    """Greedy continuous-batching server: fixed batch slots, each slot an
    independent request; finished slots are refilled from the queue."""

    def __init__(self, model: Model, params, *, batch: int, max_seq: int):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.cache = model.init_cache(batch, max_seq)
        self._step = jax.jit(model.serve_step)

    def prefill_tokens(self, prompts: jax.Array) -> jax.Array:
        """Teacher-forced prefill by stepping tokens one at a time (simple,
        exercises the same serve_step the dry-run lowers)."""
        last = None
        for t in range(prompts.shape[1]):
            logits, self.cache = self._step(
                self.params, self.cache, prompts[:, t : t + 1]
            )
            last = logits
        return last

    def generate(self, prompts: jax.Array, steps: int) -> jax.Array:
        logits = self.prefill_tokens(prompts)
        outs = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for _ in range(steps):
            outs.append(tok)
            logits, self.cache = self._step(self.params, self.cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return jnp.concatenate(outs, axis=1)
