import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh with ShapeDtypeStruct stand-ins
(no allocation), and derive the roofline terms from the compiled
artifacts. MUST run as its own process (the XLA flag above is set before
any other import so the 512 placeholder devices exist).

Roofline methodology: XLA's ``cost_analysis()`` ignores ``while``-loop
trip counts, so a scan-over-layers module under-reports FLOPs/bytes and
in-loop collectives. We therefore compile, per combo:

  1. the PRODUCTION module (scan over layers, remat) — this is the
     deliverable .lower().compile() artifact; memory analysis and the
     collective schedule come from here;
  2. two REDUCED-DEPTH fully-unrolled variants (L1 < L2 layers) whose
     cost analysis is exact; FLOPs / bytes / collective wire bytes are
     linear in depth for a homogeneous stack, so the two points give an
     exact extrapolation to the full depth.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh pod [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
"""
import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.compat import set_mesh
from repro.core.hierarchy import SyncConfig
from repro.launch import analysis
from repro.launch.mesh import make_moe_mesh, make_production_mesh, mesh_num_chips
from repro.launch.serve import cache_specs, make_serve_step, token_specs
from repro.launch.train import (
    batch_specs,
    clientize_batch_specs,
    make_train_state,
    make_train_step,
    state_specs,
)
from repro.models.model import build_model
from repro.optim.sgd import sgd
from repro.sharding.rules import batch_pspec, param_specs


def _shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return "full-attention arch: 500k dense KV decode is out of scope (DESIGN.md §4)"
    return None


def _reduced_depths(cfg) -> tuple:
    """Two depths for the exact linear extrapolation, honoring each
    family's repeating unit (hybrid repeats per attn_period group)."""
    if cfg.arch_type == "hybrid":
        p = cfg.attn_period
        return (p, 2 * p)
    return (2, 4)


def _with_depth(cfg, L: int):
    upd = dict(num_layers=L, unroll_layers=True)
    if cfg.is_enc_dec:
        upd["enc_layers"] = L
    return dataclasses.replace(cfg, **upd)


def lower_module(cfg, shape, mesh: Mesh, sync: SyncConfig, *,
                 microbatch: int = 1):
    fsdp = sync.fsdp
    """Lower (not yet compiled) the right step for this input shape."""
    model = build_model(cfg)
    if shape.kind == "train":
        optimizer = sgd(0.1, momentum=0.9)  # the paper's server optimizer
        state = make_train_state(model, optimizer, sync, abstract=True,
                                 mesh=mesh)
        sspecs = state_specs(state, mesh, sync)
        in_batch = model.input_specs(shape)
        if sync.num_clients > 1:
            in_batch = clientize_batch_specs(in_batch, sync.num_clients)
        bspecs = batch_specs(model, shape, mesh, sync)
        step = make_train_step(model, optimizer, sync, mesh,
                               microbatch=microbatch)
        return jax.jit(
            step,
            in_shardings=(_shardings(mesh, sspecs), _shardings(mesh, bspecs)),
            out_shardings=(_shardings(mesh, sspecs), None),
        ).lower(state, in_batch)
    if shape.kind == "prefill":
        params = jax.eval_shape(model.init, jax.random.key(0))
        pspecs = param_specs(params, mesh, fsdp=fsdp)
        in_batch = model.input_specs(shape)
        bspecs = {
            k: batch_pspec(mesh, v.shape[0], extra_dims=len(v.shape) - 1)
            for k, v in in_batch.items()
        }
        return jax.jit(
            model.forward,
            in_shardings=(_shardings(mesh, pspecs), _shardings(mesh, bspecs)),
        ).lower(params, in_batch)
    # decode
    params = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = param_specs(params, mesh, fsdp=fsdp)
    cache = jax.eval_shape(
        lambda: build_model(cfg).init_cache(shape.global_batch, shape.seq_len))
    cspecs = cache_specs(cache, mesh)
    tok = model.input_specs(shape)["tokens"]
    tspec = token_specs(tok.shape, mesh)
    return jax.jit(
        make_serve_step(model),
        in_shardings=(
            _shardings(mesh, pspecs),
            _shardings(mesh, cspecs),
            NamedSharding(mesh, tspec),
        ),
        out_shardings=(None, _shardings(mesh, cspecs)),
    ).lower(params, cache, tok)


def _compile_metrics(lowered, chips: int) -> dict:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = analysis.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.wire_bytes,
        "coll_counts": coll.counts,
        "memory": analysis.memory_summary(compiled.memory_analysis()),
    }


def lower_one(arch: str, shape_name: str, mesh: Mesh, sync_mode: str,
              *, esgd_interval: int = 64, verbose: bool = True,
              seq_shard: bool = False, microbatch: int = 1,
              remat: bool = True, extrapolate: bool = True,
              fsdp: bool = False) -> dict:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, seq_shard_activations=seq_shard,
                              remat=remat)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
                "skipped": reason}

    chips = mesh_num_chips(mesh)
    num_clients = mesh.shape.get("pod", 1) if sync_mode == "mpi_esgd" else 1
    sync = SyncConfig(mode=sync_mode, num_clients=num_clients,
                      esgd_interval=esgd_interval, fsdp=fsdp)
    sync.validate(mesh)

    # 1) production module: the deliverable compile + memory + schedule
    # (lowered under the ambient mesh so in-model sharding constraints
    # like shard_batch_dim/maybe_seq_shard resolve axis names)
    t0 = time.time()
    with set_mesh(mesh):
        lowered = lower_module(cfg, shape, mesh, sync, microbatch=microbatch)
    t_lower = time.time() - t0
    t0 = time.time()
    prod = _compile_metrics(lowered, chips)
    t_compile = time.time() - t0

    # 2) depth extrapolation for exact FLOPs/bytes/wire
    extra = {}
    if extrapolate:
        L1, L2 = _reduced_depths(cfg)
        pts = []
        for L in (L1, L2):
            cfg_l = _with_depth(cfg, L)
            with set_mesh(mesh):
                low = lower_module(cfg_l, shape, mesh, sync,
                                   microbatch=microbatch)
            pts.append(_compile_metrics(low, chips))
        Lfull = cfg.num_layers

        def extrap(key):
            m1, m2 = pts[0][key], pts[1][key]
            slope = (m2 - m1) / (L2 - L1)
            return m2 + slope * (Lfull - L2)

        # the microbatch accumulation loop is itself a while loop whose
        # trip count cost_analysis ignores; everything except the optimizer
        # update (negligible) runs inside it, so scale by M
        mscale = microbatch if (shape.kind == "train" and microbatch > 1) else 1
        extra = {
            "flops": extrap("flops") * mscale,
            "bytes": extrap("bytes") * mscale,
            "wire": extrap("wire") * mscale,
            "depths": [L1, L2],
            "microbatch_scale": mscale,
        }

    flops = extra.get("flops", prod["flops"])
    bytes_ = extra.get("bytes", prod["bytes"])
    wire = extra.get("wire", prod["wire"])

    if shape.kind == "train":
        if cfg.is_enc_dec:
            model_flops = analysis.enc_dec_model_flops(
                cfg, shape.global_batch, shape.seq_len, train=True)
        else:
            tokens = shape.global_batch * shape.seq_len
            model_flops = analysis.train_model_flops(
                cfg.param_count(), cfg.active_param_count(), tokens)
    elif shape.kind == "prefill":
        if cfg.is_enc_dec:
            model_flops = analysis.enc_dec_model_flops(
                cfg, shape.global_batch, shape.seq_len, train=False)
        else:
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * cfg.active_param_count() * tokens
    else:
        model_flops = analysis.decode_model_flops(
            cfg.active_param_count(), shape.global_batch)

    roof = analysis.Roofline(
        chips=chips,
        hlo_flops=flops * chips,
        hlo_bytes=bytes_ * chips,
        wire_bytes=wire * chips,
        compute_s=flops / analysis.PEAK_FLOPS,
        memory_s=bytes_ / analysis.HBM_BW,
        collective_s=wire / analysis.ICI_BW,
        model_flops=model_flops,
    )

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "sync": sync_mode,
        "chips": chips,
        "opts": {"seq_shard": seq_shard, "microbatch": microbatch,
                 "remat": remat, "fsdp": fsdp},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": prod["memory"],
        "collective_schedule": prod["coll_counts"],
        "extrapolation": extra,
        "roofline": roof.to_dict(),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if verbose:
        mem = prod["memory"]
        bpd = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        print(
            f"[dryrun] {arch} × {shape_name} × {chips}c ({sync_mode}"
            f"{', mb=' + str(microbatch) if microbatch > 1 else ''}"
            f"{', sp' if seq_shard else ''}): "
            f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
            f"bytes/dev {bpd/1e9:.2f}GB | dominant={roof.dominant} "
            f"(c={roof.compute_s*1e3:.2f}ms m={roof.memory_s*1e3:.2f}ms "
            f"x={roof.collective_s*1e3:.2f}ms) useful={roof.useful_flops_ratio:.2f}",
            flush=True,
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--sync", default=None,
                    help="mpi_sgd | mpi_esgd (default: sgd on pod, esgd on multipod)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--moe-mesh", action="store_true",
                    help="expert-parallel pod variant (data=16, expert=8, tp=2)")
    args = ap.parse_args()

    if args.moe_mesh:
        mesh = make_moe_mesh(multi_pod=args.mesh == "multipod")
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    sync = args.sync or ("mpi_esgd" if args.mesh == "multipod" else "mpi_sgd")

    combos = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                combos.append((arch.replace("_", "-"), shape))
    else:
        combos.append((args.arch, args.shape))

    results = []
    for arch, shape in combos:
        try:
            results.append(lower_one(
                arch, shape, mesh, sync,
                seq_shard=args.seq_shard, microbatch=args.microbatch,
                remat=not args.no_remat,
                extrapolate=not args.no_extrapolate, fsdp=args.fsdp,
            ))
        except Exception as e:  # a failure here is a bug in the system
            import traceback

            traceback.print_exc()
            print(f"[dryrun] FAILED {arch} × {shape}: {type(e).__name__}: {e}",
                  flush=True)
            results.append({"arch": arch, "shape": shape,
                            "mesh": dict(mesh.shape), "error": str(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    failed = [r for r in results if "error" in r]
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
