"""Compiled-artifact analysis: cost/memory extraction and the three-term
roofline (§Roofline of EXPERIMENTS.md).

    compute term    = HLO_FLOPs / (chips × peak FLOP/s)
    memory term     = HLO_bytes / (chips × HBM bw)
    collective term = wire_bytes / (chips × link bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device
for an SPMD module — multiplied back up by chip count). Collective bytes
are not in cost_analysis: we parse the optimized HLO and charge each op
its ring wire cost on the axis it runs over.
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field

# TPU v5e per-chip constants (from the brief)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    operand_bytes: dict = field(default_factory=dict)
    wire_bytes: float = 0.0

    def total_ops(self) -> int:
        return sum(self.counts.values())


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective bytes from optimized HLO text.

    Wire-cost convention (ring algorithms, group size g):
      all-reduce        2·(g−1)/g · bytes   (reduce-scatter + all-gather)
      all-gather        (g−1)/g · out_bytes
      reduce-scatter    (g−1)/g · in_bytes  (result type is the shard => ·(g−1))
      all-to-all        (g−1)/g · bytes
      collective-permute  bytes
    Group size is parsed per-op from replica_groups; ops with unknown
    groups assume g→∞ (factor 1).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        nbytes = _shape_bytes(dtype, dims)
        g = _group_size(line)
        frac = (g - 1) / g if g > 1 else 1.0
        if kind == "all-reduce":
            wire = 2 * frac * nbytes
        elif kind == "all-gather":
            wire = frac * nbytes
        elif kind == "reduce-scatter":
            wire = (g - 1) * nbytes if g > 1 else nbytes
        elif kind == "all-to-all":
            wire = frac * nbytes
        else:  # collective-permute
            wire = nbytes
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.operand_bytes[kind] = stats.operand_bytes.get(kind, 0) + nbytes
        stats.wire_bytes += wire
    return stats


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_RE2.search(line)
    if m:  # iota groups: [num_groups,group_size]
        return int(m.group(2))
    return 0


@dataclass
class Roofline:
    chips: int
    hlo_flops: float            # whole-job flops
    hlo_bytes: float            # whole-job HBM traffic
    wire_bytes: float           # whole-job collective wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d["dominant"] = self.dominant
        d["useful_flops_ratio"] = self.useful_flops_ratio
        return d


def roofline_from_analysis(cost: dict, coll: CollectiveStats, chips: int,
                           model_flops: float = 0.0,
                           wire_dtype: "str | None" = None) -> Roofline:
    # cost_analysis of an SPMD executable reports the per-device module
    per_dev_flops = float(cost.get("flops", 0.0))
    per_dev_bytes = float(cost.get("bytes accessed", 0.0))
    flops = per_dev_flops * chips
    bytes_ = per_dev_bytes * chips
    # wire_dtype projects the low-precision wire protocol onto a module
    # traced at full precision (the quantized collectives move
    # cost_model.wire_ratio of the f32 bytes per hop — codes + scales
    # for int8); the compiled-on-TPU path would show the s8 operands in
    # the HLO directly and needs no projection
    from repro.core.cost_model import wire_ratio
    per_dev_wire = coll.wire_bytes * wire_ratio(wire_dtype)
    wire = per_dev_wire * chips
    return Roofline(
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        wire_bytes=wire,
        compute_s=per_dev_flops / PEAK_FLOPS,
        memory_s=per_dev_bytes / HBM_BW,
        collective_s=per_dev_wire / ICI_BW,
        model_flops=model_flops,
    )


def overlap_projection(nbytes: float, p: int, compute_s: float, *,
                       bucket_bytes: "list[float] | None" = None,
                       num_buckets: int = 4,
                       wire_dtype: "str | None" = None,
                       net=None) -> dict:
    """Modeled step time with and without the backward-overlapped
    bucketed reduce-scatter, next to the wire-dtype projection.

    ``nbytes`` is the packed gradient payload (f32 bytes), ``p`` the
    ring size, ``compute_s`` the per-step compute time the bucket legs
    hide behind. ``bucket_bytes`` gives the real schedule partition
    (e.g. from ``flatbuf.BucketSchedule.sizes`` × itemsize); omitted,
    an even ``num_buckets`` split stands in. Keys: ``overlap_fraction``
    (structural — cost_model.overlap_fraction), ``step_no_overlap_s``,
    ``step_overlap_s``, ``hidden_s``, ``speedup``.
    """
    from repro.core import cost_model

    net = net or cost_model.tpu_v5e()
    bb = (list(bucket_bytes) if bucket_bytes
          else [nbytes / num_buckets] * num_buckets)
    no = cost_model.overlapped_step_time(compute_s, [nbytes], p, net,
                                         wire_dtype)
    ov = cost_model.overlapped_step_time(compute_s, bb, p, net, wire_dtype)
    return {
        "overlap_fraction": cost_model.overlap_fraction(bb, p),
        "step_no_overlap_s": no,
        "step_overlap_s": ov,
        "hidden_s": no - ov,
        "speedup": no / ov if ov else 1.0,
    }


def train_model_flops(param_count: int, active_param_count: int,
                      tokens: int) -> float:
    """6·N·D (N = active params for MoE)."""
    return 6.0 * active_param_count * tokens


def enc_dec_model_flops(cfg, batch: int, dec_tokens_per_seq: int,
                        train: bool = True) -> float:
    """Enc-dec (whisper): encoder params see B·enc_seq tokens, decoder
    params see B·S tokens — 6·N·T per side (2·N·T forward-only)."""
    d, h = cfg.d_model, cfg.resolved_head_dim
    attn = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * h + cfg.num_heads * h * d
    enc_n = cfg.enc_layers * (attn + 2 * d * cfg.d_ff)
    dec_n = cfg.num_layers * (2 * attn + 2 * d * cfg.d_ff)  # self + cross
    dec_n += 2 * cfg.padded_vocab * d  # embed + unembed
    mult = 6.0 if train else 2.0
    t_dec = batch * dec_tokens_per_seq
    t_enc = batch * cfg.enc_seq_len
    return mult * (enc_n * t_enc + dec_n * t_dec)


def decode_model_flops(active_param_count: int, batch: int) -> float:
    """One token per sequence: 2·N·B forward."""
    return 2.0 * active_param_count * batch


def memory_summary(mem) -> dict:
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out
