"""Policy autotuner: rank the collective-policy space with the cost model.

The paper hand-picks its sync configuration per experiment; Shi et al.
(arXiv:1711.05979) show an α-β-γ performance model can rank such
configurations ahead of time. Ours already matches every measured
BENCH_*.json byte count exactly (bench_fused_step / bench_wire /
bench_overlap gate the per-leg bytes against ``core.cost_model``), so the
search layer is: enumerate the ``CollectivePolicy`` grid, prune every
candidate the ONE ``CollectivePolicy.validate()`` rejects (the guard
message becomes the prune reason — invalid points are ranked out, not
crashed on), score the survivors with ``cost_model`` (per-device wire
bytes of the gradient + param legs, modeled step wall time) and pick the
fastest. ``launch/train.py --policy auto`` and the launcher run this at
startup; ``benchmarks/bench_autotune.py`` gates the predicted-best
against the measured-best bytes/step.

Scoring conventions (matching the fused sharded step the drivers run):

  ring-family   reduce-scatter + allgather, wire-scaled β
                (``grad_leg_bytes`` + ``param_leg_bytes``)
  psum          XLA lowers to the same ring pattern at full precision
  tree          2·ceil(log2 p) full-buffer hops
  per_leaf      ring bytes + one collective launch per leaf (α each)
  overlap       ``overlapped_step_time``: the hidden reduce-scatter
                fraction rides behind backward compute

``compute_s`` is the per-step compute the overlap candidates hide their
wire leg behind; pass 0.0 to rank on pure communication.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core import cost_model
from repro.core.collectives import RING_METHODS, _METHODS
from repro.core.comm import CollectivePolicy
from repro.launch.analysis import HBM_BW, PEAK_FLOPS, train_model_flops

#: deterministic tie-break order among equal-time, equal-byte candidates:
#: prefer the plain single ring (fewest moving parts), then the fancier
#: ring variants, then the XLA-native / reference methods
_METHOD_PREF = ("ring", "multi_ring", "scatter_gather", "psum", "tree",
                "per_leaf")

#: wire preference on exact ties (cheaper wire first is already decided
#: by bytes; this only orders the impossible exact-tie case)
_WIRE_PREF = (None, "bf16", "int8")

#: byte-bucketing grid point (4 MiB — flatbuf's overlap-free bucketed
#: schedules); modeled identically to the monolithic leg, enumerated so
#: the overlap ⇒ no-byte-bucketing guard shows up as a pruned candidate
_BUCKET_CHOICES = (None, 4 << 20)


@dataclass(frozen=True)
class ScoredPolicy:
    """One valid candidate with its cost-model score."""

    policy: CollectivePolicy
    bytes_per_step: float    # per-device wire bytes, grad + param legs
    step_time_s: float       # modeled wall time of one step
    overlap_fraction: float  # structural hidden fraction (0 = none)

    def to_dict(self) -> dict:
        return {"policy": self.policy.to_dict(),
                "bytes_per_step": self.bytes_per_step,
                "step_time_s": self.step_time_s,
                "overlap_fraction": self.overlap_fraction}


@dataclass(frozen=True)
class PrunedPolicy:
    """One grid point ``CollectivePolicy.validate()`` rejected."""

    policy: CollectivePolicy
    reason: str

    def to_dict(self) -> dict:
        return {"policy": self.policy.to_dict(), "reason": self.reason}


@dataclass(frozen=True)
class AutotuneResult:
    chosen: ScoredPolicy
    ranked: tuple            # every valid candidate, best first
    pruned: tuple            # every invalid grid point with its guard
    nbytes: float            # f32 gradient payload the scores assume
    p: int                   # ring size (devices per client)
    compute_s: float         # per-step compute the overlap legs hide in

    def to_dict(self) -> dict:
        return {
            "chosen": self.chosen.to_dict(),
            "ranked": [s.to_dict() for s in self.ranked],
            "pruned": [s.to_dict() for s in self.pruned],
            "nbytes": self.nbytes, "p": self.p, "compute_s": self.compute_s,
        }


def enumerate_policies() -> list[CollectivePolicy]:
    """The full candidate grid, valid and invalid alike.

    Every method × ring count (multi_ring explores 2 and 4 rings) ×
    wire dtype × overlap × byte-bucketing point. Pruning happens in
    ``autotune`` via ``CollectivePolicy.validate()`` so each guard is
    exercised by at least one grid point.
    """
    grid = []
    for method in _METHODS:
        ring_counts = (2, 4) if method == "multi_ring" else (1,)
        for num_rings in ring_counts:
            for wire in (None, "bf16", "int8"):
                for overlap in (False, True):
                    for bucket in _BUCKET_CHOICES:
                        grid.append(CollectivePolicy(
                            method=method, num_rings=num_rings,
                            bucket_bytes=bucket, wire_dtype=wire,
                            overlap=overlap))
    return grid


def policy_bytes_per_step(policy: CollectivePolicy, nbytes: float,
                          p: int) -> float:
    """Per-device wire bytes of one synchronized step under ``policy``.

    Ring-family methods run the wire-scaled reduce-scatter + allgather
    halves (``cost_model.grad_leg_bytes`` / ``param_leg_bytes`` — the
    quantities bench_fused_step / bench_wire measure from the jaxpr);
    psum and per_leaf move the same ring bytes at full precision; tree
    pays 2·ceil(log2 p) full-buffer hops.
    """
    if p <= 1:
        return 0.0
    if policy.method == "tree":
        return 2 * math.ceil(math.log2(p)) * nbytes
    wire = policy.wire if policy.method in RING_METHODS else None
    return (cost_model.grad_leg_bytes(nbytes, p, wire)
            + cost_model.param_leg_bytes(nbytes, p, wire))


def score_policy(policy: CollectivePolicy, *, nbytes: float, p: int,
                 compute_s: float = 0.0,
                 net: Optional[cost_model.NetParams] = None,
                 num_leaves: int = 64) -> ScoredPolicy:
    """Cost-model score of one VALID policy (callers prune first)."""
    net = net or cost_model.tpu_v5e()
    wire = policy.wire if policy.method in RING_METHODS else None
    frac = 0.0
    if policy.overlap:
        bb = [nbytes / policy.overlap_buckets] * policy.overlap_buckets
        time_s = cost_model.overlapped_step_time(compute_s, bb, p, net, wire)
        frac = cost_model.overlap_fraction(bb, p)
    elif policy.method == "per_leaf":
        # the per-leaf reference pays one collective launch per leaf on
        # top of the same ring wire bytes
        time_s = (compute_s + cost_model.ring_allreduce_time(nbytes, p, net)
                  + num_leaves * max(p - 1, 0) * net.alpha)
    else:
        time_s = compute_s + cost_model.allreduce_time(
            nbytes, p, net, policy.method, policy.num_rings, wire)
    return ScoredPolicy(policy=policy,
                        bytes_per_step=policy_bytes_per_step(
                            policy, nbytes, p),
                        step_time_s=time_s, overlap_fraction=frac)


def _rank_key(s: ScoredPolicy):
    pol = s.policy
    return (s.step_time_s, s.bytes_per_step,
            _METHOD_PREF.index(pol.method), pol.num_rings,
            _WIRE_PREF.index(pol.wire), pol.overlap,
            pol.bucket_bytes or 0)


def autotune(*, nbytes: float, p: int, compute_s: float = 0.0,
             net: Optional[cost_model.NetParams] = None,
             num_leaves: int = 64) -> AutotuneResult:
    """Enumerate → prune → score → rank the policy space.

    ``nbytes`` is the packed f32 gradient payload (the FlatBuffer size),
    ``p`` the devices one client syncs over, ``compute_s`` the per-step
    compute time. Returns every valid candidate ranked fastest-first
    (ties broken deterministically by bytes, then method preference),
    plus every pruned grid point with the ``validate()`` message that
    rejected it.
    """
    if p < 1:
        raise ValueError(f"autotune needs p >= 1 devices, got {p}")
    if nbytes <= 0:
        raise ValueError(f"autotune needs a positive payload, got {nbytes}")
    scored, pruned = [], []
    for pol in enumerate_policies():
        try:
            pol.validate(where="autotune")
        except ValueError as e:
            pruned.append(PrunedPolicy(policy=pol, reason=str(e)))
            continue
        scored.append(score_policy(pol, nbytes=nbytes, p=p,
                                   compute_s=compute_s, net=net,
                                   num_leaves=num_leaves))
    ranked = tuple(sorted(scored, key=_rank_key))
    return AutotuneResult(chosen=ranked[0], ranked=ranked,
                          pruned=tuple(pruned), nbytes=nbytes, p=p,
                          compute_s=compute_s)


def fused_step_compute_s(nbytes: float) -> float:
    """Deterministic per-step compute estimate for geometries where only
    the payload is known (the bench harness): the fused update's HBM
    roofline — ~5 full passes over the packed buffer (grad read, param
    read+write, momentum read+write) at ``analysis.HBM_BW``."""
    return 5.0 * nbytes / HBM_BW


def compute_s_for_model(cfg, tokens_per_step: int, p: int) -> float:
    """Per-device per-step compute time of a real model config on the
    roofline: ``6·N·D`` training FLOPs over ``p`` chips at peak."""
    flops = train_model_flops(cfg.param_count(), cfg.active_param_count(),
                              tokens_per_step)
    return flops / (p * PEAK_FLOPS)


def autotune_for_model(cfg, *, p: int, tokens_per_step: int,
                       net: Optional[cost_model.NetParams] = None,
                       ) -> AutotuneResult:
    """``autotune`` for a real ModelConfig: payload = f32 param bytes,
    compute from the 6·N·D roofline at ``p`` chips."""
    nbytes = 4.0 * cfg.param_count()
    return autotune(nbytes=nbytes, p=p,
                    compute_s=compute_s_for_model(cfg, tokens_per_step, p),
                    net=net)


def format_table(result: AutotuneResult, top: int = 5) -> str:
    """Markdown ranking table (README's "Choosing a policy" section)."""
    lines = [
        "| # | method | rings | wire | overlap | bucket | bytes/step"
        " | step time |",
        "|---|--------|-------|------|---------|--------|-----------:"
        "|----------:|",
    ]
    for i, s in enumerate(result.ranked[:top], 1):
        pol = s.policy
        bucket = (f"{pol.bucket_bytes >> 20} MiB" if pol.bucket_bytes
                  else "—")
        lines.append(
            f"| {i} | {pol.method} | {pol.num_rings} "
            f"| {pol.wire_dtype or 'f32'} "
            f"| {'yes' if pol.overlap else 'no'} | {bucket} "
            f"| {s.bytes_per_step:,.0f} | {s.step_time_s * 1e6:,.1f} µs |")
    return "\n".join(lines)
