"""Run a transport job as REAL OS processes on localhost.

``run_job(algo)`` is the multi-process twin of ``algorithms.run(cfg)``:

  tcp       builds the JobSpec, ``emit_scripts`` materializes one shell
            script per server and per worker, and each script is spawned
            with ``/bin/sh`` as its own OS process — the processes find
            each other through an in-process rendezvous served at the
            spec's scheduler address, exactly as a cluster scheduler
            would run the emitted scripts. Worker metrics come back
            through ``outdir/metrics_worker_<rank>.json``.
  loopback  the same rendezvous/KVServer/worker code paths on the
            loopback transport (threads, no sockets) — the bit-exact
            in-process reference the tcp loss curves are gated against.

The aggregated ``JobResult`` mirrors algorithms.History where it can
(per-step mean worker loss in client order, per-epoch metrics) and adds
the transport-side accounting (exit codes, server stats, socket bytes).

Crash recovery (PR 10): the tcp path runs under launch/supervisor.py —
an abnormal exit respawns the unit (schedule- or budget-driven) with
REPRO_ATTEMPT bumped, the dying generation's partial
``metrics_worker_<rank>.json`` is stashed as ``.pre<attempt>.json``,
and ``_collect_worker_metrics`` merges every generation's curve by
global step (the respawn replays from its parked PS state, so the
merged dist_sgd curve is bit-identical to the fault-free run). A spent
restart budget raises ``JobFailed`` carrying the partial JobResult and
the full per-unit exit-code history.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class JobResult:
    transport: str
    losses: list = field(default_factory=list)    # per-step mean over workers
    metrics: list = field(default_factory=list)   # per-epoch (worker 0)
    final_loss: Optional[float] = None
    per_worker: dict = field(default_factory=dict)
    server_stats: dict = field(default_factory=dict)
    exit_codes: dict = field(default_factory=dict)
    degraded_syncs: int = 0
    late_pushes: int = 0
    membership_epochs: int = 0
    live: list = field(default_factory=list)
    script_paths: list = field(default_factory=list)
    outdir: str = ""
    # supervision accounting (tcp): one record per respawn (unit,
    # attempt, exit_code, scheduled?, wall-clock gap), final attempt
    # numbers, exit-code history, and the units whose budget ran out
    respawns: list = field(default_factory=list)
    attempts: dict = field(default_factory=dict)
    exit_history: dict = field(default_factory=dict)
    exhausted: list = field(default_factory=list)


def free_port() -> int:
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _make_spec(algo, *, transport: str, port: int):
    from repro.launch.launcher import JobSpec

    from repro.core.faults import as_schedule

    sched = as_schedule(algo.faults, seed=algo.seed)
    server_sched = as_schedule(getattr(algo, "server_faults", None),
                               seed=algo.seed)
    return JobSpec(
        algo.num_workers, algo.num_servers, algo.effective_clients,
        "qwen3-4b", "train_4k",
        scheduler_host="127.0.0.1", scheduler_port=port,
        faults=sched.format() if sched is not None else "",
        barrier_timeout=algo.barrier_timeout or 0.0,
        restarts=getattr(algo, "restarts", 0),
        restart_backoff=getattr(algo, "restart_backoff", 0.05),
        checkpoint_every=getattr(algo, "checkpoint_every", 0),
        server_faults=(server_sched.format()
                       if server_sched is not None else ""),
        transport=transport, mode=algo.mode, policy=algo.policy)


def _aggregate(result: JobResult, worker_out: dict[int, dict]) -> None:
    """History-shaped curves from per-worker records: per-step mean loss
    over the workers that computed that step (client order), worker 0's
    per-epoch metrics (every replica's params are identical on clean
    sync runs, so the choice only matters after a kill)."""
    result.per_worker = worker_out
    by_step: dict[int, list] = {}
    for rank in sorted(worker_out):
        rec = worker_out[rank]
        for gstep, loss in zip(rec.get("gsteps", []),
                               rec.get("losses", [])):
            by_step.setdefault(int(gstep), []).append(loss)
    result.losses = [float(np.mean(by_step[s])) for s in sorted(by_step)]
    for rank in sorted(worker_out):
        if worker_out[rank].get("metrics"):
            result.metrics = [float(m)
                              for m in worker_out[rank]["metrics"]]
            break
    if result.losses:
        result.final_loss = result.losses[-1]


def _merge_worker_records(recs: list[dict]) -> dict:
    """Fold one worker's metric pieces (pre-kill partials stashed by the
    supervisor, oldest first, then the final record) into one curve:
    losses merge by global step and per-epoch metrics by epoch, with the
    LATER generation winning ties — a replayed step recomputes the same
    loss on the sync path, so ties only differ after esgd drift."""
    by_step: dict[int, float] = {}
    by_epoch: dict[int, float] = {}
    for rec in recs:
        for g, loss in zip(rec.get("gsteps", []), rec.get("losses", [])):
            by_step[int(g)] = float(loss)
        epochs = rec.get("metric_epochs")
        metrics = rec.get("metrics", [])
        if epochs is None:
            epochs = list(range(len(metrics)))
        for e, m in zip(epochs, metrics):
            by_epoch[int(e)] = float(m)
    out = dict(recs[-1])
    out["gsteps"] = sorted(by_step)
    out["losses"] = [by_step[g] for g in out["gsteps"]]
    out["metric_epochs"] = sorted(by_epoch)
    out["metrics"] = [by_epoch[e] for e in out["metric_epochs"]]
    out["pieces"] = len(recs)
    return out


def _collect_worker_metrics(outdir: str, num_workers: int) -> dict[int, dict]:
    """Read every generation's metrics file per worker and merge."""
    worker_out: dict[int, dict] = {}
    names = set(os.listdir(outdir)) if os.path.isdir(outdir) else set()
    for rank in range(num_workers):
        prefix = f"metrics_worker_{rank}.pre"
        stashed = []
        for name in names:
            if name.startswith(prefix) and name.endswith(".json"):
                try:
                    stashed.append(
                        (int(name[len(prefix):-len(".json")]), name))
                except ValueError:
                    continue
        paths = [os.path.join(outdir, n) for _, n in sorted(stashed)]
        final = os.path.join(outdir, f"metrics_worker_{rank}.json")
        if os.path.exists(final):
            paths.append(final)
        recs = []
        for path in paths:
            try:
                with open(path) as f:
                    recs.append(json.load(f))
            except (OSError, ValueError):
                continue            # torn partial flush: skip the piece
        if recs:
            worker_out[rank] = _merge_worker_records(recs)
    return worker_out


def _fold_server_stats(result: JobResult, stats: dict[int, dict]) -> None:
    result.server_stats = stats
    for st in stats.values():
        result.degraded_syncs += int(st.get("degraded_syncs", 0))
        result.late_pushes += int(st.get("late_pushes", 0))
        if int(st.get("membership_epoch", 0)) >= result.membership_epochs:
            result.membership_epochs = int(st.get("membership_epoch", 0))
            result.live = list(st.get("live", []))


def run_job(algo, *, transport: str = "tcp", problem: str = "logreg8",
            outdir: Optional[str] = None, timeout: float = 240.0,
            keep_servers: bool = False) -> JobResult:
    if transport == "tcp":
        return _run_tcp(algo, problem=problem, outdir=outdir,
                        timeout=timeout)
    if transport == "loopback":
        return _run_loopback(algo, problem=problem, timeout=timeout,
                             keep_servers=keep_servers)
    raise ValueError(f"transport must be tcp/loopback, got {transport!r}")


# ---------------------------------------------------------------------------
# tcp: real processes from emitted scripts
# ---------------------------------------------------------------------------

def _child_env() -> dict:
    import repro

    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _run_tcp(algo, *, problem: str, outdir: Optional[str],
             timeout: float) -> JobResult:
    from repro.core.faults import injector
    from repro.launch.launcher import emit_scripts
    from repro.launch.supervisor import JobFailed, RestartPolicy, Supervisor
    from repro.net.rendezvous import Rendezvous, algo_to_dict
    from repro.net.transport import TcpTransport

    outdir = outdir or tempfile.mkdtemp(prefix="repro_tcp_")
    os.makedirs(outdir, exist_ok=True)
    port = free_port()
    spec = _make_spec(algo, transport="tcp", port=port)
    paths = emit_scripts(spec, outdir)
    result = JobResult(transport="tcp", script_paths=paths, outdir=outdir)

    rdzv = Rendezvous(
        num_workers=algo.num_workers, num_servers=algo.num_servers,
        num_clients=algo.effective_clients, algo=algo_to_dict(algo),
        problem=problem, outdir=outdir, transport="tcp")
    tr = TcpTransport()
    rdzv_server = tr.serve(rdzv.handle, "127.0.0.1", port)
    env = _child_env()
    all_procs: list[subprocess.Popen] = []
    logs = []
    script_for: dict[str, str] = {}

    def _spawn_proc(name: str, attempt: int) -> subprocess.Popen:
        # append mode: a respawn's output lands after its predecessor's
        log = open(os.path.join(outdir, f"{name}.log"), "ab")
        logs.append(log)
        child = dict(env, REPRO_ATTEMPT=str(attempt))
        proc = subprocess.Popen(
            ["/bin/sh", script_for[name]], env=child, cwd=outdir,
            stdout=log, stderr=subprocess.STDOUT)
        all_procs.append(proc)
        return proc

    def _stash_metrics(unit) -> None:
        # keep the dying generation's partial curve for the merged
        # loss history (the respawn writes a fresh final file)
        if unit.role != "worker":
            return
        src = os.path.join(outdir, f"metrics_worker_{unit.unit}.json")
        if os.path.exists(src):
            os.replace(src, os.path.join(
                outdir,
                f"metrics_worker_{unit.unit}.pre{unit.attempt}.json"))

    sup = Supervisor(
        lambda unit: _spawn_proc(unit.name, unit.attempt),
        policy=RestartPolicy(
            max_restarts=getattr(algo, "restarts", 0) or 0,
            backoff=getattr(algo, "restart_backoff", 0.05)),
        worker_injector=injector(algo.faults, seed=algo.seed),
        server_injector=injector(getattr(algo, "server_faults", None),
                                 seed=algo.seed),
        on_respawn=_stash_metrics)
    try:
        scripts = ([p for p in paths if "server_" in os.path.basename(p)]
                   + [p for p in paths if "client_" in os.path.basename(p)])
        for path in scripts:
            name = os.path.splitext(os.path.basename(path))[0]
            script_for[name] = path
            role, _, rank = name.partition("_")
            sup.register(name, _spawn_proc(name, 0),
                         role="worker" if role == "client" else "server",
                         unit=int(rank))
        report = sup.supervise(timeout=timeout)
        if report["timed_out"]:
            for u in sup.units.values():
                if u.role == "worker" and u.proc.poll() is None:
                    u.proc.kill()
                    u.proc.wait(timeout=5.0)
        # workers are done: read server stats over a fresh connection
        # (rdzv.server_addrs holds the respawn's re-published address),
        # then tell the server processes to exit
        stats: dict[int, dict] = {}
        for rank, addr in sorted(rdzv.server_addrs.items()):
            try:
                conn = tr.connect(addr, timeout=5.0)
                st, _ = conn.request("stats")
                stats[rank] = st
                conn.request("shutdown")
                conn.close()
            except OSError:
                stats[rank] = {"error": "unreachable"}
        _fold_server_stats(result, stats)
        for name, u in sup.units.items():
            if u.role == "server":
                try:
                    u.proc.wait(timeout=10.0)
                except subprocess.TimeoutExpired:
                    u.proc.kill()
                    u.proc.wait(timeout=5.0)
            result.exit_codes[name] = u.proc.returncode
        result.respawns = report["respawns"]
        result.attempts = report["attempts"]
        result.exit_history = report["exit_history"]
        result.exhausted = report["exhausted"]
    finally:
        for proc in all_procs:
            if proc.poll() is None:
                proc.kill()
        for log in logs:
            log.close()
        rdzv_server.close()
    _aggregate(result, _collect_worker_metrics(outdir, algo.num_workers))
    if result.exhausted:
        raise JobFailed(
            "restart budget exhausted for "
            f"{', '.join(result.exhausted)} (budget="
            f"{getattr(algo, 'restarts', 0)}); exit codes: "
            + "; ".join(f"{n}={result.exit_history.get(n)}"
                        for n in result.exhausted),
            result=result)
    return result


# ---------------------------------------------------------------------------
# loopback: same code paths, threads instead of processes
# ---------------------------------------------------------------------------

def _run_loopback(algo, *, problem: str, timeout: float,
                  keep_servers: bool) -> JobResult:
    from repro.net.kvserver import KVServer
    from repro.net.rendezvous import (Rendezvous, algo_from_dict,
                                      algo_to_dict, join_rendezvous)
    from repro.net.transport import LoopbackTransport
    from repro.net.worker import WorkerKilled, run_worker

    # fail fast with the launcher's actionable message when the config
    # asks for respawns: threads cannot be SIGKILLed and re-exec'd
    _make_spec(algo, transport="loopback", port=0).validate()
    result = JobResult(transport="loopback")
    tr = LoopbackTransport()
    rdzv = Rendezvous(
        num_workers=algo.num_workers, num_servers=algo.num_servers,
        num_clients=algo.effective_clients, algo=algo_to_dict(algo),
        problem=problem, outdir="", transport="loopback")
    rdzv_server = tr.serve(rdzv.handle, "127.0.0.1", 0)
    cfg = algo_from_dict(algo_to_dict(algo))
    kvs, kv_servers = [], []
    for rank in range(algo.num_servers):
        srv = KVServer(cfg, rank=rank)
        server = tr.serve(srv.handle)
        conn = tr.connect(rdzv_server.addr)
        join_rendezvous(conn, "server", rank, addr=server.addr)
        kvs.append(srv)
        kv_servers.append(server)

    worker_out: dict[int, dict] = {}
    errors: dict[int, BaseException] = {}

    def run_one(rank: int) -> None:
        def killed() -> None:
            raise WorkerKilled(rank)

        try:
            worker_out[rank] = run_worker(
                rank=rank, rendezvous_addr=rdzv_server.addr,
                transport="loopback", on_kill=killed)
        except WorkerKilled:
            worker_out[rank] = {"killed": True, "losses": [], "gsteps": [],
                                "metrics": []}
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors[rank] = e

    threads = [threading.Thread(target=run_one, args=(rank,), daemon=True)
               for rank in range(algo.num_workers)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(max(0.5, deadline - time.monotonic()))
    stats = {}
    for rank, srv in enumerate(kvs):
        st, _ = srv.handle("stats", {}, b"")
        stats[rank] = st
    _fold_server_stats(result, stats)
    if not keep_servers:
        for server in kv_servers:
            server.close()
        rdzv_server.close()
    if errors:
        rank, err = sorted(errors.items())[0]
        raise RuntimeError(f"loopback worker {rank} failed: {err!r}") from err
    for rank in range(algo.num_workers):
        result.exit_codes[f"client_{rank}"] = (
            0 if rank in worker_out and "killed" not in worker_out[rank]
            else -9 if rank in worker_out else None)
    _aggregate(result, worker_out)
    return result


def main() -> None:  # pragma: no cover - CLI wrapper over run_job
    import argparse

    from repro.core.algorithms import AlgoConfig

    ap = argparse.ArgumentParser(
        description="run a transport job as local OS processes")
    ap.add_argument("--mode", default="dist_sgd",
                    choices=("dist_sgd", "dist_esgd"))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--transport", default="tcp",
                    choices=("tcp", "loopback"))
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--wire-dtype", default="f32",
                    choices=("f32", "bf16", "int8"))
    ap.add_argument("--faults", default="")
    ap.add_argument("--barrier-timeout", type=float, default=0.0)
    ap.add_argument("--restarts", type=int, default=0,
                    help="per-unit supervised-respawn budget (tcp only)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="durable KV checkpoint + state-parking cadence "
                         "in steps (0 = off)")
    ap.add_argument("--server-faults", default="",
                    help="fault schedule the SERVER tier evaluates")
    ap.add_argument("--outdir", default=None)
    ap.add_argument("--timeout", type=float, default=240.0)
    args = ap.parse_args()
    algo = AlgoConfig(
        mode=args.mode, num_workers=args.workers,
        num_clients=args.workers, num_servers=args.servers,
        lr=args.lr, epochs=args.epochs, steps_per_epoch=args.steps,
        seed=0, wire_dtype=(None if args.wire_dtype == "f32"
                            else args.wire_dtype),
        faults=args.faults or None,
        barrier_timeout=args.barrier_timeout or None,
        restarts=args.restarts,
        checkpoint_every=args.checkpoint_every,
        server_faults=args.server_faults or None)
    res = run_job(algo, transport=args.transport, outdir=args.outdir,
                  timeout=args.timeout)
    print(json.dumps({
        "transport": res.transport, "losses": res.losses,
        "metrics": res.metrics, "final_loss": res.final_loss,
        "exit_codes": res.exit_codes,
        "degraded_syncs": res.degraded_syncs,
        "membership_epochs": res.membership_epochs, "live": res.live,
        "respawns": len(res.respawns),
        "respawn_gaps_s": [round(r["gap_s"], 4) for r in res.respawns],
        "attempts": res.attempts,
    }, indent=2))


if __name__ == "__main__":  # pragma: no cover
    main()
