"""Production train step + training-loop driver.

``make_train_step`` builds the jittable step for both lowerable sync
modes (core/hierarchy.py):

  mpi_sgd   C=1: one communicator; grads allreduced over every data axis
            per step (pure-MPI pushpull == tensor allreduce, #servers=0)
  mpi_esgd  C>1: params carry a leading client dim sharded over 'pod';
            vmap gives each client an independent replica whose gradient
            sync happens only over 'data' (intra-client); every INTERVAL
            steps the elastic exchange (eqs. 2/3) crosses 'pod' — the
            only cross-pod traffic.

The optimizer is momentum SGD by default (what the paper ships to the PS);
state lives in a TrainState pytree so checkpointing is one call.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.elastic import elastic_exchange_multiclient
from repro.core.hierarchy import SyncConfig, clientize, clientize_specs
from repro.models.model import Model
from repro.optim.sgd import Optimizer
from repro.sharding.rules import batch_pspec, param_specs


def make_train_state(model: Model, optimizer: Optimizer, sync: SyncConfig,
                     rng: jax.Array | None = None, *, abstract: bool = False):
    """Concrete (or eval_shape'd) initial state."""
    rng = jax.random.key(0) if rng is None else rng

    def build(rng):
        params = model.init(rng)
        state = {
            "params": clientize(params, sync.num_clients),
            "opt": clientize(optimizer.init(params), sync.num_clients),
            "step": jnp.zeros((), jnp.int32),
        }
        if sync.mode == "mpi_esgd":
            state["center"] = params  # center variables w̃ (eq. 2)
        return state

    if abstract:
        return jax.eval_shape(build, rng)
    return build(rng)


def state_specs(state: Any, mesh: Mesh, sync: SyncConfig) -> Any:
    """PartitionSpecs for a TrainState (params rules + client dim)."""
    C = sync.num_clients
    base_params = state["params"]
    if C > 1:
        base_params = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), base_params
        )
    pspecs = param_specs(base_params, mesh, fsdp=sync.fsdp)
    out = {
        "params": clientize_specs(pspecs, C),
        "opt": clientize_specs(param_specs_like(state["opt"], base_params, pspecs, C), C)
        if _opt_matches(state["opt"], base_params)
        else jax.tree.map(lambda _: P(), state["opt"]),
        "step": P(),
    }
    if "center" in state:
        out["center"] = pspecs
    return out


def _opt_matches(opt_state: Any, params: Any) -> bool:
    try:
        jax.tree.map(lambda a, b: None, opt_state, params)
        return True
    except ValueError:
        return False


def param_specs_like(opt_state, base_params, pspecs, C):
    """Optimizer state mirrors param tree (momentum) -> same specs."""
    if C > 1:
        opt_state = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), opt_state
        )
    return jax.tree.map(lambda s: s, pspecs)


def make_train_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                    mesh: Mesh, *, microbatch: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch`` > 1 splits the per-step batch into M accumulation steps
    — the paper's distinction between the *batch* (MXNET's scheduling
    unit) and the algorithmic *mini_batch_size* (§5), and the standard
    memory-term lever (only 1/M of the activations live at once).
    """
    C = sync.num_clients

    # the gradient accumulator is a while-loop carry: without an explicit
    # constraint GSPMD replicates it (measured: +32 GB/dev on qwen3-4b),
    # so pin it to the params' sharding when a mesh is known
    acc_shardings = None
    if mesh is not None and C <= 1 and microbatch > 1:
        abstract = jax.eval_shape(model.init, jax.random.key(0))
        acc_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            param_specs(abstract, mesh, fsdp=sync.fsdp),
        )

    def _pin(grads):
        if acc_shardings is None:
            return grads
        return jax.tree.map(
            jax.lax.with_sharding_constraint, grads, acc_shardings
        )

    def single_grad(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def one_client_grad(params, batch):
        if microbatch <= 1:
            return single_grad(params, batch)
        M = microbatch
        mb = jax.tree.map(
            lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]), batch
        )
        g0 = _pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        ))
        m0 = jax.eval_shape(lambda b: single_grad(params, b)[1],
                            jax.tree.map(lambda a: a[0], mb))
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)

        def body(carry, mbatch):
            loss_acc, met_acc, g_acc = carry
            loss, metrics, grads = single_grad(params, mbatch)
            g_acc = _pin(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads
            ))
            met_acc = jax.tree.map(jnp.add, met_acc, metrics)
            return (loss_acc + loss, met_acc, g_acc), None

        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), m0, g0), mb
        )
        grads = jax.tree.map(
            lambda g, p: (g / M).astype(p.dtype), grads, params
        )
        metrics = jax.tree.map(lambda m: m / M, metrics)
        return loss / M, metrics, grads

    def step_c1(state, batch):
        loss, metrics, grads = one_client_grad(state["params"], batch)
        new_p, new_o = optimizer.update(grads, state["opt"], state["params"])
        return (
            {"params": new_p, "opt": new_o, "step": state["step"] + 1},
            {"loss": loss, **metrics},
        )

    def step_multiclient(state, batch):
        # batch leaves have a leading client dim C (sharded over 'pod')
        loss, metrics, grads = jax.vmap(one_client_grad)(state["params"], batch)
        new_p, new_o = jax.vmap(optimizer.update)(
            grads, state["opt"], state["params"]
        )
        new_state = dict(state, params=new_p, opt=new_o, step=state["step"] + 1)

        if sync.mode == "mpi_esgd":
            def exchange(s):
                p2, c2 = elastic_exchange_multiclient(
                    s["params"], s["center"], sync.esgd_alpha / C
                )
                return dict(s, params=p2, center=c2)

            new_state = jax.lax.cond(
                (state["step"] % sync.esgd_interval) == 0,
                exchange, lambda s: s, new_state,
            )
        return new_state, {"loss": jnp.mean(loss),
                           **jax.tree.map(jnp.mean, metrics)}

    return step_c1 if C <= 1 else step_multiclient


def batch_specs(model: Model, shape, mesh: Mesh, sync: SyncConfig) -> Any:
    """PartitionSpecs for the input batch (client dim first when C>1)."""
    specs = model.input_specs(shape)
    C = sync.num_clients

    def one(name, leaf):
        extra = len(leaf.shape) - 1
        bp = batch_pspec(mesh, leaf.shape[0], extra_dims=extra)
        return bp

    base = {k: one(k, v) for k, v in specs.items()}
    if C > 1:
        # (C, B/C, ...): client dim on 'pod', batch dim on 'data'
        def reclient(name, leaf, spec):
            dims = [None] * len(leaf.shape)
            return P("pod", "data", *dims[2:])

        return {
            k: reclient(k, v, base[k]) for k, v in clientize_batch_specs(specs, C).items()
        }
    return base


def clientize_batch_specs(specs: Any, C: int) -> Any:
    return {
        k: jax.ShapeDtypeStruct((C, v.shape[0] // C) + v.shape[1:], v.dtype)
        for k, v in specs.items()
    }


def train_loop(model: Model, optimizer: Optimizer, sync: SyncConfig,
               mesh: Mesh, batches, *, rng=None, log_every: int = 10,
               callback: Optional[Callable] = None):
    """Concrete training driver (examples / smoke scale)."""
    state = make_train_state(model, optimizer, sync, rng)
    step_fn = jax.jit(make_train_step(model, optimizer, sync, mesh))
    history = []
    for i, batch in enumerate(batches):
        state, metrics = step_fn(state, batch)
        if i % log_every == 0:
            entry = {k: float(v) for k, v in metrics.items()}
            entry["step"] = i
            history.append(entry)
            if callback:
                callback(entry)
    return state, history
