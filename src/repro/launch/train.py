"""Production train step + training-loop driver.

``make_train_step`` builds the jittable step for both lowerable sync
modes (core/hierarchy.py):

  mpi_sgd   C=1: one communicator; grads synced over every data axis per
            step (pure-MPI pushpull == tensor allreduce, #servers=0)
  mpi_esgd  C>1: params carry a leading client dim sharded over 'pod';
            vmap gives each client an independent replica whose gradient
            sync happens only over 'data' (intra-client); every INTERVAL
            steps the elastic exchange (eqs. 2/3) crosses 'pod' — the
            only cross-pod traffic.

HOW each leg syncs is no longer decided here: ``core.sync_engine``
resolves the strategy once (``make_sync_engine``) and the step drives
its interface. On the default no-mesh path BOTH modes ride the
flat-buffer substrate:

  * the gradient/update leg packs into a persistent ``FlatBuffer`` (spec
    built ONCE at trace time — no per-step concatenate), ring
    reduce-scatters, runs the fused momentum-SGD Pallas kernel on the
    local 1/p shard (momentum sharded: p× optimizer-memory reduction),
    and ring-allgathers updated params — (p-1)/p·n gradient-leg bytes
    instead of a full allreduce's 2·(p-1)/p·n;
  * the elastic leg packs params and centers and runs ONE fused Pallas
    kernel for eqs. (2)+(3) (one HBM pass, one launch) instead of
    O(num_leaves) tree.maps.

The paths are collective-explicit: they engage when no mesh is given
(single-process drivers, shard_map worker programs — see
launch/shard_driver.py — and vmap emulation; ``axis_name`` names the
device axis). With a mesh, GSPMD keeps inserting the gradient
collectives and the per-leaf legs are kept so parameter sharding is
undisturbed.

The optimizer is momentum SGD by default (what the paper ships to the PS);
state lives in a TrainState pytree so checkpointing is one call.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import comm as comm_lib, flatbuf
from repro.core.hierarchy import (
    SyncConfig,
    clientize,
    clientize_specs,
    should_elastic_sync,
)
from repro.core.sync_engine import (
    flat_exchange_active,
    flat_update_supported,
    make_sync_engine,
)
from repro.models.model import Model
from repro.optim.sgd import Optimizer
from repro.sharding.rules import batch_pspec, param_specs


def fused_path_active(optimizer: Optimizer, sync: SyncConfig,
                      mesh: Mesh | None = None) -> bool:
    """Whether the flat fused update replaces the per-leaf update.

    Back-compat shim over ``core.sync_engine.flat_update_supported`` —
    since the SyncEngine refactor it covers mpi_esgd (C>1) too, where
    each client's local update is the p=1 fused kernel.
    make_train_state and make_train_step must agree, so both call this
    with the same mesh.
    """
    return flat_update_supported(optimizer, sync, mesh)


def grad_spec(model: Model) -> flatbuf.FlatBuffer:
    """The persistent FlatBuffer spec for this model's gradient pytree —
    built once (static lane-aligned offsets) and reused every step."""
    abstract = jax.eval_shape(model.init, jax.random.key(0))
    return flatbuf.spec_for(abstract)


def _engine_spec(model: Model, optimizer: Optimizer, sync: SyncConfig,
                 mesh: Mesh | None):
    """The FlatBuffer spec, when any flat leg will engage (else None)."""
    if (flat_update_supported(optimizer, sync, mesh)
            or flat_exchange_active(sync, mesh)):
        return grad_spec(model)
    return None


def overlap_schedule(model: Model, sync: SyncConfig, p: int = 1):
    """(OverlapStages, BucketSchedule) for the backward-overlapped path.

    The schedule is built ONCE over the STAGED param spec — the FlatBuffer
    of ``stage(params)``'s stage-subtree tuple, whose leaf order groups
    each backward stage's params contiguously so every schedule bucket is
    a leaf-boundary (lane-aligned) slice. ``p`` is the gradient group's
    shard count (``comm.resolve_size()``; 1 for the local state
    geometry).
    """
    if model.overlap_stages is None:
        raise ValueError(
            f"SyncConfig.overlap=True but model {model.cfg.name!r} does "
            "not publish overlap_stages — the staged-backward hook is "
            "wired for the decoder family (models/model.py "
            "_decoder_overlap_stages); run this architecture without "
            "overlap")
    stages = model.overlap_stages(sync.overlap_buckets)
    abstract = jax.eval_shape(model.init, jax.random.key(0))
    staged = jax.eval_shape(stages.stage, abstract)
    spec = flatbuf.spec_for(staged)
    counts = tuple(len(jax.tree_util.tree_leaves(s)) for s in staged)
    return stages, flatbuf.bucket_schedule(spec, counts, p)


def make_overlap_grad_fn(model: Model, stages, schedule,
                         comm: comm_lib.Communicator) -> Callable:
    """``(params, batch) -> (loss, metrics, g_shard)`` with the wire leg
    issued DURING backward.

    Forward runs stage-by-stage under ``jax.vjp`` (recording one pullback
    per stage); backward then replays the pullbacks in reverse AT TRACE
    TIME, and bucket ``s``'s ring reduce-scatter is emitted immediately
    after stage ``s``'s pullback — so in the traced program every
    bucket's ppermute chain except the first-issued one sits BEFORE
    later (earlier-layer) backward compute, where the scheduler can
    overlap wire and math. ``g_shard`` is the bucket-major
    ``(schedule.shard_size,)`` concat of this device's reduced chunks —
    feed it to ``FlatEngine.update_overlapped`` / ``optim.sgd.
    overlap_update``.
    """
    S = stages.num_stages

    def grad_fn(params, batch):
        parts = stages.stage(params)
        # forward: record one pullback per stage
        vjps = [None] * S
        carry = None
        for s in range(S):
            fn = stages.fns[s]
            if S == 1:  # degenerate single bucket: the whole loss_fn
                loss, vjps[0], metrics = jax.vjp(
                    lambda p, fn=fn: fn(p, batch), parts[0], has_aux=True)
            elif s == 0:
                carry, vjps[0] = jax.vjp(
                    lambda p, fn=fn: fn(p, batch), parts[0])
            elif s < S - 1:
                carry, vjps[s] = jax.vjp(
                    lambda p, c, fn=fn: fn(p, c, batch), parts[s], carry)
            else:
                loss, vjps[s], metrics = jax.vjp(
                    lambda p, c, fn=fn: fn(p, c, batch), parts[s], carry,
                    has_aux=True)
        # backward: reversed stage order (head first, embedding last),
        # each bucket's reduce-scatter issued as soon as its grads exist
        shards = [None] * S
        ct: Any = jnp.ones((), loss.dtype)
        for s in range(S - 1, -1, -1):
            if s > 0:
                gp, ct = vjps[s](ct)
            else:
                (gp,) = vjps[0](ct)
            shards[s] = comm.reduce_scatter_bucket(
                schedule.pack_bucket(s, gp), schedule, s)
        g_shard = shards[0] if S == 1 else jnp.concatenate(shards)
        return loss, metrics, g_shard

    return grad_fn


def make_train_state(model: Model, optimizer: Optimizer, sync: SyncConfig,
                     rng: jax.Array | None = None, *, abstract: bool = False,
                     mesh: Mesh | None = None):
    """Concrete (or eval_shape'd) initial state.

    On the fused path the optimizer state is the flat state buffer
    (momentum / AdaGrad accumulator / AdamW m+v streams) in local (p=1)
    geometry — one per client when C>1; device-sharded drivers
    (shard_map / vmap emulation) re-init it per device with
    ``optim.sgd.optstate_shard_init``.
    """
    rng = jax.random.key(0) if rng is None else rng
    schedule = None
    if sync.overlap:
        _, schedule = overlap_schedule(model, sync, 1)
    engine = make_sync_engine(optimizer, sync, mesh,
                              spec=_engine_spec(model, optimizer, sync, mesh),
                              schedule=schedule)

    def build(rng):
        params = model.init(rng)
        opt0 = engine.init_opt(params)
        state = {
            "params": clientize(params, sync.num_clients),
            "opt": clientize(opt0, sync.num_clients),
            "step": jnp.zeros((), jnp.int32),
        }
        if sync.mode == "mpi_esgd":
            state["center"] = params  # center variables w̃ (eq. 2)
        return state

    if abstract:
        return jax.eval_shape(build, rng)
    return build(rng)


def state_specs(state: Any, mesh: Mesh, sync: SyncConfig) -> Any:
    """PartitionSpecs for a TrainState (params rules + client dim).

    Optimizer state that mirrors the param tree (per-leaf momentum)
    shares the param specs; anything else (flat fused buffers, custom
    states) is replicated.
    """
    C = sync.num_clients
    base_params = state["params"]
    if C > 1:
        base_params = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), base_params
        )
    pspecs = param_specs(base_params, mesh, fsdp=sync.fsdp)
    out = {
        "params": clientize_specs(pspecs, C),
        "opt": clientize_specs(pspecs, C)
        if _opt_matches(state["opt"], base_params)
        else jax.tree.map(lambda _: P(), state["opt"]),
        "step": P(),
    }
    if "center" in state:
        out["center"] = pspecs
    return out


def _opt_matches(opt_state: Any, params: Any) -> bool:
    try:
        jax.tree.map(lambda a, b: None, opt_state, params)
        return True
    except ValueError:
        return False


def make_grad_fn(model: Model, microbatch: int = 1,
                 pin: Optional[Callable] = None) -> Callable:
    """Build ``(params, batch) -> (loss, metrics, grads)`` for one client.

    ``microbatch`` > 1 splits the per-step batch into M accumulation
    steps — the paper's distinction between the *batch* (MXNET's
    scheduling unit) and the algorithmic *mini_batch_size* (§5), and the
    standard memory-term lever (only 1/M of the activations live at
    once). ``pin`` optionally constrains the accumulator's sharding.

    Shared by launch/train.py and launch/shard_driver.py so the mapped
    per-device step computes grads with exactly the single-process math.
    """
    pin = pin or (lambda g: g)

    def single_grad(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    if microbatch <= 1:
        return single_grad
    M = microbatch

    def accum_grad(params, batch):
        mb = jax.tree.map(
            lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]), batch
        )
        g0 = pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        ))
        m0 = jax.eval_shape(lambda b: single_grad(params, b)[1],
                            jax.tree.map(lambda a: a[0], mb))
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)

        def body(carry, mbatch):
            loss_acc, met_acc, g_acc = carry
            loss, metrics, grads = single_grad(params, mbatch)
            g_acc = pin(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads
            ))
            met_acc = jax.tree.map(jnp.add, met_acc, metrics)
            return (loss_acc + loss, met_acc, g_acc), None

        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), m0, g0), mb
        )
        grads = jax.tree.map(
            lambda g, p: (g / M).astype(p.dtype), grads, params
        )
        metrics = jax.tree.map(lambda m: m / M, metrics)
        return loss / M, metrics, grads

    return accum_grad


def make_train_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                    mesh: Mesh, *, microbatch: int = 1,
                    comm: comm_lib.Communicator | None = None,
                    axis_name: str | None = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``comm`` is the gradient communicator for the fused sync path when
    the step runs inside shard_map (real mesh) or vmap (emulation);
    omitted (and with no deprecated ``axis_name``), the group is trivial
    — single-process: the fused update still runs (one Pallas grid over
    the whole flat buffer) with no collective.
    """
    C = sync.num_clients
    if mesh is not None:
        sync.validate(mesh)
    # C>1 vmaps the update over the client dim, so each client's sync
    # geometry is local (the trivial group inside the vmap)
    if comm is None:
        axes = (axis_name,) if (axis_name is not None and C <= 1) else ()
        comm = comm_lib.from_sync(sync, axes)
    elif C > 1:
        comm = comm.local()
    stages = schedule = None
    if sync.overlap:
        sync.validate(mesh)  # overlap guards apply even with no mesh
        if microbatch > 1:
            raise ValueError(
                "overlap=True with microbatch>1 would re-issue every "
                "schedule bucket's ring leg per accumulation step (M× the "
                "wire bytes — exactly the traffic overlap exists to "
                "hide); accumulate without overlap, or raise the per-step "
                "batch instead")
        stages, schedule = overlap_schedule(model, sync, comm.resolve_size())
    engine = make_sync_engine(
        optimizer, sync, mesh, comm=comm,
        spec=_engine_spec(model, optimizer, sync, mesh),
        schedule=schedule)

    if sync.overlap:
        ograd_fn = make_overlap_grad_fn(model, stages, schedule, comm)

        def step_overlap(state, batch):
            engine.check_opt_layout(state["opt"])
            loss, metrics, g_shard = ograd_fn(state["params"], batch)
            staged = stages.stage(state["params"])
            new_staged, new_o = engine.update_overlapped(
                g_shard, staged, state["opt"])
            return (
                {"params": stages.unstage(new_staged), "opt": new_o,
                 "step": state["step"] + 1},
                {"loss": loss, **metrics},
            )

        return step_overlap  # overlap is mpi_sgd / C=1 (validate)

    # the gradient accumulator is a while-loop carry: without an explicit
    # constraint GSPMD replicates it (measured: +32 GB/dev on qwen3-4b),
    # so pin it to the params' sharding when a mesh is known
    pin = None
    if mesh is not None and C <= 1 and microbatch > 1:
        abstract = jax.eval_shape(model.init, jax.random.key(0))
        acc_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            param_specs(abstract, mesh, fsdp=sync.fsdp),
        )
        pin = lambda grads: jax.tree.map(
            jax.lax.with_sharding_constraint, grads, acc_shardings
        )

    grad_fn = make_grad_fn(model, microbatch, pin)

    def step_c1(state, batch):
        engine.check_opt_layout(state["opt"])
        loss, metrics, grads = grad_fn(state["params"], batch)
        new_p, new_o = engine.update(grads, state["opt"], state["params"])
        return (
            {"params": new_p, "opt": new_o, "step": state["step"] + 1},
            {"loss": loss, **metrics},
        )

    def step_multiclient(state, batch):
        # batch leaves have a leading client dim C (sharded over 'pod')
        engine.check_opt_layout(state["opt"], C)
        loss, metrics, grads = jax.vmap(grad_fn)(state["params"], batch)
        new_p, new_o = jax.vmap(engine.update)(
            grads, state["opt"], state["params"]
        )
        new_state = dict(state, params=new_p, opt=new_o, step=state["step"] + 1)

        if sync.mode == "mpi_esgd":
            def exchange(s):
                p2, c2 = engine.exchange_multiclient(
                    s["params"], s["center"], sync.esgd_alpha / C
                )
                return dict(s, params=p2, center=c2)

            new_state = jax.lax.cond(
                should_elastic_sync(state["step"], sync.esgd_interval),
                exchange, lambda s: s, new_state,
            )
        return new_state, {"loss": jnp.mean(loss),
                           **jax.tree.map(jnp.mean, metrics)}

    return step_c1 if C <= 1 else step_multiclient


def batch_specs(model: Model, shape, mesh: Mesh, sync: SyncConfig) -> Any:
    """PartitionSpecs for the input batch (client dim first when C>1)."""
    specs = model.input_specs(shape)
    C = sync.num_clients

    def one(name, leaf):
        extra = len(leaf.shape) - 1
        bp = batch_pspec(mesh, leaf.shape[0], extra_dims=extra)
        return bp

    base = {k: one(k, v) for k, v in specs.items()}
    if C > 1:
        # (C, B/C, ...): client dim on 'pod', batch dim on 'data'
        def reclient(name, leaf, spec):
            dims = [None] * len(leaf.shape)
            return P("pod", "data", *dims[2:])

        return {
            k: reclient(k, v, base[k]) for k, v in clientize_batch_specs(specs, C).items()
        }
    return base


def clientize_batch_specs(specs: Any, C: int) -> Any:
    return {
        k: jax.ShapeDtypeStruct((C, v.shape[0] // C) + v.shape[1:], v.dtype)
        for k, v in specs.items()
    }


def train_loop(model: Model, optimizer: Optimizer, sync: SyncConfig,
               mesh: Mesh, batches, *, rng=None, log_every: int = 10,
               callback: Optional[Callable] = None,
               checkpoint_every: int = 0, checkpoint_dir: str = "",
               restore: str = ""):
    """Concrete training driver (examples / smoke scale).

    ``checkpoint_every``/``checkpoint_dir`` write atomic durable
    checkpoints (checkpoint/checkpoint.py) of the full TrainState every
    N completed steps; ``restore`` loads one and fast-forwards past the
    steps it already covers (the data pipeline is deterministic per
    step, so the resumed curve continues the original).
    """
    from repro.checkpoint import checkpoint as ckpt

    state = make_train_state(model, optimizer, sync, rng, mesh=mesh)
    start = 0
    if restore:
        state, meta = ckpt.restore_checkpoint(restore, state)
        start = int(meta.get("step", 0))
    step_fn = jax.jit(make_train_step(model, optimizer, sync, mesh))
    history = []
    for i, batch in enumerate(batches):
        if i < start:
            continue            # covered by the restored checkpoint
        state, metrics = step_fn(state, batch)
        if i % log_every == 0:
            entry = {k: float(v) for k, v in metrics.items()}
            entry["step"] = i
            history.append(entry)
            if callback:
                callback(entry)
        if (checkpoint_every and checkpoint_dir
                and (i + 1) % checkpoint_every == 0):
            ckpt.save_checkpoint(
                ckpt.checkpoint_path(checkpoint_dir, i + 1), state,
                step=i + 1)
    return state, history


def main() -> None:  # pragma: no cover (CLI driver; see tests/test_launch.py)
    """The per-client worker entry point the launcher's emitted
    ``mpirun ... python -m repro.launch.train`` commands invoke.

    One process == one MPI client (C=1 inside the process; the PS tier
    glues clients together, so --client/--num-clients/--scheduler are
    recorded for the job spec but the in-process sync mode is mpi_sgd).
    Sync knobs arrive as the flags launcher.JobSpec threads through
    (--optimizer / --fused-update / --no-fused-update / --flat-exchange /
    --no-flat-exchange / --bucket-bytes) and are lowered via
    configs.base.TrainSettings.
    """
    import argparse
    import os

    from repro.configs.base import TrainSettings, get_config, reduced
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models.model import build_model

    ap = argparse.ArgumentParser(description="per-client training worker")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--shape", default="train_4k",
                    help="job-spec input shape id (recorded)")
    ap.add_argument("--client", type=int, default=0)
    ap.add_argument("--num-clients", type=int, default=1)
    ap.add_argument("--scheduler", default=None,
                    help="scheduler host:port from the job spec (recorded; "
                         "the single-process reproduction runs standalone)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "adagrad", "adamw"),
                    help="update rule; every choice rides the fused flat "
                         "path when --fused-update is set")
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--fused-update", dest="fused_update",
                    action="store_true", default=True)
    ap.add_argument("--no-fused-update", dest="fused_update",
                    action="store_false")
    ap.add_argument("--flat-exchange", dest="flat_exchange",
                    action="store_true", default=True)
    ap.add_argument("--no-flat-exchange", dest="flat_exchange",
                    action="store_false")
    ap.add_argument("--bucket-bytes", type=int, default=0)
    ap.add_argument("--wire-dtype", default="f32",
                    choices=("f32", "bf16", "int8"),
                    help="low-precision wire protocol on the ring hops "
                         "(requires a ring-family --allreduce method; "
                         "f32 = full precision)")
    ap.add_argument("--state-dtype", default="f32",
                    choices=("f32", "bf16"),
                    help="flat optimizer-state stream dtype (bf16 halves "
                         "AdaGrad/AdamW state bytes per device)")
    ap.add_argument("--overlap", action="store_true", default=False,
                    help="backward-overlapped bucketed reduce-scatter: "
                         "stage backprop and issue each schedule bucket's "
                         "ring leg while earlier layers still "
                         "differentiate (forces a ring allreduce and "
                         "num_rings=1)")
    ap.add_argument("--overlap-buckets", type=int, default=4,
                    help="schedule buckets == backward stages "
                         "(1 = degenerate non-overlapped schedule)")
    ap.add_argument("--allreduce", default=None,
                    choices=("psum", "ring", "multi_ring", "tree",
                             "scatter_gather"),
                    help="intra-client collective (default: psum, or ring "
                         "when --wire-dtype is low-precision)")
    ap.add_argument("--num-rings", type=int, default=0,
                    help="concurrent rings for ring-family methods "
                         "(0 = default: 2, or 1 under --overlap)")
    ap.add_argument("--policy", default=None, choices=("auto",),
                    help="'auto' ranks the collective-policy space with "
                         "the cost model (launch.autotune) and runs the "
                         "fastest valid policy, overriding the flat "
                         "--allreduce/--num-rings/--wire-dtype/--overlap "
                         "knobs")
    ap.add_argument("--tune-p", type=int, default=8,
                    help="devices per client --policy auto scores the "
                         "candidates at (the job geometry)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault schedule (core/faults.py "
                         "string form, e.g. 'kill@12:unit=1'); validated "
                         "here, injected by the drivers that own a clock "
                         "(core/algorithms.py, shard_driver.drive)")
    ap.add_argument("--barrier-timeout", type=float, default=None,
                    help="seconds before the sync PS barrier releases "
                         "with the survivor group (kill/drop schedules "
                         "need it)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="durable checkpoint cadence in completed steps "
                         "(0 = off); transport workers park PS state at "
                         "this cadence instead")
    ap.add_argument("--checkpoint-dir", default="checkpoints",
                    help="directory the in-process loop checkpoints into")
    ap.add_argument("--restore", default="",
                    help="checkpoint path to restore params/opt-state/"
                         "step from before stepping")
    ap.add_argument("--full-size", action="store_true",
                    help="full architecture (default: reduced smoke config)")
    ap.add_argument("--transport", default="loopback",
                    choices=("loopback", "tcp"),
                    help="'tcp' makes this process a real transport worker: "
                         "it joins the rendezvous, gets its identity, and "
                         "runs net/worker.py's loop against the socket PS "
                         "tier ('loopback' keeps the standalone in-process "
                         "reproduction below)")
    ap.add_argument("--rendezvous",
                    default=os.environ.get("REPRO_RDZV_ADDR"),
                    help="rendezvous host:port for --transport tcp "
                         "(default: $REPRO_RDZV_ADDR from the emitted "
                         "script)")
    ap.add_argument("--mode", default="",
                    help="transport algorithm mode (dist_sgd / dist_esgd); "
                         "the job config from the rendezvous is "
                         "authoritative, this is recorded for the spec")
    ap.add_argument("--problem", default="logreg8",
                    help="transport training problem (net/problem.py)")
    args = ap.parse_args()

    if args.transport == "tcp":
        import json as _json

        from repro.net.worker import _jsonable, run_worker

        if not args.rendezvous:
            ap.error("--transport tcp needs --rendezvous (or "
                     "REPRO_RDZV_ADDR in the environment)")
        rank = int(os.environ.get("REPRO_RANK", args.client))
        attempt = int(os.environ.get("REPRO_ATTEMPT", "0"))
        out = run_worker(rank=rank, rendezvous_addr=args.rendezvous,
                         transport="tcp", attempt=attempt)
        from repro.net.transport import connect_with_retry, transport_for

        conn = connect_with_retry(transport_for("tcp"), args.rendezvous)
        config, _ = conn.request("config")
        conn.close()
        outdir = config.get("outdir")
        if outdir:
            path = os.path.join(outdir, f"metrics_worker_{rank}.json")
            with open(path, "w") as f:
                _json.dump(_jsonable(out), f, indent=2)
        print(f"[train] transport worker {rank} done: "
              f"{len(out.get('losses', []))} steps, "
              f"final loss "
              f"{out['losses'][-1] if out.get('losses') else None}",
              flush=True)
        return

    from repro.core.comm import CollectivePolicy

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    if args.policy == "auto":
        from repro.configs.base import INPUT_SHAPES
        from repro.launch.autotune import autotune_for_model, format_table

        shape = INPUT_SHAPES.get(args.shape)
        tokens = (shape.seq_len * shape.global_batch if shape is not None
                  else 1 << 20)
        result = autotune_for_model(cfg, p=args.tune_p,
                                    tokens_per_step=tokens)
        pol = result.chosen.policy
        print(f"[train] --policy auto: ranked "
              f"{len(result.ranked)} valid / {len(result.pruned)} pruned "
              f"candidates at p={result.p}, "
              f"payload={result.nbytes:.0f} B", flush=True)
        print(format_table(result), flush=True)
    else:
        method = args.allreduce or (
            "psum" if args.wire_dtype == "f32" and not args.overlap
            else "ring")
        pol = CollectivePolicy(
            method=method,
            num_rings=1 if args.overlap else (args.num_rings or 2),
            bucket_bytes=args.bucket_bytes or None,
            wire_dtype=(None if args.wire_dtype == "f32"
                        else args.wire_dtype),
            overlap=args.overlap, overlap_buckets=args.overlap_buckets)
    settings = TrainSettings(lr=args.lr, momentum=args.momentum,
                             optimizer_name=args.optimizer,
                             weight_decay=args.weight_decay,
                             fused_update=args.fused_update,
                             flat_exchange=args.flat_exchange,
                             policy=pol,
                             state_dtype=args.state_dtype,
                             faults=args.faults,
                             barrier_timeout=args.barrier_timeout,
                             checkpoint_every=args.checkpoint_every,
                             restore=args.restore)
    settings.fault_schedule()  # parse errors surface before any compute
    model = build_model(cfg)
    sync = settings.sync_config()
    optimizer = settings.optimizer()
    pipe = TokenPipeline(DataConfig(
        seed=0, vocab_size=min(cfg.padded_vocab, 256), seq_len=64,
        batch_size=8, steps_per_epoch=args.steps, shard=args.client))
    print(f"[train] client {args.client}/{args.num_clients} arch={cfg.name} "
          f"shape={args.shape} scheduler={args.scheduler} "
          f"optimizer={settings.optimizer_name} "
          f"fused_update={settings.fused_update} "
          f"bucket_bytes={settings.bucket_bytes} "
          f"wire_dtype={settings.wire_dtype} "
          f"state_dtype={settings.state_dtype} "
          f"overlap={settings.overlap} "
          f"overlap_buckets={settings.overlap_buckets} "
          f"faults={settings.faults!r} "
          f"barrier_timeout={settings.barrier_timeout}", flush=True)
    _, hist = train_loop(model, optimizer, sync, None, pipe.epoch(0),
                         log_every=max(args.steps // 10, 1),
                         checkpoint_every=settings.checkpoint_every,
                         checkpoint_dir=args.checkpoint_dir,
                         restore=settings.restore)
    for entry in hist:
        print(f"step {entry['step']:4d} loss {entry['loss']:.4f}", flush=True)
    print(f"[train] done: {len(hist)} log points, "
          f"final loss {hist[-1]['loss']:.4f}", flush=True)


if __name__ == "__main__":  # pragma: no cover
    main()
