"""shard_map production driver: the whole train step per-device.

launch/train.py's collective-explicit fused path engages when no mesh is
given; under GSPMD (a mesh) XLA owns the gradient collectives. This
driver closes the gap between the two (ROADMAP open item 3): it runs
BOTH lowerable modes with the step mapped per-device over a real mesh
axis via ``compat.shard_map`` — gradients computed INSIDE the mapped
function on the device's batch shard, explicit ring collectives carrying
every byte of cross-device traffic (GSPMD inserts nothing), optimizer
state sharded with ``optstate_shard_init`` (momentum SGD, AdaGrad, or
AdamW — AdamW's two full-size moment streams both live 1/p per device):

  mpi_sgd   the device axis is the intra-client MPI communicator: pack
            grads into the FlatBuffer -> ring reduce-scatter -> fused
            momentum-SGD Pallas kernel on the local 1/p shard (momentum
            sharded 1/p) -> ring allgather of updated params
  mpi_esgd  each device is one CLIENT (the pod axis): local fused SGD
            every step; every INTERVAL steps the flat sharded elastic
            exchange crosses the axis (ONE Pallas pass for eq. (3) + the
            packed differences, ring reduce-scatter of the differences,
            fused eq. (2) on the 1/p center shard, allgather) — the only
            cross-device traffic

Driver state is *stacked*: every leaf carries a leading device dim p,
sharded over the axis on a real mesh (so each device holds exactly its
replica/shard) and vmapped under single-device emulation — one layout
serves production and tests alike. The elastic INTERVAL condition is
applied OUTSIDE the mapped functions (a scalar ``lax.cond`` choosing
whether to invoke the mapped exchange at all), so the collectives never
sit inside a data-dependent branch.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import flatbuf
from repro.core.compat import axis_size, shard_map
from repro.core.elastic import elastic_exchange_sharded
from repro.core.hierarchy import SyncConfig, should_elastic_sync
from repro.core.sync_engine import flat_update_supported, make_sync_engine
from repro.launch.train import grad_spec, make_grad_fn
from repro.models.model import Model
from repro.optim.sgd import Optimizer, optstate_shard_init

AXIS = "dev"


def _require_supported(model: Model, optimizer: Optimizer, sync: SyncConfig,
                       p: int) -> flatbuf.FlatBuffer:
    if not flat_update_supported(optimizer, sync, None):
        raise ValueError(
            "the shard driver runs the flat fused substrate only: "
            "momentum-SGD (f32 state), AdaGrad or AdamW with "
            "SyncConfig.fused_update=True")
    if sync.mode == "mpi_esgd" and sync.num_clients != p:
        raise ValueError(
            f"mpi_esgd under the shard driver maps one client per device: "
            f"num_clients={sync.num_clients} != p={p}")
    return grad_spec(model)


def shard_batch(batch: Any, p: int) -> Any:
    """(B, ...) host batch -> (p, B/p, ...) stacked per-device shards.

    For mpi_esgd the leading dim doubles as the client dim (device ==
    client), matching launch/train.py's clientized batch layout.
    """
    return jax.tree.map(
        lambda a: a.reshape((p, a.shape[0] // p) + a.shape[1:]), batch
    )


def make_driver_state(model: Model, optimizer: Optimizer, sync: SyncConfig,
                      p: int, rng: jax.Array | None = None) -> dict:
    """Stacked (leading device dim p) initial state.

    mpi_sgd: params replicated p ways, optimizer state (momentum /
    AdaGrad accumulator / AdamW m+v streams) sharded 1/p per device.
    mpi_esgd: one replica per device (device == client), full local
    optimizer state per device, replicated center.
    """
    rng = jax.random.key(0) if rng is None else rng
    spec = _require_supported(model, optimizer, sync, p)
    nr = flatbuf.effective_rings(spec.nbytes, sync.num_rings,
                                 sync.bucket_bytes)
    esgd = sync.mode == "mpi_esgd"
    params = model.init(rng)
    opt0 = optstate_shard_init(optimizer.hyper, spec, 1 if esgd else p, nr)

    def stack(tree):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (p,) + l.shape).copy(), tree
        )

    state = {
        "params": stack(params),
        "opt": stack(opt0),
        "step": jnp.zeros((p,), jnp.int32),
    }
    if esgd:
        state["center"] = stack(params)
    return state


def make_device_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                     *, axis_name: str = AXIS, microbatch: int = 1
                     ) -> tuple[Callable, Optional[Callable]]:
    """The per-device programs: ``(device_step, device_exchange)``.

    ``device_step`` computes grads on the device's batch shard and runs
    the engine's sync+update leg; ``device_exchange`` (mpi_esgd only) is
    the flat sharded elastic exchange. Both are meant to run inside
    shard_map on a real mesh or under ``jax.vmap(..., axis_name=...)``
    emulation — ``make_sharded_step`` / ``make_emulated_step`` wrap them.
    """
    esgd = sync.mode == "mpi_esgd"
    spec = grad_spec(model)
    # mpi_sgd: the axis is the gradient communicator. mpi_esgd: gradient
    # sync is intra-client (local here — one device IS one client), so
    # the update runs in p=1 geometry and only the exchange crosses.
    engine = make_sync_engine(optimizer, sync, None,
                              axis_name=None if esgd else axis_name,
                              spec=spec)
    grad_fn = make_grad_fn(model, microbatch)

    def device_step(state, batch):
        loss, metrics, grads = grad_fn(state["params"], batch)
        new_p, new_o = engine.update(grads, state["opt"], state["params"])
        metrics = {"loss": loss, **metrics}
        metrics = jax.tree.map(lambda m: lax.pmean(m, axis_name), metrics)
        return dict(state, params=new_p, opt=new_o,
                    step=state["step"] + 1), metrics

    if not esgd:
        return device_step, None

    def device_exchange(state):
        alpha = sync.esgd_alpha / axis_size(axis_name)
        new_p, new_c = elastic_exchange_sharded(
            spec, state["params"], state["center"], alpha,
            axis_name=axis_name, num_rings=sync.num_rings,
            bucket_bytes=sync.bucket_bytes)
        return dict(state, params=new_p, center=new_c)

    return device_step, device_exchange


def _compose(mapped_step: Callable, mapped_exchange: Optional[Callable],
             sync: SyncConfig) -> Callable:
    """Full driver step over stacked state: mapped update, then — on the
    INTERVAL boundary, decided by a scalar cond outside the map — the
    mapped elastic exchange (launch/train.py's step_multiclient order:
    the pre-increment step count gates the exchange AFTER the update)."""

    def step(state, batch):
        old_step = state["step"][0]
        new_state, metrics = mapped_step(state, batch)
        if mapped_exchange is not None:
            new_state = lax.cond(
                should_elastic_sync(old_step, sync.esgd_interval),
                mapped_exchange, lambda s: s, new_state,
            )
        # pmean'd inside the map: identical on every device — report one
        return new_state, jax.tree.map(lambda m: m[0], metrics)

    return step


def make_emulated_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                       p: int, *, axis_name: str = AXIS,
                       microbatch: int = 1) -> Callable:
    """vmap-emulated driver step (tests / single-device hosts): the same
    per-device program, with vmap providing the named axis."""
    _require_supported(model, optimizer, sync, p)
    dev_step, dev_ex = make_device_step(model, optimizer, sync,
                                        axis_name=axis_name,
                                        microbatch=microbatch)
    vstep = jax.vmap(dev_step, axis_name=axis_name)
    vex = jax.vmap(dev_ex, axis_name=axis_name) if dev_ex else None
    return _compose(vstep, vex, sync)


def make_sharded_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                      mesh, *, axis_name: str = AXIS,
                      microbatch: int = 1) -> Callable:
    """Real-mesh driver step: the per-device program under
    ``compat.shard_map`` with every stacked leaf sharded over
    ``axis_name`` — each device holds exactly its replica/shard and the
    ring collectives are the only cross-device traffic."""
    p = mesh.shape[axis_name]
    _require_supported(model, optimizer, sync, p)
    dev_step, dev_ex = make_device_step(model, optimizer, sync,
                                        axis_name=axis_name,
                                        microbatch=microbatch)

    def _blocked(fn):
        # shard_map hands each device a leading-dim-1 block of the
        # stacked leaves; the per-device program wants them squeezed
        def g(*args):
            squeezed = jax.tree.map(lambda l: l.reshape(l.shape[1:]), args)
            out = fn(*squeezed)
            return jax.tree.map(lambda l: jnp.asarray(l)[None], out)

        return g

    sspec = P(axis_name)
    mstep = shard_map(_blocked(dev_step), mesh=mesh,
                      in_specs=(sspec, sspec), out_specs=(sspec, sspec),
                      check_vma=False)
    mex = (shard_map(_blocked(dev_ex), mesh=mesh,
                     in_specs=(sspec,), out_specs=sspec, check_vma=False)
           if dev_ex else None)
    return _compose(mstep, mex, sync)


def drive(model: Model, optimizer: Optimizer, sync: SyncConfig, batches,
          *, p: int | None = None, mesh=None, axis_name: str = AXIS,
          rng=None, microbatch: int = 1, log_every: int = 10,
          callback: Optional[Callable] = None):
    """Training loop over the shard driver.

    ``mesh=None`` emulates ``p`` devices with vmap; with a mesh, ``p``
    is the ``axis_name`` axis size and the step runs under shard_map.
    ``batches`` yield host-layout (B, ...) arrays; they are split into
    per-device shards here.
    """
    if mesh is not None:
        p = mesh.shape[axis_name]
    if p is None:
        raise ValueError("pass p= (emulation) or mesh=")
    state = make_driver_state(model, optimizer, sync, p, rng)
    if mesh is None:
        step = make_emulated_step(model, optimizer, sync, p,
                                  axis_name=axis_name, microbatch=microbatch)
    else:
        step = make_sharded_step(model, optimizer, sync, mesh,
                                 axis_name=axis_name, microbatch=microbatch)
    step = jax.jit(step)
    history = []
    for i, batch in enumerate(batches):
        state, metrics = step(state, shard_batch(batch, p))
        if i % log_every == 0:
            entry = {k: float(v) for k, v in metrics.items()}
            entry["step"] = i
            history.append(entry)
            if callback:
                callback(entry)
    return state, history


def _selftest(p: int = 8) -> None:  # pragma: no cover (subprocess helper)
    """REAL-mesh check (needs >= p host devices, set XLA_FLAGS): the
    shard_map driver's losses must match the single-process reference
    step for both modes and every lowerable optimizer family — run by
    tests/test_multidevice.py."""
    import numpy as np

    from repro.configs.base import get_config, reduced
    from repro.core.compat import make_mesh
    from repro.launch.train import make_train_state, make_train_step
    from repro.models.model import build_model
    from repro.optim.sgd import adagrad, adamw, sgd

    assert len(jax.devices()) >= p, "set XLA_FLAGS host device count"
    model = build_model(reduced(get_config("qwen2-0.5b")))
    mesh = make_mesh((p,), (AXIS,))
    k = jax.random.key(0)
    toks = jax.random.randint(k, (p, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    for opt in (sgd(0.1, momentum=0.9), adamw(3e-3), adagrad(0.05)):
        oname = opt.hyper["name"]
        for sync in (SyncConfig(mode="mpi_sgd", num_clients=1),
                     SyncConfig(mode="mpi_esgd", num_clients=p,
                                esgd_interval=2)):
            st = make_driver_state(model, opt, sync, p, jax.random.key(1))
            step = jax.jit(make_sharded_step(model, opt, sync, mesh))
            ref = make_train_state(model, opt, sync, jax.random.key(1))
            ref_step = jax.jit(make_train_step(model, opt, sync, None))
            ref_batch = (batch if sync.num_clients <= 1
                         else shard_batch(batch, p))
            for _ in range(3):
                st, m = step(st, shard_batch(batch, p))
                ref, mr = ref_step(ref, ref_batch)
                np.testing.assert_allclose(float(m["loss"]),
                                           float(mr["loss"]), rtol=1e-4)
            print(f"shard driver selftest OK p={p} mode={sync.mode} "
                  f"opt={oname} (shard_map on {len(jax.devices())} devices)")


if __name__ == "__main__":  # pragma: no cover
    import sys

    _selftest(int(sys.argv[1]) if len(sys.argv) > 1 else 8)
