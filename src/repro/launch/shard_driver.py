"""shard_map production driver: the whole train step per-device.

launch/train.py's collective-explicit fused path engages when no mesh is
given; under GSPMD (a mesh) XLA owns the gradient collectives. This
driver closes the gap between the two: it runs BOTH lowerable modes with
the step mapped per-device over real mesh axes via ``compat.shard_map``
— gradients computed INSIDE the mapped function on the device's batch
shard, explicit ring collectives carrying every byte of cross-device
traffic (GSPMD inserts nothing), optimizer state sharded with
``optstate_shard_init`` (momentum SGD, AdaGrad, or AdamW — AdamW's two
full-size moment streams both live 1/p per device).

Which collective runs over which devices is decided by **communicator
algebra** (core/comm.py), not axis-name strings: the driver builds a
``world`` communicator over the mesh axes and ``comm.sync_comms`` carves
it into the paper's groups —

  mpi_sgd   the gradient group IS the world (C = 1 pure-MPI mode): pack
            grads into the FlatBuffer -> (hierarchical) ring
            reduce-scatter -> fused optimizer Pallas kernel on the local
            1/p shard (state sharded 1/p) -> ring allgather
  mpi_esgd  the 'pod' axis is the PS tier: the gradient group is
            everything BUT 'pod' (local fused update inside the client),
            and every INTERVAL steps the flat sharded elastic exchange
            crosses the 'pod' group (ONE Pallas pass for eq. (3) + the
            packed differences, ring reduce-scatter of the differences,
            fused eq. (2) on the 1/p center shard, allgather) — the only
            cross-client traffic

Two mesh layouts serve this:

  1-axis    ``p`` is an int, one axis (default "dev"). mpi_sgd: the axis
            is the intra-client MPI communicator. mpi_esgd: each device
            is one CLIENT (the axis plays the pod role).
  2-axis    ``p`` is ``(P, D)`` (or the mesh has 'pod' and 'data' axes):
            the paper's full hierarchy in ONE shard_map program. mpi_sgd
            reduce-scatters hierarchically over pod then data (same
            total bytes and final 1/(P*D) shard as one (P*D)-ring).
            mpi_esgd confines the gradient leg to 'data' INSIDE each
            pod-client (state sharded 1/D) while the elastic leg crosses
            'pod' — provably: the legs' ppermutes name disjoint axes
            (tests/test_shard_driver.py asserts this on the jaxpr).

Both legs run under the world communicator's full collective policy —
including the low-precision wire protocol (``SyncConfig.wire_dtype``:
bf16 casts or int8 codes + per-bucket scales on every ppermute hop,
compounding the (p−1)/p·n gradient-leg saving by another 2–4x) and the
low-precision optimizer-state streams (``hyper["state_dtype"]``: bf16
AdaGrad accumulator / AdamW m+v at half the bytes per device).

Driver state is *stacked*: every leaf carries a leading device dim
p_total (pod-major for 2-axis), sharded over the axes on a real mesh (so
each device holds exactly its replica/shard) and vmapped — one nested
vmap per axis — under single-device emulation; one layout serves
production and tests alike. The elastic INTERVAL condition is applied
OUTSIDE the mapped functions (a scalar ``lax.cond`` choosing whether to
invoke the mapped exchange at all), so the collectives never sit inside
a data-dependent branch.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import comm as comm_lib, flatbuf
from repro.core.comm import Communicator, sync_comms
from repro.core.compat import shard_map
from repro.core.elastic import elastic_exchange_sharded
from repro.core.hierarchy import SyncConfig, should_elastic_sync
from repro.core.sync_engine import flat_update_supported, make_sync_engine
from repro.launch.train import (
    grad_spec,
    make_grad_fn,
    make_overlap_grad_fn,
    overlap_schedule,
)
from repro.models.model import Model
from repro.optim.sgd import Optimizer, optstate_sched_init, optstate_shard_init

AXIS = "dev"                       # the 1-axis layout's single axis
POD_AXIS, DATA_AXIS = "pod", "data"  # the 2-axis (hierarchy) layout

Geometry = Union[int, Sequence[int]]


def _factorize(p: Geometry, axis_name: str = AXIS
               ) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Normalize the device geometry: an int is the 1-axis layout over
    ``axis_name``; a (pods, data) pair is the 2-axis pod×data layout."""
    if isinstance(p, (tuple, list)):
        if len(p) != 2:
            raise ValueError(
                f"2-axis geometry is (pods, data), got {tuple(p)}")
        return (int(p[0]), int(p[1])), (POD_AXIS, DATA_AXIS)
    return (int(p),), (axis_name,)


def driver_world(sync: SyncConfig, p: Geometry, *,
                 axis_name: str = AXIS) -> Communicator:
    """The top-level communicator for a driver geometry, carrying the
    SyncConfig's collective policy."""
    shape, axes = _factorize(p, axis_name)
    return comm_lib.from_sync(sync, axes, shape)


def _require_supported(model: Model, optimizer: Optimizer, sync: SyncConfig,
                       world: Communicator) -> flatbuf.FlatBuffer:
    if not flat_update_supported(optimizer, sync, None):
        raise ValueError(
            "the shard driver runs the flat fused substrate only: "
            "momentum-SGD (f32 state), AdaGrad or AdamW with "
            "SyncConfig.fused_update=True")
    sync.validate()
    if sync.mode == "mpi_esgd":
        _, ex = sync_comms(sync, world)
        pods = ex.static_size
        if sync.num_clients != pods:
            what = ("one client per pod" if POD_AXIS in world.axes
                    else "one client per device")
            raise ValueError(
                f"mpi_esgd under the shard driver maps {what}: "
                f"num_clients={sync.num_clients} != {pods} (world "
                f"axes {world.axes}, sizes {world.sizes})")
    return grad_spec(model)


def shard_batch(batch: Any, p: Geometry) -> Any:
    """(B, ...) host batch -> (p_total, B/p_total, ...) stacked
    per-device shards (pod-major for 2-axis geometries).

    For mpi_esgd the leading dim doubles as the client dim (pod ==
    client), matching launch/train.py's clientized batch layout.
    """
    shape, _ = _factorize(p)
    n = math.prod(shape)
    leaves = jax.tree_util.tree_leaves(batch)
    if leaves and leaves[0].shape[0] % n:
        raise ValueError(
            f"batch size {leaves[0].shape[0]} does not divide over "
            f"{n} devices (geometry {p}) — after an elastic membership "
            "change, feed batches sized for the SURVIVOR count (a "
            "multiple of every geometry the fault schedule can reach)")
    return jax.tree.map(
        lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch
    )


def make_driver_state(model: Model, optimizer: Optimizer, sync: SyncConfig,
                      p: Geometry, rng: jax.Array | None = None) -> dict:
    """Stacked (leading device dim p_total) initial state.

    mpi_sgd: params replicated, optimizer state (momentum / AdaGrad
    accumulator / AdamW m+v streams) sharded 1/p_total per device.
    mpi_esgd: one replica per client (pod), optimizer state sharded over
    the client's gradient group (1-axis: full local state per device;
    2-axis: 1/D per device), replicated center.
    """
    rng = jax.random.key(0) if rng is None else rng
    world = driver_world(sync, p)
    spec = _require_supported(model, optimizer, sync, world)
    grad_comm, _ = sync_comms(sync, world)
    gp = grad_comm.static_size
    nr = grad_comm.rings_for(spec.nbytes)
    n = world.static_size
    params = model.init(rng)
    if sync.overlap:
        # overlapped layout: bucket-major concat of per-bucket chunks
        # over the STAGED spec, at the gradient group's p
        _, schedule = overlap_schedule(model, sync, gp)
        opt0 = optstate_sched_init(optimizer.hyper, schedule)
    else:
        opt0 = optstate_shard_init(optimizer.hyper, spec, gp, nr)

    def stack(tree):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape).copy(), tree
        )

    state = {
        "params": stack(params),
        "opt": stack(opt0),
        "step": jnp.zeros((n,), jnp.int32),
    }
    if sync.mode == "mpi_esgd":
        state["center"] = stack(params)
    return state


def make_device_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                     *, world: Optional[Communicator] = None,
                     axis_name: str = AXIS, microbatch: int = 1
                     ) -> tuple[Callable, Optional[Callable]]:
    """The per-device programs: ``(device_step, device_exchange)``.

    ``device_step`` computes grads on the device's batch shard and runs
    the engine's sync+update leg over the gradient communicator;
    ``device_exchange`` (mpi_esgd only) is the flat sharded elastic
    exchange over the exchange (pod) communicator. Both are meant to run
    inside shard_map on a real mesh or under nested
    ``jax.vmap(..., axis_name=...)`` emulation — ``make_sharded_step`` /
    ``make_emulated_step`` wrap them.

    ``world`` is the driver's top-level communicator (see
    ``driver_world``); omitted, a 1-axis world over ``axis_name`` with
    trace-time-resolved size is built (the legacy spelling).
    """
    if world is None:
        world = comm_lib.from_sync(sync, (axis_name,))
    grad_comm, ex_comm = sync_comms(sync, world)
    spec = grad_spec(model)
    stages = schedule = None
    if sync.overlap:
        if microbatch > 1:
            raise ValueError(
                "overlap=True with microbatch>1 would re-issue every "
                "schedule bucket's ring leg per accumulation step (M× "
                "the wire bytes overlap exists to hide); accumulate "
                "without overlap, or raise the per-step batch instead")
        stages, schedule = overlap_schedule(model, sync,
                                            grad_comm.resolve_size())
    engine = make_sync_engine(optimizer, sync, None, comm=grad_comm,
                              spec=spec, schedule=schedule)
    grad_fn = (make_overlap_grad_fn(model, stages, schedule, grad_comm)
               if sync.overlap else make_grad_fn(model, microbatch))

    def device_step(state, batch):
        if sync.overlap:
            loss, metrics, g_shard = grad_fn(state["params"], batch)
            staged = stages.stage(state["params"])
            new_staged, new_o = engine.update_overlapped(
                g_shard, staged, state["opt"])
            new_p = stages.unstage(new_staged)
        else:
            loss, metrics, grads = grad_fn(state["params"], batch)
            new_p, new_o = engine.update(grads, state["opt"],
                                         state["params"])
        metrics = {"loss": loss, **metrics}
        metrics = jax.tree.map(world.pmean, metrics)
        return dict(state, params=new_p, opt=new_o,
                    step=state["step"] + 1), metrics

    if ex_comm is None:
        return device_step, None

    def device_exchange(state):
        alpha = sync.esgd_alpha / ex_comm.resolve_size()
        new_p, new_c = elastic_exchange_sharded(
            spec, state["params"], state["center"], alpha, comm=ex_comm)
        return dict(state, params=new_p, center=new_c)

    return device_step, device_exchange


def _compose(mapped_step: Callable, mapped_exchange: Optional[Callable],
             sync: SyncConfig) -> Callable:
    """Full driver step over stacked state: mapped update, then — on the
    INTERVAL boundary, decided by a scalar cond outside the map — the
    mapped elastic exchange (launch/train.py's step_multiclient order:
    the pre-increment step count gates the exchange AFTER the update)."""

    def step(state, batch):
        old_step = state["step"][0]
        new_state, metrics = mapped_step(state, batch)
        if mapped_exchange is not None:
            new_state = lax.cond(
                should_elastic_sync(old_step, sync.esgd_interval),
                mapped_exchange, lambda s: s, new_state,
            )
        # pmean'd inside the map: identical on every device — report one
        return new_state, jax.tree.map(lambda m: m.reshape(-1)[0], metrics)

    return step


def _nested_vmap(fn: Callable, shape: tuple[int, ...],
                 axes: tuple[str, ...]) -> Callable:
    """Map a per-device program over stacked (p_total-leading) state with
    one named vmap per mesh axis (outermost axis first) — the emulation
    backend of the same communicator programs shard_map runs."""
    mapped = fn
    for a in reversed(axes):
        mapped = jax.vmap(mapped, axis_name=a)

    def g(*args):
        split = jax.tree.map(
            lambda l: l.reshape(shape + l.shape[1:]), args)
        out = mapped(*split)
        n = math.prod(shape)
        return jax.tree.map(
            lambda l: l.reshape((n,) + l.shape[len(shape):]), out)

    return g


def make_emulated_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                       p: Geometry, *, axis_name: str = AXIS,
                       microbatch: int = 1) -> Callable:
    """vmap-emulated driver step (tests / single-device hosts): the same
    per-device program, with nested vmaps providing the named axes."""
    shape, axes = _factorize(p, axis_name)
    world = driver_world(sync, p, axis_name=axis_name)
    _require_supported(model, optimizer, sync, world)
    dev_step, dev_ex = make_device_step(model, optimizer, sync, world=world,
                                        microbatch=microbatch)
    vstep = _nested_vmap(dev_step, shape, axes)
    vex = _nested_vmap(dev_ex, shape, axes) if dev_ex else None
    return _compose(vstep, vex, sync)


def _mesh_geometry(mesh, axis_name: str = AXIS
                   ) -> tuple[Geometry, tuple[str, ...]]:
    """Which driver layout a mesh carries: ('pod' and 'data') -> 2-axis,
    else the single ``axis_name`` axis."""
    if POD_AXIS in mesh.shape and DATA_AXIS in mesh.shape:
        return ((mesh.shape[POD_AXIS], mesh.shape[DATA_AXIS]),
                (POD_AXIS, DATA_AXIS))
    if axis_name not in mesh.shape:
        raise ValueError(
            f"mesh axes {dict(mesh.shape)} fit neither driver layout: "
            f"expected a '{axis_name}' axis (1-axis) or both "
            f"'{POD_AXIS}' and '{DATA_AXIS}' axes (2-axis hierarchy)")
    return mesh.shape[axis_name], (axis_name,)


def make_sharded_step(model: Model, optimizer: Optimizer, sync: SyncConfig,
                      mesh, *, axis_name: str = AXIS,
                      microbatch: int = 1) -> Callable:
    """Real-mesh driver step: the per-device program under
    ``compat.shard_map`` with every stacked leaf sharded over the mesh
    axes — each device holds exactly its replica/shard and the ring
    collectives are the only cross-device traffic. A mesh with 'pod'
    and 'data' axes selects the 2-axis hierarchy layout."""
    p, axes = _mesh_geometry(mesh, axis_name)
    world = driver_world(sync, p, axis_name=axis_name)
    _require_supported(model, optimizer, sync, world)
    dev_step, dev_ex = make_device_step(model, optimizer, sync, world=world,
                                        microbatch=microbatch)

    def _blocked(fn):
        # shard_map hands each device a leading-dim-1 block of the
        # stacked leaves; the per-device program wants them squeezed
        def g(*args):
            squeezed = jax.tree.map(lambda l: l.reshape(l.shape[1:]), args)
            out = fn(*squeezed)
            return jax.tree.map(lambda l: jnp.asarray(l)[None], out)

        return g

    sspec = P(axes)
    mstep = shard_map(_blocked(dev_step), mesh=mesh,
                      in_specs=(sspec, sspec), out_specs=(sspec, sspec),
                      check_vma=False)
    mex = (shard_map(_blocked(dev_ex), mesh=mesh,
                     in_specs=(sspec,), out_specs=sspec, check_vma=False)
           if dev_ex else None)
    return _compose(mstep, mex, sync)


def _check_driver_faults(inj, mesh, p) -> None:
    """What the driver's fault path can serve: kill (membership
    reconfiguration) and corrupt (deterministic batch noise), under
    vmap emulation. Timing faults need a clock and a real mesh needs
    real process recovery — both out of scope here."""
    if mesh is not None:
        raise ValueError(
            "drive(faults=...) runs under vmap emulation only: elastic "
            "reconfiguration on a REAL mesh needs the multi-process "
            "transport (see ROADMAP.md 'real multi-process transport') "
            "— pass p= instead of mesh=")
    timed = inj.schedule.kinds & {"drop", "delay", "straggle"}
    if timed:
        raise ValueError(
            f"fault kinds {sorted(timed)} need a clock — the driver's "
            "jitted step has no timing axis; run them through the "
            "event-driven simulation (core/algorithms.py, "
            "AlgoConfig.faults). The driver serves kill/corrupt.")
    shape, _ = _factorize(p)
    if inj.schedule.kinds & {"kill", "restart"} and len(shape) == 2:
        # pod kills/joins need the hierarchical (pod-then-data) shard
        # layout re-derived, which only the 1-axis ring-major geometry
        # shares with membership.reshard_optstate today
        raise ValueError(
            "kill/restart faults under the 2-axis pod×data layout are "
            "not wired — the hierarchical state re-layout is part of "
            "the ROADMAP 'real multi-process transport' item; use the "
            "1-axis layout")


def _reconfigure(model: Model, optimizer: Optimizer, sync: SyncConfig,
                 state: dict, p_old: int, dead: list[int],
                 live: "Membership", *, axis_name: str,
                 microbatch: int) -> tuple[dict, int, Callable, dict]:
    """Evict ``dead`` devices from a 1-axis emulated run: re-split the
    geometry to the survivor count, carry survivor rows of the stacked
    state over, re-shard the FlatBuffer optimizer state
    (membership.reshard_optstate — survivors keep their slices, dead
    slices restart from zero), and rebuild + re-jit the step.

    mpi_sgd: the axis is ONE data-parallel group — params are replicated
    (any survivor row serves) and opt state is 1/p sharded, so it is
    re-laid-out p_old -> p_new. mpi_esgd: each device is one CLIENT with
    full local opt state — the dead client's row is simply dropped and
    the SyncConfig shrinks to the survivor client count."""
    import dataclasses as _dc

    from repro.core.membership import reshard_optstate

    for u in dead:
        live.fail(u)
    survivors = [r for r in range(p_old) if live.is_live(r)]
    p_new = len(survivors)
    rows = jnp.asarray(survivors)
    world = driver_world(sync, p_old, axis_name=axis_name)
    info: dict = {"p_old": p_old, "p_new": p_new, "moved_bytes": 0.0,
                  "survivors": tuple(survivors)}
    if sync.mode == "mpi_esgd":
        sync = _dc.replace(sync, num_clients=p_new)
        state = jax.tree.map(lambda l: l[rows], state)
    else:
        spec = grad_spec(model)
        new_opt, rinfo = reshard_optstate(
            optimizer.hyper, spec, state["opt"], p_old, p_new,
            survivors=survivors, num_rings=world.num_rings,
            bucket_bytes=world.bucket_bytes)
        info.update(rinfo)
        state = {**jax.tree.map(lambda l: l[rows],
                                {k: v for k, v in state.items()
                                 if k != "opt"}),
                 "opt": new_opt}
    step = jax.jit(make_emulated_step(model, optimizer, sync, p_new,
                                      axis_name=axis_name,
                                      microbatch=microbatch))
    return state, p_new, step, dict(info, sync=sync)


def _rejoin(model: Model, optimizer: Optimizer, sync: SyncConfig,
            state: dict, p_old: int, joiners: list[int],
            live: "Membership", *, axis_name: str,
            microbatch: int) -> tuple[dict, int, Callable, dict]:
    """Admit ``joiners`` into a 1-axis emulated run mid-stream: a new
    membership epoch per joiner, the geometry re-split to the grown
    count, the FlatBuffer optimizer state re-sharded at p_new
    (membership.reshard_optstate with every old shard surviving —
    reconstruct from p_old slices, re-slice), and the step re-jitted.

    mpi_sgd: params are replicated, so the joiner's row is a broadcast
    of row 0 — the emulated analogue of the respawned worker's
    pull-live-params-from-the-PS. mpi_esgd: the joiner is a NEW client
    admitted at the current center (the PS hands it w̃) with fresh
    local optimizer state, and the SyncConfig grows to the new count."""
    import dataclasses as _dc

    from repro.core.membership import reshard_optstate

    old_ids = list(live.live)
    for u in joiners:
        live.join(u)
    new_ids = list(live.live)
    p_new = len(new_ids)
    pos = {u: r for r, u in enumerate(old_ids)}
    rows = [pos.get(u, -1) for u in new_ids]
    world = driver_world(sync, p_old, axis_name=axis_name)
    info: dict = {"p_old": p_old, "p_new": p_new, "moved_bytes": 0.0,
                  "joined": tuple(joiners),
                  "survivors": tuple(range(p_old))}

    def expand(tree, fill):
        return jax.tree.map(
            lambda l: jnp.stack([l[r] if r >= 0 else fill(l)
                                 for r in rows]), tree)

    if sync.mode == "mpi_esgd":
        sync = _dc.replace(sync, num_clients=p_new)
        state = {
            "params": jax.tree.map(
                lambda pl, cl: jnp.stack(
                    [pl[r] if r >= 0 else cl[0] for r in rows]),
                state["params"], state["center"]),
            "opt": expand(state["opt"], lambda l: jnp.zeros_like(l[0])),
            "step": expand(state["step"], lambda l: l[0]),
            "center": expand(state["center"], lambda l: l[0]),
        }
    else:
        spec = grad_spec(model)
        new_opt, rinfo = reshard_optstate(
            optimizer.hyper, spec, state["opt"], p_old, p_new,
            survivors=list(range(p_old)), num_rings=world.num_rings,
            bucket_bytes=world.bucket_bytes)
        info.update(rinfo)
        rest = {k: v for k, v in state.items() if k != "opt"}
        state = {**{k: expand(v, lambda l: l[0]) for k, v in rest.items()},
                 "opt": new_opt}
    step = jax.jit(make_emulated_step(model, optimizer, sync, p_new,
                                      axis_name=axis_name,
                                      microbatch=microbatch))
    return state, p_new, step, dict(info, sync=sync)


def drive(model: Model, optimizer: Optimizer, sync: SyncConfig, batches,
          *, p: Geometry | None = None, mesh=None, axis_name: str = AXIS,
          rng=None, microbatch: int = 1, log_every: int = 10,
          callback: Optional[Callable] = None, faults=None,
          fault_seed: int = 0, net: Optional[Any] = None):
    """Training loop over the shard driver.

    ``mesh=None`` emulates ``p`` devices with nested vmaps — an int, or
    a (pods, data) pair for the 2-axis hierarchy; with a mesh, the
    geometry comes from the mesh axes and the step runs under shard_map.
    ``batches`` yield host-layout (B, ...) arrays; they are split into
    per-device shards here.

    ``faults`` (a core.faults schedule / string) injects deterministic
    failures, emulation only: ``kill@s:unit=d`` evicts device d before
    step s — the run reconfigures to the survivors (state re-laid-out
    via membership.reshard_optstate, step re-jitted) and a
    ``reconfigure`` entry with the recovery byte/time accounting
    (cost_model.reconfig_time over ``net``) lands in the history;
    ``restart@s:unit=d`` ADMITS device d before step s when it is not
    live (a brand-new id grows the run; a previously-killed id
    rejoins) — the geometry grows to the joined count (``_rejoin``:
    reshard_optstate at p_new, joiner params pulled from a live row /
    the center) and a ``join`` entry carries the
    cost_model.join_reshard_bytes / recovery_time accounting. Kills
    are generation-indexed: a rejoined unit dies again only at its
    NEXT kill event. ``corrupt`` adds seeded noise to the device's
    batch shard. The same schedule replayed is bit-identical; feed
    batches sized for every geometry the schedule can reach.
    """
    from repro.core import cost_model
    from repro.core.faults import injector
    from repro.core.membership import Membership

    if mesh is not None:
        p, _ = _mesh_geometry(mesh, axis_name)
    if p is None:
        raise ValueError("pass p= (emulation) or mesh=")
    inj = injector(faults, seed=fault_seed)
    if inj is not None:
        if sync.overlap:
            raise ValueError(
                "drive(faults=...) with SyncConfig.overlap=True is not "
                "wired: the elastic re-layout "
                "(membership.reshard_optstate) assumes the monolithic "
                "ring-major shard geometry, not the bucket-major "
                "overlapped schedule — run faults without overlap, or "
                "overlap without faults")
        _check_driver_faults(inj, mesh, p)
    state = make_driver_state(model, optimizer, sync, p, rng)
    if mesh is None:
        step = make_emulated_step(model, optimizer, sync, p,
                                  axis_name=axis_name, microbatch=microbatch)
    else:
        step = make_sharded_step(model, optimizer, sync, mesh,
                                 axis_name=axis_name, microbatch=microbatch)
    step = jax.jit(step)
    live = (Membership(math.prod(_factorize(p)[0]))
            if inj is not None else None)
    attempts: dict[int, int] = {}    # unit -> spawn generation
    history = []
    for i, batch in enumerate(batches):
        if inj is not None:
            joiners = [u for u in inj.restart_units(i)
                       if not live.is_live(u)]
            if joiners:
                delay = max(inj.restart_delay(u, attempts.get(u, 0)) or 0.0
                            for u in joiners)
                for u in joiners:
                    attempts[u] = attempts.get(u, 0) + 1
                state, p, step, info = _rejoin(
                    model, optimizer, sync, state, int(p), joiners, live,
                    axis_name=axis_name, microbatch=microbatch)
                sync = info.pop("sync")
                netp = net or cost_model.testbed()
                state_nbytes = info.get("state_nbytes", 0.0)
                entry = {"step": i, "event": "join", **info,
                         "join_reshard_bytes":
                             cost_model.join_reshard_bytes(
                                 state_nbytes, info["p_old"]),
                         "recovery_time": cost_model.recovery_time(
                             0.0, delay, info["p_old"], info["p_new"],
                             netp, state_nbytes=state_nbytes)}
                history.append(entry)
                if callback:
                    callback(entry)
            dead = [u for u in live.live
                    if inj.is_killed(u, i, attempts.get(u, 0))]
            if dead:
                if len(dead) >= live.live_count:
                    raise ValueError(
                        f"fault schedule kills every live device at "
                        f"step {i} — no survivor group to reconfigure to")
                state, p, step, info = _reconfigure(
                    model, optimizer, sync, state, int(p), dead, live,
                    axis_name=axis_name, microbatch=microbatch)
                sync = info.pop("sync")
                netp = net or cost_model.testbed()
                entry = {"step": i, "event": "reconfigure",
                         "killed": dead, **info,
                         "reconfig_time": cost_model.reconfig_time(
                             info.get("state_nbytes", 0.0), info["p_old"],
                             info["p_new"], netp,
                             survivors=len(info["survivors"]))}
                history.append(entry)
                if callback:
                    callback(entry)
        shard = shard_batch(batch, p)
        if inj is not None:
            for r, u in enumerate(live.live):
                if inj.active(u, i):
                    noisy = inj.corrupt(
                        jax.tree.map(lambda l: l[r], shard), u, i)
                    shard = jax.tree.map(
                        lambda l, x: l.at[r].set(x), shard, noisy)
        state, metrics = step(state, shard)
        if i % log_every == 0:
            entry = {k: float(v) for k, v in metrics.items()}
            entry["step"] = i
            history.append(entry)
            if callback:
                callback(entry)
    return state, history


def _selftest(p: int = 8) -> None:  # pragma: no cover (subprocess helper)
    """REAL-mesh check (needs >= p host devices, set XLA_FLAGS): the
    shard_map driver's losses must match the single-process reference
    step for both modes and every lowerable optimizer family — run by
    tests/test_multidevice.py. Also runs the 2-axis pod×data hierarchy
    layout (both factorizations of p) against the same references."""
    import numpy as np

    from repro.configs.base import get_config, reduced
    from repro.core.compat import make_mesh
    from repro.launch.train import make_train_state, make_train_step
    from repro.models.model import build_model
    from repro.optim.sgd import adagrad, adamw, sgd

    assert len(jax.devices()) >= p, "set XLA_FLAGS host device count"
    model = build_model(reduced(get_config("qwen2-0.5b")))
    mesh = make_mesh((p,), (AXIS,))
    k = jax.random.key(0)
    toks = jax.random.randint(k, (p, 32), 0, 1024)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    for opt in (sgd(0.1, momentum=0.9), adamw(3e-3), adagrad(0.05)):
        oname = opt.hyper["name"]
        for sync in (SyncConfig(mode="mpi_sgd", num_clients=1),
                     SyncConfig(mode="mpi_esgd", num_clients=p,
                                esgd_interval=2)):
            st = make_driver_state(model, opt, sync, p, jax.random.key(1))
            step = jax.jit(make_sharded_step(model, opt, sync, mesh))
            ref = make_train_state(model, opt, sync, jax.random.key(1))
            ref_step = jax.jit(make_train_step(model, opt, sync, None))
            ref_batch = (batch if sync.num_clients <= 1
                         else shard_batch(batch, p))
            for _ in range(3):
                st, m = step(st, shard_batch(batch, p))
                ref, mr = ref_step(ref, ref_batch)
                np.testing.assert_allclose(float(m["loss"]),
                                           float(mr["loss"]), rtol=1e-4)
            print(f"shard driver selftest OK p={p} mode={sync.mode} "
                  f"opt={oname} (shard_map on {len(jax.devices())} devices)")

    # 2-axis pod×data hierarchy: losses must match the stacked C-client
    # reference (mpi_esgd, C = pods) and the single-process data-parallel
    # reference (mpi_sgd) on a REAL (P, D) mesh
    opt = sgd(0.1, momentum=0.9)
    for P_, D_ in ((2, p // 2), (p // 2, 2)):
        mesh2 = make_mesh((P_, D_), (POD_AXIS, DATA_AXIS))
        for sync in (SyncConfig(mode="mpi_sgd", num_clients=1),
                     SyncConfig(mode="mpi_esgd", num_clients=P_,
                                esgd_interval=2)):
            st = make_driver_state(model, opt, sync, (P_, D_),
                                   jax.random.key(1))
            step = jax.jit(make_sharded_step(model, opt, sync, mesh2))
            ref = make_train_state(model, opt, sync, jax.random.key(1))
            ref_step = jax.jit(make_train_step(model, opt, sync, None))
            ref_batch = (batch if sync.num_clients <= 1
                         else shard_batch(batch, P_))
            for _ in range(3):
                st, m = step(st, shard_batch(batch, (P_, D_)))
                ref, mr = ref_step(ref, ref_batch)
                np.testing.assert_allclose(float(m["loss"]),
                                           float(mr["loss"]), rtol=1e-4)
            print(f"shard driver selftest OK mesh=({P_}x{D_}) "
                  f"mode={sync.mode} (2-axis pod×data hierarchy)")


if __name__ == "__main__":  # pragma: no cover
    import sys

    _selftest(int(sys.argv[1]) if len(sys.argv) > 1 else 8)
