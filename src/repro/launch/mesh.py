"""Production meshes. Functions only — importing this module never touches
jax device state (device count is locked at first jax init)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e: 256 chips/pod as (16, 16); 2 pods add the 'pod' axis.

    axes: 'data' carries the intra-client gradient ring (the MPI
    communicator), 'model' carries tensor parallelism, 'pod' is the PS
    tier (one client per pod; crossed only by the lazy elastic exchange).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_moe_mesh(*, multi_pod: bool = False) -> Mesh:
    """Expert-parallel variant of the production pod: the 16-way model
    axis splits into ('expert', 'tp') = (8, 2). Same 256 chips/pod;
    expert weights shard over 'expert' (dispatch becomes all-to-all
    token routing), inner ff dims over 'tp'. Used by the MoE perf
    iterations (EXPERIMENTS.md §Perf Pair A / roofline notes)."""
    shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
    axes = (("pod",) if multi_pod else ()) + ("data", "expert", "tp")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    return make_mesh((data, model), ("data", "model"))


def mesh_num_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
