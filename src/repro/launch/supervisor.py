"""Process supervision for the multi-process PS tier (paper §8's
LSF-auto-restart role, owned explicitly).

``Supervisor`` watches the spawned worker/server processes of one job.
A clean exit (code 0) finishes the unit; an abnormal exit (e.g. 137 —
the SIGKILL the fault schedule lands) is answered one of three ways, in
priority order:

  scheduled   the fault schedule carries a ``restart@step:unit=U[:delay]``
              event for this spawn generation (``FaultInjector
              .restart_delay(unit, attempt)`` — generation a's death
              consults the (a+1)-th restart event): respawn after that
              delay WITHOUT charging the restart budget, so chaos
              scripts replay deterministically
  budget      the ``RestartPolicy`` budget has headroom: respawn after
              exponential backoff (``backoff * factor**used``, capped)
              and charge one restart
  give up     no schedule, no budget — the unit stays down (PR 9's
              eviction semantics). If a budget existed and is now spent
              the unit is marked EXHAUSTED and the job must fail loudly
              (launch/run_local.py raises ``JobFailed`` with the full
              exit-code history).

Every respawn bumps the unit's ``attempt`` (shipped to the child as
REPRO_ATTEMPT) — kills are generation-indexed in core/faults.py, the
worker resumes from its parked PS state, and a server restores its
latest durable snapshot. ``on_respawn`` fires just before the new spawn
(run_local stashes the pre-kill partial metrics file there so the
curves merge instead of overwriting).

The class is transport-agnostic and wall-clock injectable: ``spawn``
takes the Unit and returns a process-like object (``poll() ->
Optional[int]``), so tests drive it with fakes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass(frozen=True)
class RestartPolicy:
    """Per-unit restart budget + exponential backoff."""

    max_restarts: int = 0
    backoff: float = 0.05
    backoff_factor: float = 2.0
    max_backoff: float = 2.0

    def delay(self, used: int) -> float:
        return min(self.backoff * self.backoff_factor ** used,
                   self.max_backoff)


@dataclass
class Unit:
    """One supervised process slot (stable across respawns)."""

    name: str
    role: str                   # "worker" | "server"
    unit: int                   # fault-schedule unit id (rank)
    proc: Any
    attempt: int = 0
    used_budget: int = 0
    finished: bool = False
    gave_up: bool = False
    exhausted: bool = False
    exit_codes: list = field(default_factory=list)


class JobFailed(RuntimeError):
    """A unit exhausted its restart budget; carries the partial result."""

    def __init__(self, message: str, result: Any = None):
        super().__init__(message)
        self.result = result


class Supervisor:
    """Watch, respawn (schedule- or budget-driven), report."""

    def __init__(self, spawn: Callable[[Unit], Any], *,
                 policy: Optional[RestartPolicy] = None,
                 worker_injector=None, server_injector=None,
                 on_respawn: Optional[Callable[[Unit], None]] = None,
                 clock=time.monotonic, sleep=time.sleep,
                 poll_interval: float = 0.05):
        self.spawn = spawn
        self.policy = policy or RestartPolicy()
        self.worker_injector = worker_injector
        self.server_injector = server_injector
        self.on_respawn = on_respawn
        self.clock = clock
        self.sleep = sleep
        self.poll_interval = poll_interval
        self.units: dict[str, Unit] = {}
        self.respawns: list[dict] = []

    # -- registration --------------------------------------------------------
    def register(self, name: str, proc: Any, *, role: str = "worker",
                 unit: int = 0) -> Unit:
        if role not in ("worker", "server"):
            raise ValueError(f"role must be worker/server, got {role!r}")
        u = Unit(name=name, role=role, unit=unit, proc=proc)
        self.units[name] = u
        return u

    def procs(self) -> list[Any]:
        return [u.proc for u in self.units.values()]

    # -- decision ------------------------------------------------------------
    def _injector_for(self, u: Unit):
        return (self.worker_injector if u.role == "worker"
                else self.server_injector)

    def _decide(self, u: Unit) -> Optional[tuple[float, bool]]:
        """(respawn delay, scheduled?) — or None to give up. Death of
        spawn generation ``u.attempt`` consults the (attempt+1)-th
        restart event; the budget is the fallback."""
        inj = self._injector_for(u)
        if inj is not None:
            delay = inj.restart_delay(u.unit, u.attempt)
            if delay is not None:
                return float(delay), True
        if u.used_budget < self.policy.max_restarts:
            delay = self.policy.delay(u.used_budget)
            u.used_budget += 1
            return delay, False
        if self.policy.max_restarts > 0:
            u.exhausted = True
        return None

    def _handle_exit(self, u: Unit, rc: int) -> None:
        u.exit_codes.append(rc)
        if rc == 0:
            u.finished = True
            return
        died = self.clock()
        decision = self._decide(u)
        if decision is None:
            u.finished = True
            u.gave_up = True
            return
        delay, scheduled = decision
        if delay > 0:
            self.sleep(delay)
        if self.on_respawn is not None:
            self.on_respawn(u)
        u.attempt += 1
        u.proc = self.spawn(u)
        self.respawns.append({
            "name": u.name, "role": u.role, "unit": u.unit,
            "attempt": u.attempt, "exit_code": rc,
            "scheduled": scheduled, "gap_s": self.clock() - died,
        })

    # -- the loop ------------------------------------------------------------
    def supervise(self, *, timeout: float = 600.0) -> dict:
        """Poll until every WORKER unit finishes (servers idle until the
        job's shutdown RPC; they are still respawned on abnormal death).
        Returns the supervision report."""
        deadline = self.clock() + timeout
        timed_out = False
        while True:
            for u in list(self.units.values()):
                if u.finished:
                    continue
                rc = u.proc.poll()
                if rc is not None:
                    self._handle_exit(u, rc)
            workers = [u for u in self.units.values() if u.role == "worker"]
            if all(u.finished for u in workers):
                break
            if self.clock() >= deadline:
                timed_out = True
                break
            self.sleep(self.poll_interval)
        return self.report(timed_out=timed_out)

    def report(self, *, timed_out: bool = False) -> dict:
        return {
            "respawns": list(self.respawns),
            "exit_codes": {n: (u.exit_codes[-1] if u.exit_codes else None)
                           for n, u in self.units.items()},
            "exit_history": {n: list(u.exit_codes)
                             for n, u in self.units.items()},
            "attempts": {n: u.attempt for n, u in self.units.items()},
            "exhausted": sorted(n for n, u in self.units.items()
                                if u.exhausted),
            "gave_up": sorted(n for n, u in self.units.items()
                              if u.gave_up),
            "timed_out": timed_out,
        }
